//! Streaming audio (genre) classification on the Table II workload:
//! GTZAN-like synthetic clips, DeepCoT vs the non-continual encoder with
//! identical weights — accuracy and per-tick latency side by side.
//!
//!     cargo run --release --example audio_stream

use anyhow::Result;

use deepcot::baselines::{ContinualModel, StreamModel, WindowModel};
use deepcot::bench_harness::table::fmt_secs;
use deepcot::bench_harness::{measure_ticks, pipeline::clip_probe_eval};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;
use deepcot::workload::audio;

fn main() -> Result<()> {
    let cli = Cli::new("audio_stream: continual audio classification demo")
        .opt("clips", "40", "corpus size")
        .opt("len", "120", "tokens per clip")
        .opt("seed", "0", "workload seed");
    let args = cli.parse()?;
    let rt = Runtime::new(&deepcot::artifacts_dir())?;

    let mut rng = Rng::new(args.get_u64("seed")?);
    let mut deepcot = ContinualModel::load(&rt, "t2_deepcot")?;
    let corpus = audio::generate(
        &mut rng,
        args.get_usize("clips")?,
        args.get_usize("len")?,
        deepcot.config().d_in,
        deepcot.config().n_classes,
    );

    println!("model          accuracy   per-tick     notes");
    let e = clip_probe_eval(&mut deepcot, &corpus, 0.7, 1e-1)?;
    let (s, _) = measure_ticks(&mut deepcot, 4, 24, 1)?;
    println!(
        "t2_deepcot     {:>7.3}   {:>9}    continual (O(n) per tick)",
        e.accuracy,
        fmt_secs(s.mean_s)
    );

    let mut encoder = WindowModel::load(&rt, "t2_encoder")?;
    let e2 = clip_probe_eval(&mut encoder, &corpus, 0.7, 1e-1)?;
    let (s2, _) = measure_ticks(&mut encoder, 4, 24, 1)?;
    println!(
        "t2_encoder     {:>7.3}   {:>9}    window recompute (O(n^2))",
        e2.accuracy,
        fmt_secs(s2.mean_s)
    );
    println!("\nspeedup: x{:.2} per tick at equal weights", s2.mean_s / s.mean_s);
    Ok(())
}
