//! Online Action Detection on a synthetic THUMOS14-like stream (the
//! Table I workload): train frame-level probes on DeepCoT features,
//! then detect actions live, reporting per-frame predictions and the
//! detection latency after each action onset.
//!
//!     cargo run --release --example oad_stream

use anyhow::Result;

use deepcot::baselines::{ContinualModel, StreamModel};
use deepcot::bench_harness::pipeline::{frame_probe_eval, stream_features};
use deepcot::nn::tensor::Mat;
use deepcot::probe::RidgeProbe;
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;
use deepcot::workload::video;

fn main() -> Result<()> {
    let cli = Cli::new("oad_stream: online action detection demo")
        .opt("streams", "24", "corpus size")
        .opt("len", "192", "frames per stream")
        .opt("seed", "0", "workload seed");
    let args = cli.parse()?;
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    let mut model = ContinualModel::load(&rt, "t1_deepcot")?;
    let cfg = model.config().clone();

    let mut rng = Rng::new(args.get_u64("seed")?);
    let corpus = video::generate(
        &mut rng,
        args.get_usize("streams")?,
        args.get_usize("len")?,
        cfg.d_in,
        cfg.n_classes - 1,
    );

    // quality snapshot (same pipeline as bench_table1)
    let eval = frame_probe_eval(&mut model, &corpus, 0.7, 1e-1)?;
    println!(
        "frame probe: acc={:.3} macroF1={:.3} mAP={:.3}",
        eval.accuracy, eval.macro_f1, eval.frame_map
    );

    // live detection demo on a held-out stream: train probe, stream,
    // report action onsets vs detection times
    let (train, evals) = corpus.split(0.7);
    let d = cfg.d_model;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for s in &train {
        for (i, f) in stream_features(&mut model, s)?.into_iter().enumerate() {
            rows.extend_from_slice(&f);
            labels.push(s.frame_labels[i]);
        }
    }
    let probe = RidgeProbe::train(
        &Mat::from_vec(labels.len(), d, rows),
        &labels,
        corpus.n_classes,
        1e-1,
    )?;
    let demo = evals.first().expect("eval stream");
    println!("\nlive stream (one frame per tick):");
    let feats = stream_features(&mut model, demo)?;
    let mut current = 0usize;
    for (t, f) in feats.iter().enumerate() {
        let pred = probe.predict(f);
        let truth = demo.frame_labels[t];
        if truth != current {
            println!("  t={t:>4}  truth: {} -> {}", label(current), label(truth));
            current = truth;
        }
        if pred != 0 && t > 0 && probe.predict(&feats[t - 1]) == 0 {
            println!("  t={t:>4}  DETECTED {}  (truth {})", label(pred), label(truth));
        }
    }
    Ok(())
}

fn label(c: usize) -> String {
    if c == 0 {
        "background".into()
    } else {
        format!("action#{c}")
    }
}
