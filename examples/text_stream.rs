//! Streaming text classification (the Table IV setting): a 12-layer
//! DeepCoT Roformer-like encoder consuming a token stream one token at
//! a time, with the class motif planted *beyond* the attention window —
//! demonstrating the extended effective receptive field l(n-1)
//! (paper §III-B, Fig. 3) that lets DeepCoT beat same-window baselines
//! at x0.5 window sizes.
//!
//!     cargo run --release --example text_stream

use anyhow::Result;

use deepcot::baselines::{ContinualModel, StreamModel, WindowModel};
use deepcot::bench_harness::pipeline::clip_probe_eval;
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;
use deepcot::workload::text;

fn main() -> Result<()> {
    let cli = Cli::new("text_stream: receptive-field demo on text streams")
        .opt("samples", "48", "corpus size")
        .opt("len", "96", "tokens per sample")
        .opt("window", "24", "attention window (t4 variant suffix)")
        .opt("seed", "0", "workload seed");
    let args = cli.parse()?;
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    let w = args.get_usize("window")?;

    let mut deepcot = ContinualModel::load(&rt, &format!("t4_deepcot_n{w}"))?;
    let mut encoder = WindowModel::load(&rt, &format!("t4_encoder_n{w}"))?;
    let cfg = deepcot.config().clone();

    let mut rng = Rng::new(args.get_u64("seed")?);
    let task = text::make_task(&mut rng, 64, cfg.d_in, cfg.n_classes);
    let n = args.get_usize("samples")?;
    let len = args.get_usize("len")?;

    // motif inside the window vs beyond it (but inside l(n-1))
    let near = text::generate(&mut rng, &task, n, len, 2, w.saturating_sub(6).max(3));
    let far_lo = w + 2; // beyond the plain window
    let far_hi = (2 * (w - 1)).min(len - 4); // within layer-2's reach
    let far = text::generate(&mut rng, &task, n, len, far_lo, far_hi.max(far_lo + 1));

    println!(
        "window n={w}, {} layers -> effective receptive field {}",
        cfg.n_layers,
        cfg.n_layers * (w - 1)
    );
    println!("\nmotif lag        deepcot acc   encoder acc");
    let dn = clip_probe_eval(&mut deepcot, &near, 0.7, 1e-1)?;
    let en = clip_probe_eval(&mut encoder, &near, 0.7, 1e-1)?;
    println!("inside window    {:>10.3}   {:>10.3}", dn.accuracy, en.accuracy);
    let df = clip_probe_eval(&mut deepcot, &far, 0.7, 1e-1)?;
    let ef = clip_probe_eval(&mut encoder, &far, 0.7, 1e-1)?;
    println!("beyond window    {:>10.3}   {:>10.3}", df.accuracy, ef.accuracy);
    println!(
        "\nbeyond-window information is reachable only through the \
         continual memories (paper Fig. 3)."
    );
    Ok(())
}
