//! Quickstart: load a DeepCoT variant, stream tokens through it, read
//! logits — the smallest end-to-end use of the public API.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use deepcot::baselines::{ContinualModel, StreamModel, WindowModel};
use deepcot::flops::{format_flops, per_tick, FlopsMode};
use deepcot::runtime::{HostTensor, Runtime};
use deepcot::util::rng::Rng;

fn main() -> Result<()> {
    // 1. open the artifacts produced by `make artifacts`
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // 2. load the continual model and its non-continual baseline
    //    (identical weights — the paper's equivalence protocol)
    let mut deepcot = ContinualModel::load(&rt, "t1_deepcot")?;
    let mut encoder = WindowModel::load(&rt, "t1_encoder")?;
    let cfg = deepcot.config().clone();
    println!(
        "model: {} layers, window {}, d_model {} ({} classes)",
        cfg.n_layers, cfg.window, cfg.d_model, cfg.n_classes
    );

    // 3. stream random tokens through both; compare cost + outputs
    let mut rng = Rng::new(7);
    let mut last = (Vec::new(), Vec::new());
    for t in 0..2 * cfg.window {
        let tok = rng.normal_vec(cfg.d_in, 1.0);
        let a = deepcot.tick(&HostTensor::new(vec![1, 1, cfg.d_in], tok.clone())?)?;
        let b = encoder.tick(&HostTensor::new(vec![1, 1, cfg.d_in], tok)?)?;
        last = (a.logits.data, b.logits.data);
        if t == 0 {
            println!("tick 0 ok — logits dim {}", last.0.len());
        }
    }
    println!("final deepcot logits[0..4] = {:?}", &last.0[..4]);
    println!("final encoder logits[0..4] = {:?}", &last.1[..4]);
    println!(
        "per-tick attention FLOPs: deepcot {} vs encoder {} ({}x reduction)",
        format_flops(per_tick("deepcot", &cfg, FlopsMode::AttentionOnly)),
        format_flops(per_tick("encoder", &cfg, FlopsMode::AttentionOnly)),
        per_tick("encoder", &cfg, FlopsMode::AttentionOnly)
            / per_tick("deepcot", &cfg, FlopsMode::AttentionOnly).max(1)
    );
    Ok(())
}
