//! Quickstart: the smallest end-to-end use of the serving API — spawn
//! the engine, open an RAII `Session`, stream tokens, read logits, and
//! watch a live migration happen underneath an unbroken stream.
//!
//! Hermetic by default: serves a tiny synthetic DeepCoT on the
//! pure-Rust scalar backend (no XLA library, no `make artifacts`).
//! Point `--artifacts` / `DEEPCOT_ARTIFACTS` at real artifacts and
//! swap the variant name to serve those instead.
//!
//!     cargo run --release --example quickstart
//!
//! # Serving over TCP (`deepcot_serve`)
//!
//! Everything below also works from *outside* the process: the
//! `deepcot_serve` binary puts the same engine behind the
//! length-prefixed wire protocol in `deepcot::net` (one engine
//! `Session` per client stream; backpressure, saturation, and shutdown
//! arrive as the same typed errors you see in-process):
//!
//!     # terminal 1 — hermetic synthetic server on an ephemeral port
//!     cargo run --release --bin deepcot_serve -- \
//!         --synthetic --shards 2 --listen 127.0.0.1:7433
//!
//!     # one-command loopback self-test (CI runs exactly this):
//!     # serve, push 100 tokens over TCP, clean shutdown
//!     cargo run --release --bin deepcot_serve -- \
//!         --synthetic --listen 127.0.0.1:0 --smoke 100
//!
//! Since PR 10 the server is a readiness-loop executor, not
//! thread-per-connection: one poll thread owns every socket
//! (nonblocking reads, per-connection write queues, tick multiplexing,
//! idle reaping) and a fixed worker pool decodes frames and drives the
//! engine, so thread count is O(workers) at any connection fanout. The
//! wire protocol is unchanged — every pre-PR-10 client still speaks to
//! it byte-for-byte. Admission control knobs (all on `deepcot_serve`
//! and `EngineConfig`):
//!
//! * `--net-workers N` — worker pool size (`0` = auto, clamped 2..=8);
//! * `--net-max-conns N` — connection cap; beyond it new sockets get a
//!   best-effort typed `Saturated` goodbye;
//! * `--net-max-streams N` — per-connection open-stream quota,
//!   answered with `Saturated { capacity: quota }` when exceeded;
//! * `--net-auth-token SECRET` — shared-secret OPEN auth: every frame
//!   is rejected until the connection's first OPEN carries the token
//!   (`NetClient::set_auth_token` on the client side; the token rides
//!   in an extended OPEN body, so unauthenticated servers and old
//!   captures are unaffected).
//!
//! From Rust, connect with `deepcot::net::client::NetClient`
//! (`connect` → `open` → `push`/`recv_tick` → `close`, plus
//! `shutdown_server` for a graceful drain). The client pipelines:
//! `push_nowait` keeps up to `set_max_inflight` requests in flight and
//! `flush_acks` settles them FIFO, so one load generator can saturate
//! the server; `bench_throughput --tcp` measures the same closed-loop
//! traffic end-to-end over loopback, and `--conns 100,1000,10000`
//! sweeps connection fanout against the fixed worker pool.
//!
//! # Kernel dispatch
//!
//! The scalar backend's hot kernels resolve onto an explicit-SIMD path
//! once at startup (`deepcot::nn::simd`): AVX2 on x86_64, NEON on
//! aarch64, the portable scalar suite otherwise. Dispatch never
//! changes stream bits — SIMD ≡ scalar is pinned bitwise — only
//! latency. Three knobs, strongest first:
//!
//! * `EngineConfig::builder().kernel_dispatch("scalar".parse()?)` (or
//!   any `DispatchChoice`) pins the path in code;
//! * `--kernel-dispatch scalar|avx2|neon` on `deepcot_serve` and both
//!   benches sets the same config field from the CLI;
//! * `DEEPCOT_KERNEL_DISPATCH=scalar|avx2|neon` forces the path under
//!   the default `auto` without touching config or flags.
//!
//! Forcing a path the CPU can't run fails loudly at startup. The
//! resolved path is reported in `ClusterMetrics::kernel_dispatch`, in
//! the `dispatch=<path>` token of `report()` / the TCP `METRICS`
//! reply, and in `bench_kernels --json` next to the detected CPU
//! features.
//!
//! # Observability
//!
//! The serving stack instruments itself through `deepcot::obs`. One
//! knob picks how much gets recorded — `off | counters | spans |
//! journal` (cumulative; default `journal`) — settable three ways:
//! `EngineConfig::builder().obs(ObsLevel::Spans)` in code, `--obs
//! spans` on `deepcot_serve` and the benches, or `DEEPCOT_OBS=spans`
//! in the environment. The pre-existing counters and tick/queue
//! histograms are always on; `off` reduces every newer site to a
//! branch, and no level ever changes stream bits or allocates on the
//! steady-state tick path (pinned in `tests/zero_alloc.rs`).
//!
//! What each layer adds:
//!
//! * `counters` — uptime, wall-clock boot timestamp, monotonic
//!   snapshot sequence numbers, and windowed rates (ticks/s, tokens/s,
//!   rejects/s) over a trailing 10s window.
//! * `spans` — per-stage pipeline latency (`deepcot::obs::span`):
//!   `ingress`, `queue`, `batch_form`, `backend_step`, `deliver`,
//!   `pipeline_total` (the four engine segments partition it), plus
//!   `net_decode` / `net_encode` and the migration legs. Exposed as
//!   the `deepcot_stage_latency_us{stage="..."}` summary family and
//!   in `bench_throughput --json` under `results[].stages`.
//! * `journal` — a bounded, rate-gated ring of typed events
//!   (`deepcot::obs::journal`): stream lifecycle, migrations,
//!   admission rejects, protocol errors, slow ticks (`--slow-tick-us`
//!   threshold), kernel-dispatch resolution.
//!
//! `deepcot_serve --metrics-listen 127.0.0.1:9100` binds the HTTP
//! endpoint (`deepcot::obs::server`):
//!
//!     curl localhost:9100/metrics        # Prometheus text format
//!     curl localhost:9100/metrics.json   # the same snapshot as JSON
//!     curl localhost:9100/journal        # drain the event journal
//!
//! The same Prometheus document answers the `METRICS_PROM` wire frame
//! (`NetClient::metrics_prometheus`), and `deepcot_serve` dumps any
//! undrained journal events as one-line JSON on shutdown. Headline
//! series: `deepcot_ticks_total`, `deepcot_tick_latency_us`,
//! `deepcot_stage_latency_us{stage=...}`, per-shard
//! `deepcot_shard_*_total` breakdowns (each sums to its aggregate —
//! pinned in `tests/obs.rs`), `deepcot_slow_ticks_total`, and the
//! `deepcot_net_*` front-door counters.
//!
//! # Session persistence & crash recovery
//!
//! A DeepCoT stream's whole identity is its `StreamState` (K/V rings +
//! position clock) plus any queued tokens — a few KB that move as a
//! value. Hibernation (`deepcot::store` + the coordinator policy)
//! builds on that: when every lane is taken, the coldest stream is
//! *spilled* to a `StateStore` instead of the open being rejected, and
//! the next PUSH to a spilled stream transparently restores it (the
//! victim of *that* restore spills in turn). Slot capacity bounds
//! **active** streams, not registered ones — a 64-lane cluster happily
//! owns 10 000 registered sessions (pinned in `tests/hibernate.rs`,
//! bitwise against per-stream oracles). Enable it in code with
//! `EngineConfig::builder().hibernate(true)` (in-memory store) or
//! `--hibernate` on `deepcot_serve` and the benches.
//!
//! Give the store a disk instead and the same mechanism is crash
//! recovery:
//!
//!     # terminal 1 — persistent server: every spill is journaled to
//!     # DIR/streams.log, plus a full-cluster snapshot every 2s and on
//!     # clean shutdown
//!     cargo run --release --bin deepcot_serve -- \
//!         --synthetic --state-dir /tmp/deepcot-state \
//!         --snapshot-every-ms 2000 --listen 127.0.0.1:7433
//!
//!     # kill -9 it mid-traffic, then start it again with the same
//!     # --state-dir: every registered stream is recovered as
//!     # hibernated, and clients reattach with an OPEN-resume frame
//!     # (`NetClient::open_resume(id)`) — tick ordinals and bits
//!     # continue exactly where the dead process left off.
//!
//! In-process the same flow is `handle.snapshot()` (checkpoint every
//! lane-resident stream), `handle.hibernated_streams()` /
//! `is_hibernated(id)` (inspection), and `handle.resume(id)` (reattach
//! a recovered, ownerless stream as a fresh RAII `Session`). A PUSH to
//! a recovered-but-unresumed stream answers the typed
//! `EngineError::Hibernated` — distinct from `StreamClosed`, so
//! clients can tell "resume me" from "gone". Records are versioned,
//! length-checked, and CRC-guarded (`store::codec`): a torn or
//! corrupted state file is detected and reported, never decoded into
//! garbage state (fuzzed over ≥10k corrupt blobs in `tests/store.rs`).
//!
//! # Failure modes & recovery
//!
//! Failure domains are isolated per shard: each worker runs under
//! `catch_unwind`, so a panic kills *one shard*, never the engine. A
//! supervisor thread marks the shard dead, re-homes its checkpointed
//! streams onto the survivors (from their last `snapshot()` /
//! `--snapshot-every-ms` checkpoint, via the hibernate path — clients
//! reattach with the same OPEN-resume flow as crash recovery), and
//! respawns the worker with bounded exponential backoff. What clients
//! see in the window is typed, not mysterious:
//!
//! * `EngineError::ShardFailed { retryable: true }` — the shard is
//!   down and the supervisor is re-homing; retry, then resume. Over
//!   the wire this is `ErrCode::ShardFailed` with the retryable flag
//!   in `aux`. A healthy engine **never** converts this into
//!   `ShuttingDown` — that variant is reserved for real shutdown.
//! * `EngineError::Hibernated(id)` — the stream was re-homed to its
//!   checkpoint and waits for an OPEN-resume (`handle.resume(id)` /
//!   `NetClient::open_resume`).
//! * `ShardFailed { retryable: false }` — the stream had no
//!   checkpoint to recover from; a typed loss notice, never a hang.
//!
//! The state store degrades instead of failing: a checkpoint or spill
//! that hits an I/O error is retried with backoff
//! (`store::with_retries`), then journaled (`StoreDegraded`) and
//! metered (`store_degraded`, `store_retries`) while serving
//! continues. The TCP front door rides out slow and dead peers too:
//! per-connection read/idle timeouts reap stuck connections
//! (`conns_reaped`), and `NetClient` reconnects with seeded
//! exponential backoff + jitter (`ReconnectPolicy`), re-establishing
//! streams via OPEN-resume; exhausted retries surface as the typed
//! `EngineError::Timeout`.
//!
//! All of it is rehearsable deterministically: a seeded fault plan —
//! `DEEPCOT_FAULT=seed=7,shard=0,shard_step=@40` in the environment,
//! `--fault ...` on `deepcot_serve`, or
//! `EngineConfig::builder().fault("...".parse()?)` in code — injects
//! panics, store I/O errors, torn snapshot tails, and network faults
//! at exact (seed, site, call#) points. Disabled (the default) it is
//! a single branch: no allocation, no bit changes. `tests/fault.rs`
//! drives a ≥500-op chaos run bitwise against a scalar oracle, and CI
//! kills a shard mid-load over TCP (`deepcot_serve
//! --expect-respawn`), asserting the respawn shows up in /metrics
//! (`deepcot_shards_respawned_total`) while the client finishes.

use std::time::Duration;

use anyhow::Result;

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::EngineThread;
use deepcot::obs::expo;
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::rng::Rng;

fn main() -> Result<()> {
    // 1. write a hermetic synthetic artifacts dir (manifest + weights)
    let spec = SyntheticServeSpec::default();
    let dir = spec.write()?;

    // 2. configure + spawn the engine: builder-style config, two shards
    let cfg = EngineConfig::builder()
        .artifacts_dir(dir)
        .variant(SyntheticServeSpec::variant_name(1))
        .backend(EngineBackend::Scalar)
        .shards(2)
        .slots_per_shard(2)
        .hibernate(true) // full shards spill cold streams, never reject
        .batch_deadline(Duration::from_millis(1))
        .build();
    let engine = EngineThread::spawn(cfg)?;
    let handle = engine.handle();

    // 3. open a stream: `open` returns an RAII Session (close-on-drop)
    let session = handle.open()?;
    println!("opened stream {:?} on shard {:?}", session.id(), handle.shard_of(session.id()));

    // 4. stream tokens through it; recv returns per-tick logits
    let mut rng = Rng::new(7);
    let mut last = Vec::new();
    for t in 0..2 * spec.window {
        session.push(rng.normal_vec(spec.d_in, 1.0))?;
        let out = session.recv_timeout(Duration::from_secs(10))?;
        last = out.logits;
        if t == 0 {
            println!("tick 1 ok — {} logits, {} activations", last.len(), out.out.len());
        }
        // 5. halfway through, live-migrate the stream to the other
        //    shard — state (K/V rings + position clock) moves with it
        //    and the session never notices
        if t == spec.window {
            let from = handle.shard_of(session.id()).unwrap_or(0);
            let to = (from + 1) % handle.n_shards();
            handle.migrate(session.id(), to)?;
            println!("migrated stream {:?}: shard {from} -> shard {to}", session.id());
        }
    }
    println!("final logits[0..4] = {:?}", &last[..4.min(last.len())]);

    // 6. hibernation: register more streams than the 4 lanes can hold —
    //    the coldest spill to the state store instead of the opens
    //    failing, and a push to a spilled stream wakes it transparently
    let extras: Vec<_> = (0..5).map(|_| handle.open()).collect::<Result<_, _>>()?;
    println!(
        "6 registered streams on 4 lanes: {} hibernated",
        handle.hibernated_streams().len()
    );
    session.push(rng.normal_vec(spec.d_in, 1.0))?; // wakes it if it was spilled
    session.recv_timeout(Duration::from_secs(10))?;
    drop(extras);

    // 7. observability: the operator report, then the same snapshot in
    //    the Prometheus text format (what `deepcot_serve`'s
    //    `--metrics-listen` endpoint serves on /metrics)
    let m = handle.metrics()?;
    println!("{}", m.report());
    let prom = expo::render_prometheus(handle.obs(), &m, None);
    let stage_lines = prom.lines().filter(|l| l.starts_with("deepcot_stage_latency_us")).count();
    println!("prometheus exposition: {} bytes, {stage_lines} stage-span lines", prom.len());

    session.close(); // explicit; dropping the session would do the same
    engine.shutdown()?;
    Ok(())
}
