//! End-to-end serving driver (the DESIGN.md §5 validation run): spin up
//! the engine on a batched DeepCoT variant, subject it to an open-loop
//! multi-client load with stream churn (opens/closes mid-run) over the
//! RAII `Session` API, and report latency percentiles + throughput.
//! Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve -- --streams 8 --ticks 200
//!
//! Requires `make artifacts` (or a synthetic artifacts dir via
//! `--artifacts`); see `quickstart.rs` for a hermetic engine demo.

use std::time::{Duration, Instant};

use anyhow::Result;

use deepcot::config::EngineConfig;
use deepcot::coordinator::engine::{EngineError, EngineThread, Session};
use deepcot::manifest::Manifest;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;
use deepcot::util::timing::Summary;

fn main() -> Result<()> {
    let cli = EngineConfig::cli(Cli::new("serve: end-to-end engine load driver"))
        .opt("streams", "8", "concurrent client streams (may exceed slots)")
        .opt("ticks", "200", "tokens per stream")
        .opt("churn", "0.1", "probability a client reopens its stream per tick")
        .opt("seed", "0", "workload seed");
    let args = cli.parse()?;
    let cfg = EngineConfig::from_args(&args)?;
    let n_streams = args.get_usize("streams")?;
    let ticks = args.get_usize("ticks")?;
    let churn = args.get_f64("churn")?;
    let seed = args.get_u64("seed")?;

    let (manifest, _) = Manifest::load(&cfg.artifacts_dir)?;
    let mc = manifest.variant(&cfg.variant)?.config.clone();
    let lane = mc.m_tokens * mc.d_in;
    println!(
        "serving {} (B={} slots), {} clients x {} ticks, churn={churn}",
        cfg.variant, mc.batch, n_streams, ticks
    );

    let engine = EngineThread::spawn(cfg.clone())?;
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for s in 0..n_streams {
        let h = engine.handle();
        clients.push(std::thread::spawn(move || -> Result<(u64, u64, Vec<Duration>)> {
            let mut rng = Rng::new(seed ^ ((s as u64 + 1) * 0x9E37));
            let mut lats = Vec::with_capacity(ticks);
            let mut ok = 0u64;
            let mut rejected = 0u64;
            let mut sess: Option<Session> = None;
            for _ in 0..ticks {
                if sess.is_none() || rng.chance(churn) {
                    // dropping the old session closes its stream
                    sess = None;
                    match h.open() {
                        Ok(s) => sess = Some(s),
                        // typically Saturated under oversubscription
                        Err(_) => {
                            rejected += 1;
                            std::thread::sleep(Duration::from_millis(2));
                            continue;
                        }
                    }
                }
                let sent = Instant::now();
                let push_err = match sess.as_ref() {
                    Some(session) => session.push(rng.normal_vec(lane, 1.0)).err(),
                    None => continue,
                };
                match push_err {
                    None => {}
                    Some(EngineError::Backpressure(_)) => {
                        rejected += 1;
                        std::thread::sleep(Duration::from_micros(500));
                        continue;
                    }
                    Some(_) => {
                        rejected += 1;
                        sess = None; // stream torn down; reopen next tick
                        continue;
                    }
                }
                let recv = match sess.as_ref() {
                    Some(session) => session.recv_timeout(Duration::from_secs(30)),
                    None => continue,
                };
                match recv {
                    Ok(_) => {
                        lats.push(sent.elapsed());
                        ok += 1;
                    }
                    Err(_) => rejected += 1,
                }
            }
            // the last session closes on drop
            Ok((ok, rejected, lats))
        }));
    }

    let mut all_lats = Vec::new();
    let (mut total_ok, mut total_rej) = (0u64, 0u64);
    for c in clients {
        let (ok, rej, lats) = c.join().expect("client")?;
        total_ok += ok;
        total_rej += rej;
        all_lats.extend(lats);
    }
    let wall = t0.elapsed();
    let m = engine.handle().metrics()?;
    let s = Summary::of(&all_lats);
    println!("== serve results ==");
    println!(
        "completed={} rejected={} wall={:.2?} throughput={:.1} tokens/s",
        total_ok,
        total_rej,
        wall,
        total_ok as f64 / wall.as_secs_f64()
    );
    println!(
        "client latency: mean={:.3}ms p50={:.3}ms p95={:.3}ms max={:.3}ms",
        s.mean_s * 1e3,
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.max_s * 1e3
    );
    println!("engine: {}", m.report());
    engine.shutdown()?;
    Ok(())
}
