//! Micro-benchmark of the L3 hot path: one continual tick, decomposed
//! into upload / execute / feedback. The §Perf optimization loop's
//! primary instrument.
use std::time::Instant;

use deepcot::baselines::{ContinualModel, StreamModel};
use deepcot::runtime::{HostTensor, Runtime};
use deepcot::util::rng::Rng;
use deepcot::util::timing::Summary;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    for variant in [
        "t1_deepcot",
        "t1_deepcot_jnp",
        "t2_deepcot",
        "serve_deepcot_b4",
        "serve_deepcot_b4_pallas",
        "serve_deepcot_b16",
        "t4_deepcot_n24",
    ] {
        let mut m = match ContinualModel::load(&rt, variant) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let cfg = m.config().clone();
        let lane = cfg.batch * cfg.m_tokens * cfg.d_in;
        let mut rng = Rng::new(1);
        let mut durs = Vec::new();
        for _ in 0..8 {
            let t = HostTensor::new(vec![cfg.batch, cfg.m_tokens, cfg.d_in], rng.normal_vec(lane, 1.0)).unwrap();
            m.tick(&t).unwrap();
        }
        for _ in 0..200 {
            let t = HostTensor::new(vec![cfg.batch, cfg.m_tokens, cfg.d_in], rng.normal_vec(lane, 1.0)).unwrap();
            let t0 = Instant::now();
            m.tick(&t).unwrap();
            durs.push(t0.elapsed());
        }
        let s = Summary::of(&durs);
        println!(
            "{variant:<22} mean={:>9.1}µs p50={:>9.1}µs p95={:>9.1}µs",
            s.mean_s * 1e6,
            s.p50_s * 1e6,
            s.p95_s * 1e6
        );
    }
}
