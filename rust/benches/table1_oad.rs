//! `cargo bench` target for Table I (quick mode; full run: bench_table1).
use deepcot::bench_harness::tables::{run_table1, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    run_table1(&rt, &BenchOpts::quick()).expect("table1");
}
