//! `cargo bench` target for Table III (quick mode; full run: bench_table3).
use deepcot::bench_harness::tables::{run_table3, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    run_table3(&rt, &BenchOpts::quick()).expect("table3");
}
