//! `cargo bench` target for Table II (quick mode; full run: bench_table2).
use deepcot::bench_harness::tables::{run_table2, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    run_table2(&rt, &BenchOpts::quick()).expect("table2");
}
