//! `cargo bench` target for Fig. 1 (quick mode, truncated sweep;
//! full sweep: bench_fig1).
use deepcot::bench_harness::tables::{run_fig1, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    run_fig1(&rt, &BenchOpts::quick(), &[16, 64, 256]).expect("fig1");
}
