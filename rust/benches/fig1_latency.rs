//! `cargo bench` target for Fig. 1 (quick mode, truncated sweep;
//! full sweep: bench_fig1). Runs the scalar-engine comparison always
//! and the PJRT sweep only when the XLA runtime + artifacts exist.
use deepcot::bench_harness::tables::{run_fig1, run_fig1_scalar, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let windows = [16, 64, 256];
    run_fig1_scalar(&BenchOpts::quick(), &windows, 4).expect("fig1 scalar");
    match Runtime::new(&deepcot::artifacts_dir()) {
        Ok(rt) => {
            run_fig1(&rt, &BenchOpts::quick(), &windows).expect("fig1");
        }
        Err(e) => eprintln!("skipping PJRT sweep: {e}"),
    }
}
