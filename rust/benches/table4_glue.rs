//! `cargo bench` target for Table IV (quick mode, x1 scale, 3 tasks;
//! full grid: bench_table4).
use deepcot::bench_harness::tables::{run_table4, BenchOpts};
use deepcot::runtime::Runtime;

fn main() {
    let rt = Runtime::new(&deepcot::artifacts_dir()).expect("artifacts");
    run_table4(&rt, &BenchOpts::quick(), &[1], &["CoLA", "SST-2", "MNLI"]).expect("table4");
}
