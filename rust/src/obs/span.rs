//! Tick-pipeline stage spans: named segments of the serving hot path
//! timed with a [`Stopwatch`] and recorded into per-stage
//! [`LatencyHisto`]s.
//!
//! The engine-side stages are *contiguous* timestamp segments — queue,
//! batch-form, backend-step, and deliver partition the interval from
//! the oldest enqueue in a tick to its last delivery, so their sums
//! reconcile with [`Stage::PipelineTotal`] to within µs truncation
//! (pinned in `tests/obs.rs`). Net decode/encode and the migration
//! legs are independent spans around their own code paths.
//!
//! Everything here is preallocated and alloc-free to record, so spans
//! can run inside the zero-alloc steady state (`tests/zero_alloc.rs`
//! measures with `obs=spans` forced on in CI).

use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyHisto;

/// A named stage of the serving pipeline.
///
/// The discriminant doubles as the index into [`StageSpans`] storage;
/// keep [`Stage::ALL`] in declaration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Push receipt → handed to the batcher (per accepted push).
    Ingress = 0,
    /// Oldest enqueue in a tick → the tick starts forming (per tick).
    Queue = 1,
    /// Tick formation: lane planning + queue bookkeeping (per tick).
    BatchForm = 2,
    /// Backend `tick_lanes` execution (per tick).
    BackendStep = 3,
    /// Tick results fanned out to stream owners (per tick).
    Deliver = 4,
    /// Oldest enqueue → last delivery; the end-to-end cut the four
    /// engine segments above sum to (per tick).
    PipelineTotal = 5,
    /// Wire frame parsed → typed `Frame` decoded (per net frame).
    NetDecode = 6,
    /// Typed reply → encoded wire bytes (per net frame).
    NetEncode = 7,
    /// Migration export leg on the source shard (per export).
    MigExport = 8,
    /// Full stream-unavailability window of a completed migration
    /// (the front door's quiesce histogram, folded in at snapshot).
    MigQuiesce = 9,
    /// Migration import leg on the target shard (per import).
    MigImport = 10,
    /// Hibernation spill: victim lane export + store write on the
    /// shard making room (per spill).
    HibernateSpill = 11,
    /// Hibernation restore: store read + lane import on the landing
    /// shard (per restore).
    HibernateRestore = 12,
    /// Full-cluster snapshot wall time at the front door (per
    /// snapshot; folded in from the door's histogram like MigQuiesce).
    Snapshot = 13,
}

impl Stage {
    /// Every stage, in storage order.
    pub const ALL: [Stage; 14] = [
        Stage::Ingress,
        Stage::Queue,
        Stage::BatchForm,
        Stage::BackendStep,
        Stage::Deliver,
        Stage::PipelineTotal,
        Stage::NetDecode,
        Stage::NetEncode,
        Stage::MigExport,
        Stage::MigQuiesce,
        Stage::MigImport,
        Stage::HibernateSpill,
        Stage::HibernateRestore,
        Stage::Snapshot,
    ];

    /// Stable snake_case name used as the `stage` label in exposition.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Ingress => "ingress",
            Stage::Queue => "queue",
            Stage::BatchForm => "batch_form",
            Stage::BackendStep => "backend_step",
            Stage::Deliver => "deliver",
            Stage::PipelineTotal => "pipeline_total",
            Stage::NetDecode => "net_decode",
            Stage::NetEncode => "net_encode",
            Stage::MigExport => "migration_export",
            Stage::MigQuiesce => "migration_quiesce",
            Stage::MigImport => "migration_import",
            Stage::HibernateSpill => "hibernate_spill",
            Stage::HibernateRestore => "hibernate_restore",
            Stage::Snapshot => "snapshot",
        }
    }
}

/// One latency histogram per [`Stage`]; fixed storage, alloc-free to
/// record and reset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpans {
    histos: [LatencyHisto; 14],
}

impl Default for StageSpans {
    fn default() -> Self {
        Self::new()
    }
}

impl StageSpans {
    /// Empty histograms for every stage.
    pub fn new() -> Self {
        Self { histos: std::array::from_fn(|_| LatencyHisto::new()) }
    }

    /// Record one sample for a stage.
    pub fn record(&mut self, stage: Stage, d: Duration) {
        self.histos[stage as usize].record(d);
    }

    /// The histogram for one stage.
    pub fn get(&self, stage: Stage) -> &LatencyHisto {
        &self.histos[stage as usize]
    }

    /// Fold another span set into this one, stage-wise.
    pub fn merge(&mut self, other: &StageSpans) {
        for (a, b) in self.histos.iter_mut().zip(&other.histos) {
            a.merge(b);
        }
    }

    /// Fold a standalone histogram into one stage's slot (used to pull
    /// the front door's quiesce histogram into the span view).
    pub fn merge_histo(&mut self, stage: Stage, h: &LatencyHisto) {
        self.histos[stage as usize].merge(h);
    }

    /// Zero every histogram in place (no allocation).
    pub fn reset(&mut self) {
        for h in &mut self.histos {
            h.reset();
        }
    }

    /// Total samples recorded across all stages.
    pub fn total_count(&self) -> u64 {
        self.histos.iter().map(|h| h.count()).sum()
    }

    /// Iterate `(stage, histogram)` pairs in storage order.
    pub fn iter(&self) -> impl Iterator<Item = (Stage, &LatencyHisto)> {
        Stage::ALL.iter().map(move |&s| (s, &self.histos[s as usize]))
    }
}

/// Minimal lap timer for carving a code path into contiguous spans.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    last: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { last: Instant::now() }
    }

    /// Time since the last lap (or start), and reset the lap marker —
    /// consecutive laps partition the elapsed time exactly.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now.duration_since(self.last);
        self.last = now;
        d
    }

    /// Time since the last lap without resetting.
    pub fn elapsed(&self) -> Duration {
        self.last.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_indices_match_all_order() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i, "Stage::ALL out of declaration order at {i}");
        }
    }

    #[test]
    fn record_and_merge_roundtrip() {
        let mut a = StageSpans::new();
        let mut b = StageSpans::new();
        a.record(Stage::BackendStep, Duration::from_micros(100));
        b.record(Stage::BackendStep, Duration::from_micros(300));
        b.record(Stage::Deliver, Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.get(Stage::BackendStep).count(), 2);
        assert_eq!(a.get(Stage::Deliver).count(), 1);
        assert_eq!(a.total_count(), 3);
        a.reset();
        assert_eq!(a.total_count(), 0);
        assert_eq!(a, StageSpans::new());
    }

    #[test]
    fn stopwatch_laps_partition_elapsed() {
        let mut w = Stopwatch::start();
        let a = w.lap();
        let b = w.lap();
        // laps are non-negative and consecutive (monotonic clock)
        assert!(a + b >= a);
        assert!(w.elapsed() >= Duration::ZERO);
    }
}
