//! Metrics exposition: Prometheus text format + JSON snapshots, with
//! monotonic snapshot sequence numbers and windowed rates computed
//! from a small preallocated ring of timestamped counter samples.
//!
//! Every renderer here is a cold path (scrapes are rare); the only
//! hot-adjacent structure is [`SnapshotRing`], whose `push` is
//! alloc-free after construction so rate accounting can never perturb
//! the serving steady state.

use std::time::Duration;

use crate::coordinator::cluster::ClusterMetrics;
use crate::coordinator::metrics::LatencyHisto;
use crate::net::server::NetMetrics;
use crate::obs::journal::{Event, EventKind};
use crate::obs::span::Stage;
use crate::obs::{ObsHandle, ObsLevel};

/// Window the built-in rate view is computed over.
pub const RATE_WINDOW: Duration = Duration::from_secs(10);

/// One timestamped sample of the cumulative counters that back the
/// windowed-rate view.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RateSample {
    /// Microseconds since obs boot when the sample was taken.
    pub t_us: u64,
    /// Cumulative ticks at sample time.
    pub ticks: u64,
    /// Cumulative accepted token vectors at sample time.
    pub tokens_in: u64,
    /// Cumulative delivered tick results at sample time.
    pub outputs: u64,
    /// Cumulative rejects (admission + cluster) at sample time.
    pub rejects: u64,
}

impl RateSample {
    /// Build a sample from a cluster snapshot at `t_us`.
    pub fn from_cluster(t_us: u64, m: &ClusterMetrics) -> Self {
        Self {
            t_us,
            ticks: m.ticks,
            tokens_in: m.tokens_in,
            outputs: m.outputs,
            rejects: m.admission_rejects + m.cluster_rejects,
        }
    }
}

/// Windowed rates: counter deltas against the oldest sample inside the
/// window, divided by the actual span between the two samples.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Rates {
    /// The actual span the deltas cover (zero when no prior sample).
    pub window: Duration,
    /// Batched ticks per second over the window.
    pub ticks_per_sec: f64,
    /// Accepted token vectors per second over the window.
    pub tokens_per_sec: f64,
    /// Delivered tick results per second over the window.
    pub outputs_per_sec: f64,
    /// Rejects per second over the window.
    pub rejects_per_sec: f64,
}

/// Fixed-capacity ring of [`RateSample`]s; push is alloc-free after
/// construction (overflow overwrites the oldest sample).
#[derive(Debug)]
pub struct SnapshotRing {
    samples: Vec<RateSample>,
    head: usize,
}

impl SnapshotRing {
    /// Ring holding at most `capacity` samples.
    pub fn new(capacity: usize) -> Self {
        Self { samples: Vec::with_capacity(capacity.max(2)), head: 0 }
    }

    /// Record a sample (alloc-free; overwrites the oldest when full).
    pub fn push(&mut self, s: RateSample) {
        if self.samples.len() < self.samples.capacity() {
            self.samples.push(s); // within reserved capacity: no realloc
        } else {
            self.samples[self.head] = s;
            self.head = (self.head + 1) % self.samples.capacity();
        }
    }

    /// Samples currently resident.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples are resident.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Rates for `now` against the oldest resident sample not older
    /// than `window` (zero rates when no usable baseline exists).
    pub fn rates_against(&self, now: &RateSample, window: Duration) -> Rates {
        let window_us = window.as_micros() as u64;
        let mut base: Option<&RateSample> = None;
        for s in &self.samples {
            if s.t_us >= now.t_us || now.t_us - s.t_us > window_us {
                continue;
            }
            if base.map(|b| s.t_us < b.t_us).unwrap_or(true) {
                base = Some(s);
            }
        }
        let Some(b) = base else { return Rates::default() };
        let dt = (now.t_us - b.t_us) as f64 / 1e6;
        if dt <= 0.0 {
            return Rates::default();
        }
        let per_sec = |n: u64, o: u64| n.saturating_sub(o) as f64 / dt;
        Rates {
            window: Duration::from_micros(now.t_us - b.t_us),
            ticks_per_sec: per_sec(now.ticks, b.ticks),
            tokens_per_sec: per_sec(now.tokens_in, b.tokens_in),
            outputs_per_sec: per_sec(now.outputs, b.outputs),
            rejects_per_sec: per_sec(now.rejects, b.rejects),
        }
    }
}

/// Growing Prometheus text buffer: `# HELP`/`# TYPE` headers + samples.
struct Prom {
    out: String,
}

impl Prom {
    fn new() -> Self {
        Self { out: String::with_capacity(8 << 10) }
    }

    fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// One sample line; `labels` is the raw inside-braces text ("" = none).
    fn sample(&mut self, name: &str, labels: &str, value: f64) {
        if labels.is_empty() {
            self.out.push_str(&format!("{name} {value}\n"));
        } else {
            self.out.push_str(&format!("{name}{{{labels}}} {value}\n"));
        }
    }

    fn counter(&mut self, name: &str, help: &str, v: u64) {
        self.header(name, "counter", help);
        self.sample(name, "", v as f64);
    }

    fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.header(name, "gauge", help);
        self.sample(name, "", v);
    }

    /// Summary-style histogram exposition: p50/p90/p99 + sum + count.
    /// `labels` ride on every line so one family can carry many series
    /// (e.g. a `stage` label).
    fn summary_series(&mut self, name: &str, labels: &str, h: &LatencyHisto) {
        for (q, qs) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
            let ql = if labels.is_empty() {
                format!("quantile=\"{qs}\"")
            } else {
                format!("{labels},quantile=\"{qs}\"")
            };
            self.sample(name, &ql, h.quantile(q).as_micros() as f64);
        }
        self.sample(&format!("{name}_sum"), labels, h.sum().as_micros() as f64);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    fn summary(&mut self, name: &str, help: &str, h: &LatencyHisto) {
        self.header(name, "summary", help);
        self.summary_series(name, "", h);
    }
}

/// Render the full cluster (+ optional net-layer) snapshot in the
/// Prometheus text exposition format. Bumps the snapshot sequence and
/// feeds the windowed-rate ring when the level admits counters.
pub fn render_prometheus(obs: &ObsHandle, m: &ClusterMetrics, net: Option<&NetMetrics>) -> String {
    let mut p = Prom::new();

    if obs.level() >= ObsLevel::Counters {
        p.gauge("deepcot_uptime_seconds", "Seconds since engine boot", m.uptime.as_secs_f64());
        p.gauge(
            "deepcot_boot_timestamp_seconds",
            "Unix time the engine booted",
            m.boot_unix_ms as f64 / 1e3,
        );
        p.counter("deepcot_snapshot_seq", "Monotonic snapshot sequence number", obs.next_seq());
    }

    if !m.kernel_dispatch.is_empty() {
        p.header("deepcot_engine_info", "gauge", "Engine build/runtime facts as labels");
        p.sample("deepcot_engine_info", &format!("dispatch=\"{}\"", m.kernel_dispatch), 1.0);
    }
    p.gauge("deepcot_shards", "Worker shard count", m.per_shard.len() as f64);

    p.counter("deepcot_ticks_total", "Batched ticks executed", m.ticks);
    p.counter("deepcot_tokens_in_total", "Token vectors accepted by batchers", m.tokens_in);
    p.counter("deepcot_outputs_total", "Tick results delivered to stream owners", m.outputs);
    p.counter("deepcot_streams_opened_total", "Streams admitted", m.streams_opened);
    p.counter("deepcot_streams_closed_total", "Streams explicitly closed", m.streams_closed);
    p.counter("deepcot_streams_evicted_total", "Idle streams reclaimed", m.streams_evicted);
    p.counter("deepcot_admission_rejects_total", "Shard admission rejects", m.admission_rejects);
    p.counter("deepcot_cluster_rejects_total", "Opens rejected by every shard", m.cluster_rejects);
    p.counter("deepcot_placed_primary_total", "Streams on their preferred shard", m.placed_primary);
    p.counter("deepcot_placed_fallback_total", "Streams on a fallback shard", m.placed_fallback);
    p.counter("deepcot_migrations_attempted_total", "Migrations requested", m.migrations_attempted);
    p.counter("deepcot_migrations_completed_total", "Migrations landed", m.migrations_completed);
    p.counter("deepcot_migrations_aborted_total", "Live migrations failed", m.migrations_aborted);
    p.counter("deepcot_slow_ticks_total", "Ticks over the slow-tick threshold", m.slow_ticks);
    p.counter(
        "deepcot_streams_hibernated_total",
        "Streams spilled to the state store",
        m.streams_hibernated,
    );
    p.counter(
        "deepcot_streams_restored_total",
        "Hibernated streams restored into lanes",
        m.streams_restored,
    );
    p.counter(
        "deepcot_streams_recovered_total",
        "Streams re-registered as hibernated at boot",
        m.streams_recovered,
    );
    p.counter("deepcot_snapshots_total", "Full-cluster snapshots taken", m.snapshots_taken);
    p.gauge(
        "deepcot_hibernated_resident",
        "Streams currently hibernated in the state store",
        m.hibernated_resident as f64,
    );
    p.counter(
        "deepcot_shard_failures_total",
        "Shard worker deaths observed by the supervisor",
        m.shard_failures,
    );
    p.counter(
        "deepcot_shards_respawned_total",
        "Dead shards respawned back into service",
        m.shards_respawned,
    );
    p.gauge("deepcot_shards_dead", "Shards currently dead (failing fast)", m.shards_dead as f64);
    p.counter(
        "deepcot_streams_rehomed_total",
        "Crashed-shard streams re-homed onto their last checkpoint",
        m.streams_rehomed,
    );
    p.counter(
        "deepcot_streams_lost_total",
        "Crashed-shard streams lost for lack of a checkpoint",
        m.streams_lost,
    );
    p.counter(
        "deepcot_store_degraded_total",
        "Store failures survived in degraded mode",
        m.store_degraded,
    );
    p.counter(
        "deepcot_store_retries_total",
        "Retries spent by degraded-store backoff",
        m.store_retries,
    );

    // per-shard breakdown: every series a scraper can sum back to the
    // aggregate above (pinned in tests/obs.rs)
    p.header("deepcot_shard_ticks_total", "counter", "Per-shard tick counts");
    for (i, s) in m.per_shard.iter().enumerate() {
        p.sample("deepcot_shard_ticks_total", &format!("shard=\"{i}\""), s.ticks as f64);
    }
    let shard_series: [(&str, fn(&crate::coordinator::metrics::EngineMetrics) -> u64); 10] = [
        ("deepcot_shard_tokens_in_total", |s| s.tokens_in),
        ("deepcot_shard_outputs_total", |s| s.outputs),
        ("deepcot_shard_streams_opened_total", |s| s.streams_opened),
        ("deepcot_shard_streams_closed_total", |s| s.streams_closed),
        ("deepcot_shard_streams_evicted_total", |s| s.streams_evicted),
        ("deepcot_shard_admission_rejects_total", |s| s.admission_rejects),
        ("deepcot_shard_migrations_in_total", |s| s.migrations_in),
        ("deepcot_shard_migrations_out_total", |s| s.migrations_out),
        ("deepcot_shard_streams_hibernated_total", |s| s.streams_hibernated),
        ("deepcot_shard_streams_restored_total", |s| s.streams_restored),
    ];
    for (name, field) in shard_series {
        p.header(name, "counter", "Per-shard counter");
        for (i, s) in m.per_shard.iter().enumerate() {
            p.sample(name, &format!("shard=\"{i}\""), field(s) as f64);
        }
    }

    p.summary("deepcot_tick_latency_us", "Backend step latency per tick (µs)", &m.tick_latency);
    p.summary("deepcot_queue_latency_us", "Batcher queue wait per token (µs)", &m.queue_latency);
    p.summary(
        "deepcot_quiesce_latency_us",
        "Stream-unavailability window per completed migration (µs)",
        &m.quiesce_latency,
    );
    p.summary(
        "deepcot_snapshot_latency_us",
        "Wall time per full-cluster snapshot (µs)",
        &m.snapshot_latency,
    );

    if obs.spans_on() {
        let mut stages = m.stage_spans.clone();
        if let Some(n) = net {
            stages.merge(&n.spans);
        }
        p.header(
            "deepcot_stage_latency_us",
            "summary",
            "Pipeline stage latency breakdown (µs); engine stages partition pipeline_total",
        );
        for (stage, h) in stages.iter() {
            p.summary_series("deepcot_stage_latency_us", &format!("stage=\"{}\"", stage.name()), h);
        }
    }

    if obs.level() >= ObsLevel::Counters {
        let sample = RateSample::from_cluster(obs.now_us(), m);
        let r = obs.observe(sample, RATE_WINDOW);
        let w = format!("window=\"{}s\"", RATE_WINDOW.as_secs());
        p.header("deepcot_ticks_per_second", "gauge", "Tick rate over the trailing window");
        p.sample("deepcot_ticks_per_second", &w, r.ticks_per_sec);
        p.header("deepcot_tokens_per_second", "gauge", "Token rate over the trailing window");
        p.sample("deepcot_tokens_per_second", &w, r.tokens_per_sec);
        p.header("deepcot_rejects_per_second", "gauge", "Reject rate over the trailing window");
        p.sample("deepcot_rejects_per_second", &w, r.rejects_per_sec);
    }

    if obs.level() >= ObsLevel::Journal {
        let js = obs.journal().stats();
        p.counter("deepcot_journal_events_total", "Events accepted into the journal", js.recorded);
        p.counter("deepcot_journal_dropped_total", "Events overwritten", js.dropped_oldest);
        p.counter("deepcot_journal_suppressed_total", "Events rate-gated", js.suppressed);
    }

    if let Some(n) = net {
        let active = n.connections_active as f64;
        p.gauge("deepcot_net_connections_active", "Connections serving now", active);
        p.counter(
            "deepcot_net_connections_accepted_total",
            "Connections accepted",
            n.connections_accepted,
        );
        p.counter("deepcot_net_frames_in_total", "Frames read off sockets", n.frames_in);
        p.counter("deepcot_net_frames_out_total", "Frames written to sockets", n.frames_out);
        p.counter("deepcot_net_protocol_errors_total", "Bad frames answered", n.protocol_errors);
        p.counter("deepcot_net_streams_opened_total", "Wire streams opened", n.streams_opened);
        p.counter(
            "deepcot_net_shutdown_requests_total",
            "SHUTDOWN frames honored",
            n.shutdown_requests,
        );
        p.counter(
            "deepcot_net_idle_reaped_total",
            "Idle stream-less connections reaped by the server",
            n.idle_conns_reaped,
        );
        p.counter(
            "deepcot_net_connections_rejected_total",
            "Connections refused at the admission limit",
            n.connections_rejected,
        );
        p.counter(
            "deepcot_net_auth_failures_total",
            "Requests rejected by the shared-token auth gate",
            n.auth_failures,
        );
        p.counter(
            "deepcot_net_quota_rejected_total",
            "Opens rejected by the per-connection stream quota",
            n.quota_rejected,
        );
        p.counter(
            "deepcot_net_write_overflows_total",
            "Connections torn down for overrunning the write queue",
            n.write_overflows,
        );
        p.counter("deepcot_net_polls_total", "Readiness-loop wakeups", n.polls);
        p.gauge("deepcot_net_workers", "Worker threads decoding frames", n.workers as f64);
        p.gauge(
            "deepcot_net_jobs_depth",
            "Decoded requests queued for workers right now",
            n.jobs_depth as f64,
        );
        p.gauge(
            "deepcot_net_jobs_depth_peak",
            "High-water mark of the worker job queue",
            n.jobs_depth_peak as f64,
        );
        p.gauge(
            "deepcot_net_write_queue_bytes",
            "Bytes parked in per-connection write queues right now",
            n.write_queue_bytes as f64,
        );
        p.gauge(
            "deepcot_net_write_queue_peak_bytes",
            "High-water mark of parked write-queue bytes",
            n.write_queue_peak as f64,
        );
        if obs.level() >= ObsLevel::Counters {
            p.gauge(
                "deepcot_net_uptime_seconds",
                "Seconds since the net front door started",
                n.uptime.as_secs_f64(),
            );
            p.gauge(
                "deepcot_net_boot_timestamp_seconds",
                "Unix time the net front door started",
                n.boot_unix_ms as f64 / 1e3,
            );
        }
    }

    p.out
}

fn histo_json(h: &LatencyHisto) -> crate::util::json::Json {
    use crate::util::json::{num, obj};
    obj(vec![
        ("count", num(h.count() as f64)),
        ("p50_us", num(h.quantile(0.5).as_micros() as f64)),
        ("p90_us", num(h.quantile(0.9).as_micros() as f64)),
        ("p99_us", num(h.quantile(0.99).as_micros() as f64)),
        ("max_us", num(h.max().as_micros() as f64)),
        ("sum_us", num(h.sum().as_micros() as f64)),
    ])
}

/// Render the same snapshot as machine-readable JSON (served on
/// `/metrics.json`). Bumps the snapshot sequence and feeds the rate
/// ring exactly like the Prometheus renderer.
pub fn render_json(obs: &ObsHandle, m: &ClusterMetrics, net: Option<&NetMetrics>) -> String {
    use crate::util::json::{num, obj, Json};
    let mut fields: Vec<(&str, Json)> = vec![
        ("obs_level", Json::Str(obs.level().to_string())),
        ("shards", num(m.per_shard.len() as f64)),
        ("kernel_dispatch", Json::Str(m.kernel_dispatch.clone())),
        ("ticks", num(m.ticks as f64)),
        ("tokens_in", num(m.tokens_in as f64)),
        ("outputs", num(m.outputs as f64)),
        ("streams_opened", num(m.streams_opened as f64)),
        ("streams_closed", num(m.streams_closed as f64)),
        ("streams_evicted", num(m.streams_evicted as f64)),
        ("admission_rejects", num(m.admission_rejects as f64)),
        ("cluster_rejects", num(m.cluster_rejects as f64)),
        ("placed_primary", num(m.placed_primary as f64)),
        ("placed_fallback", num(m.placed_fallback as f64)),
        ("migrations_attempted", num(m.migrations_attempted as f64)),
        ("migrations_completed", num(m.migrations_completed as f64)),
        ("migrations_aborted", num(m.migrations_aborted as f64)),
        ("slow_ticks", num(m.slow_ticks as f64)),
        ("streams_hibernated", num(m.streams_hibernated as f64)),
        ("streams_restored", num(m.streams_restored as f64)),
        ("streams_recovered", num(m.streams_recovered as f64)),
        ("snapshots_taken", num(m.snapshots_taken as f64)),
        ("hibernated_resident", num(m.hibernated_resident as f64)),
        ("shard_failures", num(m.shard_failures as f64)),
        ("shards_respawned", num(m.shards_respawned as f64)),
        ("shards_dead", num(m.shards_dead as f64)),
        ("streams_rehomed", num(m.streams_rehomed as f64)),
        ("streams_lost", num(m.streams_lost as f64)),
        ("store_degraded", num(m.store_degraded as f64)),
        ("store_retries", num(m.store_retries as f64)),
        ("tick_latency", histo_json(&m.tick_latency)),
        ("queue_latency", histo_json(&m.queue_latency)),
        ("quiesce_latency", histo_json(&m.quiesce_latency)),
        ("snapshot_latency", histo_json(&m.snapshot_latency)),
    ];
    if obs.level() >= ObsLevel::Counters {
        fields.push(("seq", num(obs.next_seq() as f64)));
        fields.push(("uptime_seconds", num(m.uptime.as_secs_f64())));
        fields.push(("boot_unix_ms", num(m.boot_unix_ms as f64)));
        let sample = RateSample::from_cluster(obs.now_us(), m);
        let r = obs.observe(sample, RATE_WINDOW);
        fields.push((
            "rates",
            obj(vec![
                ("window_seconds", num(r.window.as_secs_f64())),
                ("ticks_per_sec", num(r.ticks_per_sec)),
                ("tokens_per_sec", num(r.tokens_per_sec)),
                ("outputs_per_sec", num(r.outputs_per_sec)),
                ("rejects_per_sec", num(r.rejects_per_sec)),
            ]),
        ));
    }
    if obs.spans_on() {
        let mut stages = m.stage_spans.clone();
        if let Some(n) = net {
            stages.merge(&n.spans);
        }
        let entries = stages.iter().map(|(s, h)| (s.name(), histo_json(h))).collect::<Vec<_>>();
        fields.push(("stages", obj(entries)));
    }
    if obs.level() >= ObsLevel::Journal {
        let js = obs.journal().stats();
        fields.push((
            "journal",
            obj(vec![
                ("events", num(js.recorded as f64)),
                ("resident", num(js.len as f64)),
                ("dropped", num(js.dropped_oldest as f64)),
                ("suppressed", num(js.suppressed as f64)),
            ]),
        ));
    }
    if let Some(n) = net {
        fields.push((
            "net",
            obj(vec![
                ("connections_active", num(n.connections_active as f64)),
                ("connections_accepted", num(n.connections_accepted as f64)),
                ("frames_in", num(n.frames_in as f64)),
                ("frames_out", num(n.frames_out as f64)),
                ("protocol_errors", num(n.protocol_errors as f64)),
                ("streams_opened", num(n.streams_opened as f64)),
                ("shutdown_requests", num(n.shutdown_requests as f64)),
                ("idle_conns_reaped", num(n.idle_conns_reaped as f64)),
                ("connections_rejected", num(n.connections_rejected as f64)),
                ("auth_failures", num(n.auth_failures as f64)),
                ("quota_rejected", num(n.quota_rejected as f64)),
                ("write_overflows", num(n.write_overflows as f64)),
                ("workers", num(n.workers as f64)),
                ("jobs_depth", num(n.jobs_depth as f64)),
                ("jobs_depth_peak", num(n.jobs_depth_peak as f64)),
                ("write_queue_bytes", num(n.write_queue_bytes as f64)),
                ("write_queue_peak", num(n.write_queue_peak as f64)),
                ("polls", num(n.polls as f64)),
                ("uptime_seconds", num(n.uptime.as_secs_f64())),
                ("boot_unix_ms", num(n.boot_unix_ms as f64)),
            ]),
        ));
    }
    let shard_objs = m
        .per_shard
        .iter()
        .map(|s| {
            obj(vec![
                ("ticks", num(s.ticks as f64)),
                ("tokens_in", num(s.tokens_in as f64)),
                ("outputs", num(s.outputs as f64)),
                ("streams_opened", num(s.streams_opened as f64)),
                ("streams_closed", num(s.streams_closed as f64)),
                ("streams_evicted", num(s.streams_evicted as f64)),
                ("admission_rejects", num(s.admission_rejects as f64)),
                ("migrations_in", num(s.migrations_in as f64)),
                ("migrations_out", num(s.migrations_out as f64)),
                ("streams_hibernated", num(s.streams_hibernated as f64)),
                ("streams_restored", num(s.streams_restored as f64)),
            ])
        })
        .collect::<Vec<_>>();
    fields.push(("per_shard", Json::Arr(shard_objs)));
    obj(fields).to_string()
}

/// One journal event as a single-line JSON object (shutdown dumps and
/// `/journal` drains share this shape).
pub fn event_json(e: &Event) -> String {
    let mut s = format!(
        "{{\"seq\":{},\"t_us\":{},\"kind\":\"{}\",\"stream\":{},\"shard\":{},\"aux\":{}",
        e.seq,
        e.t_us,
        e.kind.name(),
        e.stream,
        e.shard,
        e.aux
    );
    if e.kind == EventKind::DispatchResolved {
        s.push_str(&format!(",\"dispatch\":\"{}\"", EventKind::dispatch_aux_name(e.aux)));
    }
    s.push('}');
    s
}

/// Drain the journal into a JSON document: health counters + every
/// resident event, oldest first. Draining consumes the events.
pub fn render_journal(obs: &ObsHandle) -> String {
    let stats = obs.journal().stats();
    let events = obs.journal().drain();
    let mut s = format!(
        "{{\"recorded\":{},\"dropped\":{},\"suppressed\":{},\"events\":[",
        stats.recorded, stats.dropped_oldest, stats.suppressed
    );
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&event_json(e));
    }
    s.push_str("]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_us: u64, ticks: u64) -> RateSample {
        RateSample { t_us, ticks, tokens_in: ticks * 4, outputs: ticks * 4, rejects: 0 }
    }

    #[test]
    fn rates_use_oldest_in_window() {
        let mut ring = SnapshotRing::new(8);
        ring.push(sample(0, 0));
        ring.push(sample(1_000_000, 100));
        // now = t 2s: baseline is t 0 (inside a 10s window) → 100 ticks / 2s
        let r = ring.rates_against(&sample(2_000_000, 200), Duration::from_secs(10));
        assert_eq!(r.ticks_per_sec, 100.0);
        assert_eq!(r.tokens_per_sec, 400.0);
        assert_eq!(r.window, Duration::from_secs(2));
        // a 1.5s window excludes t 0: baseline is t 1s → 100 ticks / 1s
        let r = ring.rates_against(&sample(2_000_000, 200), Duration::from_millis(1500));
        assert_eq!(r.ticks_per_sec, 100.0);
        assert_eq!(r.window, Duration::from_secs(1));
    }

    #[test]
    fn rates_zero_without_baseline() {
        let ring = SnapshotRing::new(4);
        let r = ring.rates_against(&sample(5_000_000, 10), Duration::from_secs(10));
        assert_eq!(r, Rates::default());
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut ring = SnapshotRing::new(4);
        for i in 0..10u64 {
            ring.push(sample(i * 1_000_000, i));
        }
        assert_eq!(ring.len(), 4);
        // the oldest resident sample is t=6s; within a 100s window the
        // baseline for t=10s is that sample
        let r = ring.rates_against(&sample(10_000_000, 100), Duration::from_secs(100));
        assert_eq!(r.window, Duration::from_secs(4));
    }

    #[test]
    fn event_json_shapes() {
        let e = Event {
            seq: 3,
            t_us: 77,
            kind: EventKind::StreamOpen,
            stream: 9,
            shard: 1,
            aux: 0,
        };
        assert_eq!(
            event_json(&e),
            "{\"seq\":3,\"t_us\":77,\"kind\":\"stream_open\",\"stream\":9,\"shard\":1,\"aux\":0}"
        );
        let d = Event { kind: EventKind::DispatchResolved, aux: 1, ..e };
        assert!(event_json(&d).contains("\"dispatch\":\"avx2\""));
    }
}
