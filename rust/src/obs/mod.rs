//! Production observability: tick-pipeline stage spans, a
//! Prometheus-style exposition layer, and a structured event journal.
//!
//! The pieces:
//!
//! * [`span`] — named pipeline stages ([`span::Stage`]) timed into
//!   per-stage latency histograms ([`span::StageSpans`]) carried in
//!   `EngineMetrics`, so queueing delay, kernel time, and delivery
//!   time are separate cuts instead of one opaque tick latency.
//! * [`journal`] — a bounded, alloc-free-on-push ring of typed events
//!   ([`journal::EventKind`]): stream lifecycle, migrations, admission
//!   rejects, protocol errors, slow ticks, dispatch resolution.
//! * [`expo`] — renderers for the Prometheus text format and a JSON
//!   snapshot, with monotonic snapshot sequence numbers and windowed
//!   rates (ticks/s, tokens/s, rejects/s) off a ring of timestamped
//!   samples.
//! * [`server`] — a std-only HTTP/1.0 responder serving `/metrics`,
//!   `/metrics.json`, and `/journal` on `--metrics-listen`; the same
//!   text also answers the `METRICS_PROM` wire frame.
//!
//! Cost is governed by one knob, [`ObsLevel`] (`off | counters |
//! spans | journal`, config + `--obs` CLI + `DEEPCOT_OBS` env): the
//! pre-existing counters and the tick/queue histograms are always on;
//! `off` reduces every *new* instrumentation site to a branch, and
//! each higher level adds the next layer. None of it may perturb
//! results — every bitwise pin in the test suite holds at every
//! level, and steady-state ticks stay allocation-free with spans on.

pub mod expo;
pub mod journal;
pub mod server;
pub mod span;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant, SystemTime};

use anyhow::Result;

use crate::obs::expo::{RateSample, Rates, SnapshotRing};
use crate::obs::journal::{EventKind, Journal};

/// How much observability the serving stack records.
///
/// Levels are cumulative (`Ord`): `spans` includes everything
/// `counters` does, `journal` includes everything `spans` does. The
/// legacy counters and tick/queue histograms predate the knob and are
/// always on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum ObsLevel {
    /// New instrumentation compiled down to a branch: no uptime/rate
    /// snapshots, no stage spans, no journal.
    Off,
    /// Uptime, boot timestamp, snapshot sequence numbers, windowed
    /// rates.
    Counters,
    /// Plus per-stage pipeline latency spans.
    Spans,
    /// Plus the structured event journal (the default: events are
    /// rare, rate-gated, and bounded).
    #[default]
    Journal,
}

impl ObsLevel {
    /// Environment variable consulted by [`ObsLevel::default_from_env`].
    pub const ENV: &'static str = "DEEPCOT_OBS";

    /// The default level, overridable via `DEEPCOT_OBS` (an invalid
    /// value warns and keeps the default rather than failing boot).
    pub fn default_from_env() -> Self {
        match std::env::var(Self::ENV) {
            Ok(v) => v.parse().unwrap_or_else(|e| {
                eprintln!("deepcot obs: ignoring {}={v:?}: {e}", Self::ENV);
                Self::Journal
            }),
            Err(_) => Self::Journal,
        }
    }
}

impl std::str::FromStr for ObsLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "counters" => Ok(Self::Counters),
            "spans" => Ok(Self::Spans),
            "journal" => Ok(Self::Journal),
            other => anyhow::bail!("unknown obs level {other:?} (want off|counters|spans|journal)"),
        }
    }
}

impl std::fmt::Display for ObsLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Off => "off",
            Self::Counters => "counters",
            Self::Spans => "spans",
            Self::Journal => "journal",
        };
        f.write_str(s)
    }
}

/// Shared observability state for one engine: the level knob, boot
/// clocks, the event journal, the snapshot sequence counter, and the
/// windowed-rate sample ring. Created once by `ShardedEngine::spawn`,
/// cloned (cheaply — everything shared is behind an `Arc`) into every
/// shard worker and the net layer.
#[derive(Debug, Clone)]
pub struct ObsHandle {
    level: ObsLevel,
    boot: Instant,
    boot_unix_ms: u64,
    journal: Arc<Journal>,
    seq: Arc<AtomicU64>,
    ring: Arc<Mutex<SnapshotRing>>,
}

impl ObsHandle {
    /// Fresh observability state at the given level, booted now.
    pub fn new(level: ObsLevel) -> Self {
        let boot_unix_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        Self {
            level,
            boot: Instant::now(),
            boot_unix_ms,
            journal: Arc::new(Journal::new()),
            seq: Arc::new(AtomicU64::new(0)),
            ring: Arc::new(Mutex::new(SnapshotRing::new(64))),
        }
    }

    /// The configured observability level.
    pub fn level(&self) -> ObsLevel {
        self.level
    }

    /// True when stage spans should be recorded (`spans` or above).
    pub fn spans_on(&self) -> bool {
        self.level >= ObsLevel::Spans
    }

    /// Time since this handle was created.
    pub fn uptime(&self) -> Duration {
        self.boot.elapsed()
    }

    /// Microseconds since boot (the journal/ring timebase).
    pub fn now_us(&self) -> u64 {
        self.boot.elapsed().as_micros() as u64
    }

    /// Wall-clock boot instant, milliseconds since the Unix epoch.
    pub fn boot_unix_ms(&self) -> u64 {
        self.boot_unix_ms
    }

    /// The shared event journal (push directly for pre-gated sites;
    /// prefer [`ObsHandle::event`] which branches on the level).
    pub fn journal(&self) -> &Journal {
        &self.journal
    }

    /// Record a journal event iff the level admits the journal — the
    /// one-line instrumentation call whose `off` cost is this branch.
    pub fn event(&self, kind: EventKind, stream: u64, shard: i64, aux: u64) {
        if self.level >= ObsLevel::Journal {
            self.journal.push(kind, stream, shard, aux);
        }
    }

    /// Next monotonic snapshot sequence number (each rendered snapshot
    /// consumes one, so a scraper can detect reordering or gaps).
    pub fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    fn ring(&self) -> MutexGuard<'_, SnapshotRing> {
        self.ring.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Push a timestamped counter sample and read the windowed rates
    /// back (deltas against the oldest sample inside `window`).
    pub fn observe(&self, sample: RateSample, window: Duration) -> Rates {
        let mut ring = self.ring();
        let rates = ring.rates_against(&sample, window);
        ring.push(sample);
        rates
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(ObsLevel::Off < ObsLevel::Counters);
        assert!(ObsLevel::Counters < ObsLevel::Spans);
        assert!(ObsLevel::Spans < ObsLevel::Journal);
        for (s, want) in [
            ("off", ObsLevel::Off),
            ("counters", ObsLevel::Counters),
            ("spans", ObsLevel::Spans),
            ("journal", ObsLevel::Journal),
        ] {
            assert_eq!(s.parse::<ObsLevel>().unwrap(), want);
            assert_eq!(want.to_string(), s);
        }
        assert!("verbose".parse::<ObsLevel>().is_err());
        assert_eq!(ObsLevel::default(), ObsLevel::Journal);
    }

    #[test]
    fn handle_gates_journal_on_level() {
        let off = ObsHandle::new(ObsLevel::Spans);
        off.event(EventKind::StreamOpen, 1, 0, 0);
        assert!(off.journal().is_empty(), "spans level must not journal");
        let on = ObsHandle::new(ObsLevel::Journal);
        on.event(EventKind::StreamOpen, 1, 0, 0);
        assert_eq!(on.journal().len(), 1);
        assert!(on.spans_on());
        assert!(!ObsHandle::new(ObsLevel::Counters).spans_on());
    }

    #[test]
    fn seq_is_monotonic() {
        let h = ObsHandle::new(ObsLevel::Counters);
        let a = h.next_seq();
        let b = h.next_seq();
        assert!(b > a);
    }
}
