//! The metrics endpoint: a deliberately tiny HTTP/1.0 text responder
//! (std-only, like the rest of the stack) bound on `--metrics-listen`.
//!
//! Routes:
//!
//! * `GET /metrics` — Prometheus text format
//!   (`text/plain; version=0.0.4`)
//! * `GET /metrics.json` — the JSON snapshot (`application/json`)
//! * `GET /journal` — drain the event journal as JSON (consumes the
//!   drained events)
//! * `GET /` — a short plain-text index of the above
//!
//! Scrapes are rare and tiny, so connections are handled serially on
//! one acceptor thread with a short read timeout — no pool, no
//! keep-alive (`Connection: close`, HTTP/1.0 semantics). Rendering is
//! delegated to a caller-supplied closure so the endpoint composes
//! over any engine + net handle pair without this module knowing
//! their types.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Which rendering a request resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricsFormat {
    /// Prometheus text exposition (`/metrics`).
    Prometheus,
    /// JSON snapshot (`/metrics.json`).
    Json,
    /// Journal drain (`/journal`).
    JournalDrain,
}

/// The running metrics endpoint. Start with [`MetricsServer::start`];
/// stops on drop (or explicitly via [`MetricsServer::stop`]).
pub struct MetricsServer {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve scrapes, rendering
    /// each through `render`.
    pub fn start<A, F>(addr: A, render: F) -> io::Result<MetricsServer>
    where
        A: ToSocketAddrs,
        F: Fn(MetricsFormat) -> String + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stopping = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let stopping = Arc::clone(&stopping);
            std::thread::Builder::new().name("deepcot-obs-http".into()).spawn(move || {
                loop {
                    let sock = match listener.accept() {
                        Ok((sock, _peer)) => sock,
                        Err(_) if stopping.load(Ordering::SeqCst) => return,
                        Err(_) => {
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    if stopping.load(Ordering::SeqCst) {
                        return; // the wake-up connection
                    }
                    serve_one(sock, &render);
                }
            })?
        };
        Ok(MetricsServer { addr, stopping, acceptor: Some(acceptor) })
    }

    /// The address the endpoint actually listens on (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the acceptor thread.
    pub fn stop(&mut self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the acceptor out of accept(); it sees the flag and exits
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Answer one scrape: read the request head, route on the request
/// line, write one response, close.
fn serve_one<F: Fn(MetricsFormat) -> String>(mut sock: TcpStream, render: &F) {
    let _ = sock.set_read_timeout(Some(Duration::from_secs(2)));
    let mut buf = [0u8; 1024];
    let n = match sock.read(&mut buf) {
        Ok(0) | Err(_) => return,
        Ok(n) => n,
    };
    let head = String::from_utf8_lossy(&buf[..n]);
    let mut words = head.split_whitespace();
    let (method, path) = (words.next().unwrap_or(""), words.next().unwrap_or(""));
    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain; charset=utf-8", "GET only\n".to_string())
    } else {
        match path {
            "/metrics" => (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                render(MetricsFormat::Prometheus),
            ),
            "/metrics.json" => ("200 OK", "application/json", render(MetricsFormat::Json)),
            "/journal" => ("200 OK", "application/json", render(MetricsFormat::JournalDrain)),
            "/" => (
                "200 OK",
                "text/plain; charset=utf-8",
                "deepcot metrics endpoint\n/metrics\n/metrics.json\n/journal\n".to_string(),
            ),
            _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    let _ = write!(
        sock,
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    let _ = sock.write_all(body.as_bytes());
    let _ = sock.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut sock = TcpStream::connect(addr).expect("connect");
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(sock, "GET {path} HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        sock.read_to_string(&mut out).expect("read response");
        out
    }

    #[test]
    fn routes_and_statuses() {
        let mut srv = MetricsServer::start("127.0.0.1:0", |f| match f {
            MetricsFormat::Prometheus => "deepcot_test_total 1\n".to_string(),
            MetricsFormat::Json => "{\"ok\":true}".to_string(),
            MetricsFormat::JournalDrain => "{\"events\":[]}".to_string(),
        })
        .expect("start");
        let addr = srv.local_addr();
        let prom = get(addr, "/metrics");
        assert!(prom.starts_with("HTTP/1.0 200 OK\r\n"), "{prom}");
        assert!(prom.contains("text/plain; version=0.0.4"));
        assert!(prom.ends_with("deepcot_test_total 1\n"));
        let json = get(addr, "/metrics.json");
        assert!(json.contains("application/json"));
        assert!(json.ends_with("{\"ok\":true}"));
        assert!(get(addr, "/journal").ends_with("{\"events\":[]}"));
        assert!(get(addr, "/").contains("/metrics.json"));
        assert!(get(addr, "/nope").starts_with("HTTP/1.0 404"));
        // sequential scrapes keep working (serial accept loop)
        assert!(get(addr, "/metrics").contains("deepcot_test_total"));
        srv.stop();
    }

    #[test]
    fn non_get_is_405() {
        let srv = MetricsServer::start("127.0.0.1:0", |_| String::new()).expect("start");
        let mut sock = TcpStream::connect(srv.local_addr()).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(sock, "POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        sock.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.0 405"), "{out}");
    }
}
