//! Structured event journal: a bounded ring of typed, fixed-size
//! serving events (stream lifecycle, migrations, admission rejects,
//! protocol errors, slow ticks, dispatch resolution).
//!
//! The hot-path contract is the same as the rest of the serving stack:
//! `push` takes one short mutex hold, never blocks on a full ring
//! (overflow overwrites the oldest event), and never allocates — the
//! ring is preallocated at construction and [`Event`] is `Copy` with
//! no owned strings. Per-event-type rate gates (a rolling one-second
//! window) keep a pathological event storm from drowning the rest of
//! the journal. Draining (the only allocating operation) happens on
//! the cold exposition path.

use std::sync::Mutex;
use std::time::Instant;

/// Typed journal event kinds.
///
/// The discriminant doubles as the index into the per-kind rate-gate
/// and suppression tables; keep [`EventKind::ALL`] in declaration
/// order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A stream was admitted on a shard (fresh open).
    StreamOpen = 0,
    /// A bound stream was explicitly closed.
    StreamClose = 1,
    /// An idle stream was reclaimed by admission.
    StreamEvict = 2,
    /// The front door started a live migration (`aux` = target shard).
    MigrationAttempt = 3,
    /// A migration landed on its target (`aux` = quiesce time, µs).
    MigrationComplete = 4,
    /// A migration failed; the stream stayed on (or returned to) its
    /// source shard where possible.
    MigrationAbort = 5,
    /// A shard rejected an open or import at capacity.
    AdmissionReject = 6,
    /// The net layer hit a malformed or unexpected frame (`aux` = the
    /// offending opcode when known).
    ProtoError = 7,
    /// A tick's end-to-end pipeline time exceeded the configured
    /// threshold (`aux` = observed time, µs).
    SlowTick = 8,
    /// A shard backend resolved its kernel dispatch path at boot
    /// (`aux`: 0 = scalar, 1 = avx2, 2 = neon, 3 = other).
    DispatchResolved = 9,
    /// A stream was spilled from its lane to the state store (its state
    /// is kept and resumable, unlike a `StreamEvict`).
    StreamHibernate = 10,
    /// A hibernated stream was restored into a lane.
    StreamRestore = 11,
    /// A full-cluster snapshot completed (`aux` = streams checkpointed).
    Snapshot = 12,
    /// A shard worker died (panic or backend failure); its streams are
    /// being re-homed and the worker respawned (`shard` = which).
    ShardPanic = 13,
    /// A dead shard's worker was respawned and is serving again
    /// (`aux` = respawns of this shard so far).
    ShardRespawn = 14,
    /// A dead shard's stream was re-homed onto the state store from its
    /// last checkpoint (resume it to continue; `aux` = checkpoint tick).
    StreamRehomed = 15,
    /// A dead shard's stream had no checkpoint to recover from; its
    /// state is lost and its owner was told so (typed, never a hang).
    StreamLost = 16,
    /// A store write failed past its retry budget; the engine degraded
    /// (kept serving without that checkpoint) instead of aborting
    /// (`aux` = retries spent).
    StoreDegraded = 17,
    /// An idle, stream-less connection was reaped by the net layer's
    /// slow-loris defense (`aux` = idle time, ms).
    ConnReaped = 18,
    /// A connection was refused at the front door's admission limit
    /// (`aux` = the configured connection cap).
    ConnRejected = 19,
    /// A request was rejected by the front door's shared-token auth
    /// gate (missing, early, or wrong token).
    AuthFailure = 20,
    /// A socket option could not be applied to an accepted connection
    /// (`aux`: 0 = nonblocking — fatal, the connection is refused;
    /// 1 = nodelay — degraded, the connection is kept).
    SockOptFailed = 21,
    /// A connection's write queue overran its byte cap and the
    /// connection was torn down (`aux` = queued bytes at overflow).
    WriteOverflow = 22,
}

impl EventKind {
    /// Every kind, in storage order.
    pub const ALL: [EventKind; 23] = [
        EventKind::StreamOpen,
        EventKind::StreamClose,
        EventKind::StreamEvict,
        EventKind::MigrationAttempt,
        EventKind::MigrationComplete,
        EventKind::MigrationAbort,
        EventKind::AdmissionReject,
        EventKind::ProtoError,
        EventKind::SlowTick,
        EventKind::DispatchResolved,
        EventKind::StreamHibernate,
        EventKind::StreamRestore,
        EventKind::Snapshot,
        EventKind::ShardPanic,
        EventKind::ShardRespawn,
        EventKind::StreamRehomed,
        EventKind::StreamLost,
        EventKind::StoreDegraded,
        EventKind::ConnReaped,
        EventKind::ConnRejected,
        EventKind::AuthFailure,
        EventKind::SockOptFailed,
        EventKind::WriteOverflow,
    ];

    /// Encode a kernel-dispatch path name as `DispatchResolved` aux.
    pub fn dispatch_aux(path: &str) -> u64 {
        match path {
            "scalar" => 0,
            "avx2" => 1,
            "neon" => 2,
            _ => 3,
        }
    }

    /// Decode a `DispatchResolved` aux back to its path name.
    pub fn dispatch_aux_name(aux: u64) -> &'static str {
        match aux {
            0 => "scalar",
            1 => "avx2",
            2 => "neon",
            _ => "other",
        }
    }

    /// Stable snake_case name used in exposition.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::StreamOpen => "stream_open",
            EventKind::StreamClose => "stream_close",
            EventKind::StreamEvict => "stream_evict",
            EventKind::MigrationAttempt => "migration_attempt",
            EventKind::MigrationComplete => "migration_complete",
            EventKind::MigrationAbort => "migration_abort",
            EventKind::AdmissionReject => "admission_reject",
            EventKind::ProtoError => "proto_error",
            EventKind::SlowTick => "slow_tick",
            EventKind::DispatchResolved => "dispatch_resolved",
            EventKind::StreamHibernate => "stream_hibernate",
            EventKind::StreamRestore => "stream_restore",
            EventKind::Snapshot => "snapshot",
            EventKind::ShardPanic => "shard_panic",
            EventKind::ShardRespawn => "shard_respawn",
            EventKind::StreamRehomed => "stream_rehomed",
            EventKind::StreamLost => "stream_lost",
            EventKind::StoreDegraded => "store_degraded",
            EventKind::ConnReaped => "conn_reaped",
            EventKind::ConnRejected => "conn_rejected",
            EventKind::AuthFailure => "auth_failure",
            EventKind::SockOptFailed => "sockopt_failed",
            EventKind::WriteOverflow => "write_overflow",
        }
    }
}

/// One journal entry: fixed-size, `Copy`, no owned data — pushing one
/// can never allocate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Monotonic sequence number (gaps reveal dropped-oldest events).
    pub seq: u64,
    /// Microseconds since journal boot.
    pub t_us: u64,
    /// What happened.
    pub kind: EventKind,
    /// Stream id, or 0 when not stream-scoped.
    pub stream: u64,
    /// Shard id, or -1 for front-door / net-layer events.
    pub shard: i64,
    /// Kind-specific payload (see [`EventKind`] docs).
    pub aux: u64,
}

/// Rolling one-second admission window for one event kind.
#[derive(Debug, Clone, Copy, Default)]
struct RateGate {
    window_start_us: u64,
    in_window: u32,
}

#[derive(Debug)]
struct Inner {
    /// Ring storage; grows (without reallocating past `with_capacity`)
    /// until full, then overwrites at `head`.
    ring: Vec<Event>,
    /// Oldest element once the ring is full; 0 while still filling.
    head: usize,
    next_seq: u64,
    recorded: u64,
    dropped_oldest: u64,
    suppressed: [u64; 23],
    gates: [RateGate; 23],
    max_per_sec: u32,
}

/// Bounded, lock-cheap, alloc-free-on-push event journal.
#[derive(Debug)]
pub struct Journal {
    boot: Instant,
    inner: Mutex<Inner>,
}

/// Aggregate journal health counters (cheap snapshot, no drain).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Events accepted into the ring since boot.
    pub recorded: u64,
    /// Events overwritten by newer ones before being drained.
    pub dropped_oldest: u64,
    /// Events refused by per-kind rate gates.
    pub suppressed: u64,
    /// Events currently resident in the ring.
    pub len: u64,
}

impl Default for Journal {
    fn default() -> Self {
        Self::new()
    }
}

impl Journal {
    /// Default sizing: 1024-event ring, 256 events/sec per kind.
    pub fn new() -> Self {
        Self::with_limits(1024, 256)
    }

    /// Journal with an explicit ring capacity and per-kind rate limit.
    pub fn with_limits(capacity: usize, max_per_sec: u32) -> Self {
        Self {
            boot: Instant::now(),
            inner: Mutex::new(Inner {
                ring: Vec::with_capacity(capacity.max(1)),
                head: 0,
                next_seq: 0,
                recorded: 0,
                dropped_oldest: 0,
                suppressed: [0; 23],
                gates: [RateGate::default(); 23],
                max_per_sec: max_per_sec.max(1),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Record one event. Never blocks on a full ring (the oldest event
    /// is overwritten) and never allocates; over-rate events for a
    /// kind are counted as suppressed and dropped.
    pub fn push(&self, kind: EventKind, stream: u64, shard: i64, aux: u64) {
        let t_us = self.boot.elapsed().as_micros() as u64;
        let mut g = self.lock();
        let max = g.max_per_sec;
        let gate = &mut g.gates[kind as usize];
        if t_us.saturating_sub(gate.window_start_us) >= 1_000_000 {
            gate.window_start_us = t_us;
            gate.in_window = 0;
        }
        if gate.in_window >= max {
            g.suppressed[kind as usize] += 1;
            return;
        }
        gate.in_window += 1;
        let seq = g.next_seq;
        g.next_seq += 1;
        g.recorded += 1;
        let ev = Event { seq, t_us, kind, stream, shard, aux };
        if g.ring.len() < g.ring.capacity() {
            g.ring.push(ev); // within reserved capacity: no realloc
        } else {
            let head = g.head;
            g.ring[head] = ev;
            g.head = (head + 1) % g.ring.capacity();
            g.dropped_oldest += 1;
        }
    }

    /// Drain every resident event, oldest first, and empty the ring
    /// (capacity retained). Cold path: allocates the returned Vec.
    pub fn drain(&self) -> Vec<Event> {
        let mut g = self.lock();
        let n = g.ring.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(g.ring[(g.head + i) % n]);
        }
        g.ring.clear();
        g.head = 0;
        out
    }

    /// Aggregate health counters without draining.
    pub fn stats(&self) -> JournalStats {
        let g = self.lock();
        JournalStats {
            recorded: g.recorded,
            dropped_oldest: g.dropped_oldest,
            suppressed: g.suppressed.iter().sum(),
            len: g.ring.len() as u64,
        }
    }

    /// Events currently resident in the ring.
    pub fn len(&self) -> usize {
        self.lock().ring.len()
    }

    /// True when no events are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity (events resident at most).
    pub fn capacity(&self) -> usize {
        self.lock().ring.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_indices_match_all_order() {
        for (i, k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(*k as usize, i, "EventKind::ALL out of declaration order at {i}");
        }
    }

    #[test]
    fn push_and_drain_ordered() {
        let j = Journal::with_limits(16, 1_000_000);
        j.push(EventKind::StreamOpen, 1, 0, 0);
        j.push(EventKind::StreamClose, 1, 0, 0);
        let evs = j.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::StreamOpen);
        assert_eq!(evs[1].kind, EventKind::StreamClose);
        assert!(evs[0].seq < evs[1].seq);
        assert!(j.is_empty());
        // capacity survives the drain
        assert_eq!(j.capacity(), 16);
    }

    #[test]
    fn overflow_drops_oldest() {
        let j = Journal::with_limits(8, 1_000_000);
        for i in 0..100u64 {
            j.push(EventKind::SlowTick, i, 0, 0);
        }
        let stats = j.stats();
        assert_eq!(stats.recorded, 100);
        assert_eq!(stats.dropped_oldest, 92);
        assert_eq!(stats.len, 8);
        let evs = j.drain();
        assert_eq!(evs.len(), 8);
        // the survivors are exactly the newest 8, oldest first
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.seq, 92 + i as u64);
            assert_eq!(ev.stream, 92 + i as u64);
        }
    }

    #[test]
    fn rate_gate_suppresses_storms() {
        let j = Journal::with_limits(1024, 5);
        for _ in 0..100 {
            j.push(EventKind::ProtoError, 0, -1, 0);
        }
        let stats = j.stats();
        // a 1s window can roll over mid-loop at most once in practice,
        // so assert the gate bit without pinning the exact split
        assert!(stats.suppressed > 0, "no suppression under a 20x-over-rate storm");
        assert!(stats.recorded < 100);
        assert_eq!(stats.recorded + stats.suppressed, 100);
        // other kinds are unaffected by this kind's gate
        j.push(EventKind::StreamOpen, 9, 0, 0);
        assert!(j.drain().iter().any(|e| e.kind == EventKind::StreamOpen));
    }
}
