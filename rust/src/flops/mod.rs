//! Analytic FLOPs accounting, reproducing the paper's efficiency columns.
//!
//! Conventions (matching the Continual-Transformer / Continual-Nystrom
//! papers the tables cite, verified against Table I's published numbers:
//! 16.92M for the 2-layer full encoder at n=64, d=1024): one
//! multiply-accumulate = one FLOP; activation entries ~2 ops each.
//! Tables I and II count **attention-block** operations only ("FLOPs
//! refer to the number of operations corresponding to the attention
//! blocks"); Table III counts the whole model. Both accountings are
//! exposed via [`FlopsMode`].
//!
//! All counts are *per stream tick* (one new token arriving, m tokens
//! for m-output variants), the paper's continual-inference unit.

use crate::manifest::ModelConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlopsMode {
    /// Attention blocks only (Tables I, II, IV).
    AttentionOnly,
    /// Attention + projections + FFN + norms (Table III).
    FullModel,
}

/// Per-tick FLOPs of the *attention product* of one full window layer:
/// scores QK^T (n²·d MACs) + apply PV (n²·d) + softmax (~2·n²·h).
fn window_attention_flops(n: u64, d: u64, h: u64) -> u64 {
    n * n * d + n * n * d + 2 * n * n * h
}

/// Single-Output attention for m new tokens against an n-row memory:
/// scores (m·n·d) + apply (m·n·d) + memory roll/update (m·n·d, counted
/// by the Continual-Transformers accounting) + activation (~2·m·n·h).
/// Reproduces Table I's 0.40M for 2 layers at n=64, d=1024.
fn single_output_attention_flops(n: u64, m: u64, d: u64, h: u64) -> u64 {
    3 * m * n * d + 2 * m * n * h
}

/// QKV + output projections for t tokens: 4 matmuls (t x d x d).
fn projection_flops(t: u64, d: u64) -> u64 {
    4 * t * d * d
}

/// FFN for t tokens: two matmuls (d x f) + activation.
fn ffn_flops(t: u64, d: u64, f: u64) -> u64 {
    2 * t * d * f + 8 * t * f
}

/// LayerNorm / ReZero per t tokens (cheap; counted in full-model mode).
fn norm_flops(t: u64, d: u64) -> u64 {
    2 * 5 * t * d
}

/// Nystrom attention with L landmarks over an n window (full recompute):
/// F (2·n·L·d), A (2·L²·d), B (2·L·n·d), pinv (6 iters x ~3 L³·h mults),
/// apply (2·n·L·d + 2·L·n·d), softmaxes.
fn nystrom_attention_flops(n: u64, d: u64, h: u64, l: u64) -> u64 {
    let pinv = 6 * 3 * l * l * l * h;
    n * l * d + l * l * d + l * n * d + pinv + n * l * d + l * n * d
        + 2 * h * (n * l + l * l + l * n)
}

/// FNet mixing per layer per tick, using the paper's O(n log n + n d log d)
/// FFT op count (the TPU lowering uses DFT matmuls, but the paper's
/// asymptotic comparison is what the tables report — DESIGN.md §4).
fn fnet_mixing_flops(n: u64, d: u64) -> u64 {
    let log_n = 64 - (n.max(2) - 1).leading_zeros() as u64;
    let log_d = 64 - (d.max(2) - 1).leading_zeros() as u64;
    // complex butterfly ~ 5 MACs per point per stage, both dims
    5 * n * d * log_n + 5 * n * d * log_d
}

/// Per-tick FLOPs for a model family at a given geometry.
pub fn per_tick(family: &str, cfg: &ModelConfig, mode: FlopsMode) -> u64 {
    let n = cfg.window as u64;
    let m = cfg.m_tokens as u64;
    let d = cfg.d_model as u64;
    let h = cfg.n_heads as u64;
    let l = cfg.n_layers as u64;
    let f = cfg.d_ffn() as u64;
    let lm = cfg.n_landmarks.max(1) as u64;
    let b = cfg.batch as u64;

    let attn: u64 = match family {
        // the paper's model: every layer is Single-Output
        "deepcot" | "xl" => l * single_output_attention_flops(n, m, d, h),
        // regular encoder: full window attention every tick, every layer
        "encoder" | "xl_full" => l * window_attention_flops(n, d, h),
        // Hedegaard: retroactive layer-0 (the continual accounting:
        // one new score row + n retroactive output updates ~ O(n·d))
        // then full window layers in between, Single-Output last.
        "cotransformer" => {
            // retroactive refresh: one new score row (n·d), n output
            // updates (n·d), rolling updates (2·n·d), activations
            let retro = 4 * n * d + 4 * n * h;
            let middle = l.saturating_sub(2) * window_attention_flops(n, d, h);
            retro + middle + single_output_attention_flops(n, 1, d, h)
        }
        "nystrom" => l * nystrom_attention_flops(n, d, h, lm),
        // Continual Nystromformer: fixed/delayed landmarks remove the
        // pinv and most of the B recompute per tick.
        "conystrom" => {
            l * (n * lm * d / (n / lm).max(1) + lm * d + n * lm * d / 4
                + 2 * h * (lm + n))
        }
        "fnet" => l * fnet_mixing_flops(n, d),
        other => panic!("unknown family {other}"),
    };
    let per_lane = match mode {
        FlopsMode::AttentionOnly => attn,
        FlopsMode::FullModel => {
            // tokens entering projections/FFN per tick: m for continual
            // families, the whole window for recompute families
            let t = match family {
                "deepcot" | "xl" => m,
                "cotransformer" => 1 + n, // newest proj + last-layer reproj
                _ => n,
            };
            let proj = if family == "fnet" { 0 } else { projection_flops(t, d) };
            attn + l * (proj + ffn_flops(t, d, f) + 2 * norm_flops(t, d))
                + 2 * t * cfg.d_in as u64 * d // input embed
                + 2 * cfg.n_classes as u64 * d // readout
        }
    };
    b * per_lane
}

/// Pretty-print with the unit the paper's table uses.
pub fn format_flops(f: u64) -> String {
    if f >= 1_000_000_000 {
        format!("{:.3} G", f as f64 / 1e9)
    } else if f >= 1_000_000 {
        format!("{:.2} M", f as f64 / 1e6)
    } else {
        format!("{:.1} K", f as f64 / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize, d: usize, h: usize, l: usize, m: usize) -> ModelConfig {
        ModelConfig {
            d_in: d,
            d_model: d,
            n_heads: h,
            n_layers: l,
            window: n,
            m_tokens: m,
            ffn_mult: 4,
            n_classes: 10,
            batch: 1,
            activation: "softmax".into(),
            norm: "layernorm".into(),
            ffn_act: "gelu".into(),
            pos: "rope".into(),
            n_landmarks: 16,
            use_pallas: true,
        }
    }

    #[test]
    fn deepcot_linear_in_window() {
        let base = per_tick("deepcot", &cfg(64, 128, 8, 2, 1), FlopsMode::AttentionOnly);
        let twice = per_tick("deepcot", &cfg(128, 128, 8, 2, 1), FlopsMode::AttentionOnly);
        let ratio = twice as f64 / base as f64;
        assert!((ratio - 2.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn encoder_quadratic_in_window() {
        let base = per_tick("encoder", &cfg(64, 128, 8, 2, 1), FlopsMode::AttentionOnly);
        let twice = per_tick("encoder", &cfg(128, 128, 8, 2, 1), FlopsMode::AttentionOnly);
        let ratio = twice as f64 / base as f64;
        assert!((ratio - 4.0).abs() < 0.1, "ratio {ratio}");
    }

    /// Table I at the paper's own geometry (n=64, d=1024, 2 layers,
    /// 16 landmarks): our accounting should land on the paper's numbers
    /// — encoder 16.92M, Nystromformer 9.42M, DeepCoT 0.40M.
    #[test]
    fn table1_matches_paper_numbers() {
        let c = cfg(64, 1024, 8, 2, 1);
        let enc = per_tick("encoder", &c, FlopsMode::AttentionOnly) as f64;
        let cot = per_tick("cotransformer", &c, FlopsMode::AttentionOnly) as f64;
        let dc = per_tick("deepcot", &c, FlopsMode::AttentionOnly) as f64;
        let nys = per_tick("nystrom", &c, FlopsMode::AttentionOnly) as f64;
        assert!(dc < cot && cot < enc, "dc {dc} cot {cot} enc {enc}");
        // paper: 16.92M full attention
        assert!((enc / 16.92e6 - 1.0).abs() < 0.05, "enc {enc}");
        // paper: 9.42M Nystromformer (ours counts the pinv slightly differently)
        assert!((nys / 9.42e6 - 1.0).abs() < 0.15, "nys {nys}");
        // paper: 0.40M DeepCoT -> ratio enc/dc = 42x
        assert!((enc / dc - 42.0).abs() < 8.0, "enc/dc = {}", enc / dc);
    }

    #[test]
    fn full_model_exceeds_attention_only() {
        let c = cfg(64, 128, 8, 2, 1);
        for fam in ["deepcot", "encoder", "cotransformer", "nystrom", "fnet"] {
            assert!(
                per_tick(fam, &c, FlopsMode::FullModel)
                    > per_tick(fam, &c, FlopsMode::AttentionOnly),
                "{fam}"
            );
        }
    }

    #[test]
    fn m_tokens_scale_deepcot() {
        let one = per_tick("deepcot", &cfg(60, 256, 8, 10, 1), FlopsMode::AttentionOnly);
        let twelve = per_tick("deepcot", &cfg(60, 256, 8, 10, 12), FlopsMode::AttentionOnly);
        assert!(twelve > 10 * one && twelve < 14 * one);
    }

    #[test]
    fn format_units() {
        assert_eq!(format_flops(1_500), "1.5 K");
        assert_eq!(format_flops(2_500_000), "2.50 M");
        assert_eq!(format_flops(41_000_000_000), "41.000 G");
    }
}
