//! Typed view of `artifacts/manifest.json` — the contract emitted by
//! `python/compile/aot.py`. Field order of `params` and `inputs` is the
//! exact argument order of the AOT executables.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Mirror of `python/compile/config.py::ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub d_in: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub window: usize,
    pub m_tokens: usize,
    pub ffn_mult: usize,
    pub n_classes: usize,
    pub batch: usize,
    pub activation: String,
    pub norm: String,
    pub ffn_act: String,
    pub pos: String,
    pub n_landmarks: usize,
    pub use_pallas: bool,
}

impl ModelConfig {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            d_in: v.req("d_in")?.as_usize()?,
            d_model: v.req("d_model")?.as_usize()?,
            n_heads: v.req("n_heads")?.as_usize()?,
            n_layers: v.req("n_layers")?.as_usize()?,
            window: v.req("window")?.as_usize()?,
            m_tokens: v.req("m_tokens")?.as_usize()?,
            ffn_mult: v.req("ffn_mult")?.as_usize()?,
            n_classes: v.req("n_classes")?.as_usize()?,
            batch: v.req("batch")?.as_usize()?,
            activation: v.req("activation")?.as_str()?.to_string(),
            norm: v.req("norm")?.as_str()?.to_string(),
            ffn_act: v.req("ffn_act")?.as_str()?.to_string(),
            pos: v.req("pos")?.as_str()?.to_string(),
            n_landmarks: v.req("n_landmarks")?.as_usize()?,
            use_pallas: v.req("use_pallas")?.as_bool()?,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn d_ffn(&self) -> usize {
        self.ffn_mult * self.d_model
    }

    /// Rows kept in each layer's K/V memory (`n - m`).
    pub fn mem_len(&self) -> usize {
        self.window - self.m_tokens
    }

    /// Synthetic geometry for hermetic tests and scalar benchmarks:
    /// softmax / layernorm / gelu / rope, `d_in = d_model / 2`,
    /// `ffn_mult = 2`, 10 classes, single token per tick, batch 1.
    /// Callers override individual fields for other regimes.
    pub fn synthetic(d_model: usize, n_heads: usize, n_layers: usize, window: usize) -> Self {
        Self {
            d_in: d_model / 2,
            d_model,
            n_heads,
            n_layers,
            window,
            m_tokens: 1,
            ffn_mult: 2,
            n_classes: 10,
            batch: 1,
            activation: "softmax".to_string(),
            norm: "layernorm".to_string(),
            ffn_act: "gelu".to_string(),
            pos: "rope".to_string(),
            n_landmarks: 0,
            use_pallas: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub family: String,
    pub config: ModelConfig,
    pub hlo: String,
    pub weights: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Output index -> input index feedback wiring for continual state.
    pub state: BTreeMap<usize, usize>,
    pub params: Vec<ParamSpec>,
    pub golden: Option<String>,
}

impl VariantEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let mut state = BTreeMap::new();
        for (k, idx) in v.req("state")?.as_obj()? {
            state.insert(
                k.parse::<usize>().context("state output index")?,
                idx.as_usize()?,
            );
        }
        let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        let params = v
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p.req("shape")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            family: v.req("family")?.as_str()?.to_string(),
            config: ModelConfig::from_json(v.req("config")?)?,
            hlo: v.req("hlo")?.as_str()?.to_string(),
            weights: v.req("weights")?.as_str()?.to_string(),
            inputs: parse_specs("inputs")?,
            outputs: parse_specs("outputs")?,
            state,
            params,
            golden: v.get("golden").and_then(|g| g.as_str().ok().map(String::from)),
        })
    }

    /// (output index, input index) feedback pairs, sorted by output.
    pub fn state_wiring(&self) -> Vec<(usize, usize)> {
        self.state.iter().map(|(&o, &i)| (o, i)).collect()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }

    /// True for continual-step families (state feedback present).
    pub fn is_step(&self) -> bool {
        !self.state.is_empty()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub seed: u64,
    pub variants: BTreeMap<String, VariantEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<(Self, PathBuf)> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let m = Self::parse(&text).context("parsing manifest.json")?;
        Ok((m, artifacts_dir.to_path_buf()))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut variants = BTreeMap::new();
        for (name, entry) in v.req("variants")?.as_obj()? {
            variants.insert(
                name.clone(),
                VariantEntry::from_json(entry)
                    .with_context(|| format!("variant {name}"))?,
            );
        }
        Ok(Self { seed: v.req("seed")?.as_i64()? as u64, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantEntry> {
        match self.variants.get(name) {
            Some(v) => Ok(v),
            None => bail!(
                "variant {name:?} not in manifest (have: {:?} ...)",
                self.variants.keys().take(8).collect::<Vec<_>>()
            ),
        }
    }

    /// All variant names with a given prefix (experiment groups).
    pub fn with_prefix(&self, prefix: &str) -> Vec<String> {
        self.variants
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "seed": 0,
      "variants": {
        "x": {
          "family": "deepcot",
          "config": {"d_in":8,"d_model":16,"n_heads":2,"n_layers":2,
            "window":6,"m_tokens":1,"ffn_mult":4,"n_classes":3,"batch":2,
            "activation":"softmax","norm":"layernorm","ffn_act":"gelu",
            "pos":"rope","n_landmarks":0,"use_pallas":true},
          "hlo": "hlo/x.hlo.txt",
          "weights": "weights/k.bin",
          "inputs": [
            {"name":"tokens","shape":[2,1,8],"dtype":"f32"},
            {"name":"pos","shape":[],"dtype":"i32"},
            {"name":"kmem","shape":[2,2,2,5,8],"dtype":"f32"},
            {"name":"vmem","shape":[2,2,2,5,8],"dtype":"f32"}],
          "outputs": [
            {"name":"logits","shape":[2,3],"dtype":"f32"},
            {"name":"out","shape":[2,1,16],"dtype":"f32"},
            {"name":"kmem_next","shape":[2,2,2,5,8],"dtype":"f32"},
            {"name":"vmem_next","shape":[2,2,2,5,8],"dtype":"f32"}],
          "state": {"2": 2, "3": 3},
          "params": [{"name":"w_in","shape":[8,16]},{"name":"b_in","shape":[16]}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.variant("x").unwrap();
        assert_eq!(e.state_wiring(), vec![(2, 2), (3, 3)]);
        assert!(e.is_step());
        assert_eq!(e.config.mem_len(), 5);
        assert_eq!(e.config.d_head(), 8);
        assert_eq!(e.inputs[2].elems(), 2 * 2 * 2 * 5 * 8);
        assert_eq!(e.total_param_elems(), 8 * 16 + 16);
        assert_eq!(e.inputs[1].elems(), 1);
        assert!(e.golden.is_none());
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.variant("nope").is_err());
        assert_eq!(m.with_prefix("x"), vec!["x".to_string()]);
    }
}
