//! Hermetic synthetic serve artifacts: writes an artifacts directory
//! (manifest.json + a little-endian weights blob) for a tiny DeepCoT
//! geometry, so the full serving stack — manifest loading, weight
//! parsing, the scalar slot backend, the shard cluster — runs without
//! `make artifacts`, JAX, or the XLA shared library.
//!
//! Shared by the engine/cluster integration tests and the
//! `bench_throughput` binary; the single source of truth for the
//! synthetic weight-blob byte layout (it must stay in `param_specs`
//! order, which is also the manifest's `params` array order).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::manifest::ModelConfig;
use crate::nn::params::{ModelParams, Norm};
use crate::util::rng::Rng;

/// Geometry + seed of a synthetic serve artifacts directory. One
/// `serve_deepcot_b{N}` continual-step variant is emitted per entry of
/// `batches`, all sharing a single weights blob.
#[derive(Debug, Clone)]
pub struct SyntheticServeSpec {
    pub d_in: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub window: usize,
    pub n_classes: usize,
    pub seed: u64,
    pub batches: Vec<usize>,
}

impl Default for SyntheticServeSpec {
    /// The integration-test geometry: small enough that a scalar tick
    /// is ~µs, batched variants at B=1 and B=4.
    fn default() -> Self {
        Self {
            d_in: 8,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            window: 6,
            n_classes: 4,
            seed: 0xD44C07,
            batches: vec![1, 4],
        }
    }
}

impl SyntheticServeSpec {
    /// The `ModelConfig` a manifest entry of this spec carries.
    pub fn model_config(&self, batch: usize) -> ModelConfig {
        let mut c = ModelConfig::synthetic(self.d_model, self.n_heads, self.n_layers, self.window);
        c.d_in = self.d_in;
        c.n_classes = self.n_classes;
        c.batch = batch;
        c
    }

    pub fn variant_name(batch: usize) -> String {
        format!("serve_deepcot_b{batch}")
    }

    /// Deterministic per-spec directory under the system temp dir: the
    /// same spec always maps to the same path (and identical contents),
    /// so concurrent test binaries can share it safely.
    pub fn default_dir(&self) -> PathBuf {
        let batches: Vec<String> = self.batches.iter().map(|b| b.to_string()).collect();
        std::env::temp_dir().join(format!(
            "deepcot-synth-d{}l{}h{}w{}c{}in{}-s{:x}-b{}",
            self.d_model,
            self.n_layers,
            self.n_heads,
            self.window,
            self.n_classes,
            self.d_in,
            self.seed,
            batches.join("_")
        ))
    }

    /// Write the artifacts into [`Self::default_dir`] and return it.
    pub fn write(&self) -> Result<PathBuf> {
        let dir = self.default_dir();
        self.write_to(&dir)?;
        Ok(dir)
    }

    /// Write manifest.json + weights/tiny.bin into `dir`. Contents are
    /// deterministic in the spec, and every file lands via
    /// tmp-then-rename, so a concurrently running process never
    /// observes a truncated file (and re-writes are idempotent).
    pub fn write_to(&self, dir: &Path) -> Result<()> {
        anyhow::ensure!(self.d_model % self.n_heads == 0, "d_model must split across heads");
        anyhow::ensure!(self.window >= 2, "window must cover memory + the new token");
        anyhow::ensure!(!self.batches.is_empty(), "need at least one batch variant");
        std::fs::create_dir_all(dir.join("weights"))
            .with_context(|| format!("creating {}", dir.display()))?;
        let write_atomic = |rel: &str, bytes: &[u8]| -> Result<()> {
            let tmp = dir.join(format!("{}.tmp.{}", rel.replace('/', "_"), std::process::id()));
            std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
            std::fs::rename(&tmp, dir.join(rel))
                .with_context(|| format!("publishing {rel} in {}", dir.display()))?;
            Ok(())
        };
        write_atomic("weights/tiny.bin", &self.weights_blob())?;
        let variants: Vec<String> = self
            .batches
            .iter()
            .map(|&b| format!("\"{}\":{}", Self::variant_name(b), self.variant_json(b)))
            .collect();
        let manifest = format!("{{\"seed\":0,\"variants\":{{{}}}}}", variants.join(","));
        write_atomic("manifest.json", manifest.as_bytes())
    }

    /// Parameter spec in blob order — the single source of truth for
    /// both the manifest's `params` array and the weights byte layout.
    fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let d = self.d_model;
        let d_ffn = self.model_config(1).d_ffn();
        let mut v =
            vec![("w_in".to_string(), vec![self.d_in, d]), ("b_in".to_string(), vec![d])];
        for i in 0..self.n_layers {
            for nm in ["q", "k", "v", "o"] {
                v.push((format!("l{i}.w{nm}"), vec![d, d]));
                v.push((format!("l{i}.b{nm}"), vec![d]));
            }
            v.push((format!("l{i}.w1"), vec![d, d_ffn]));
            v.push((format!("l{i}.b1"), vec![d_ffn]));
            v.push((format!("l{i}.w2"), vec![d_ffn, d]));
            v.push((format!("l{i}.b2"), vec![d]));
            for nm in ["g1", "be1", "g2", "be2"] {
                v.push((format!("l{i}.{nm}"), vec![d]));
            }
        }
        v.push(("w_cls".to_string(), vec![d, self.n_classes]));
        v.push(("b_cls".to_string(), vec![self.n_classes]));
        v
    }

    /// Serialize a `ModelParams::synthetic` (the single weight-init
    /// policy) into the little-endian blob, in exactly `param_specs`
    /// order.
    fn weights_blob(&self) -> Vec<u8> {
        let p = ModelParams::synthetic(&self.model_config(1), &mut Rng::new(self.seed));
        let mut parts: Vec<&Vec<f32>> = vec![&p.w_in.data, &p.b_in];
        for lp in &p.layers {
            parts.extend([
                &lp.wq.data, &lp.bq, &lp.wk.data, &lp.bk, &lp.wv.data, &lp.bv, &lp.wo.data,
                &lp.bo, &lp.w1.data, &lp.b1, &lp.w2.data, &lp.b2,
            ]);
            match &lp.norm {
                Norm::LayerNorm { g1, be1, g2, be2 } => parts.extend([g1, be1, g2, be2]),
                Norm::ReZero { .. } => unreachable!("synthetic spec is layernorm"),
            }
        }
        parts.push(&p.w_cls.data);
        parts.push(&p.b_cls);
        let mut bytes = Vec::new();
        for slice in parts {
            for v in slice {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        bytes
    }

    fn variant_json(&self, batch: usize) -> String {
        let shape_json = |shape: &[usize]| -> String {
            let inner: Vec<String> = shape.iter().map(|s| s.to_string()).collect();
            format!("[{}]", inner.join(","))
        };
        let params: Vec<String> = self
            .param_specs()
            .iter()
            .map(|(n, s)| format!("{{\"name\":\"{n}\",\"shape\":{}}}", shape_json(s)))
            .collect();
        let mlen = self.window - 1;
        let mem_shape = shape_json(&[
            self.n_layers,
            batch,
            self.n_heads,
            mlen,
            self.d_model / self.n_heads,
        ]);
        format!(
            "{{\"family\":\"deepcot\",\
             \"config\":{{\"d_in\":{d_in},\"d_model\":{d_model},\"n_heads\":{n_heads},\
             \"n_layers\":{n_layers},\"window\":{window},\"m_tokens\":1,\"ffn_mult\":2,\
             \"n_classes\":{n_classes},\"batch\":{batch},\"activation\":\"softmax\",\
             \"norm\":\"layernorm\",\"ffn_act\":\"gelu\",\"pos\":\"rope\",\
             \"n_landmarks\":0,\"use_pallas\":false}},\
             \"hlo\":\"hlo/none.hlo.txt\",\
             \"weights\":\"weights/tiny.bin\",\
             \"inputs\":[\
               {{\"name\":\"tokens\",\"shape\":{tok},\"dtype\":\"f32\"}},\
               {{\"name\":\"pos\",\"shape\":[],\"dtype\":\"i32\"}},\
               {{\"name\":\"kmem\",\"shape\":{mem},\"dtype\":\"f32\"}},\
               {{\"name\":\"vmem\",\"shape\":{mem},\"dtype\":\"f32\"}}],\
             \"outputs\":[\
               {{\"name\":\"logits\",\"shape\":{log},\"dtype\":\"f32\"}},\
               {{\"name\":\"out\",\"shape\":{out},\"dtype\":\"f32\"}},\
               {{\"name\":\"kmem_next\",\"shape\":{mem},\"dtype\":\"f32\"}},\
               {{\"name\":\"vmem_next\",\"shape\":{mem},\"dtype\":\"f32\"}}],\
             \"state\":{{\"2\":2,\"3\":3}},\
             \"params\":[{params}]}}",
            d_in = self.d_in,
            d_model = self.d_model,
            n_heads = self.n_heads,
            n_layers = self.n_layers,
            window = self.window,
            n_classes = self.n_classes,
            tok = shape_json(&[batch, 1, self.d_in]),
            log = shape_json(&[batch, self.n_classes]),
            out = shape_json(&[batch, 1, self.d_model]),
            mem = mem_shape,
            params = params.join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn written_artifacts_load_and_typecheck() {
        let spec = SyntheticServeSpec {
            seed: 0x5EED1,
            batches: vec![1, 3],
            ..SyntheticServeSpec::default()
        };
        let dir = spec.write().unwrap();
        let (manifest, dir) = Manifest::load(&dir).unwrap();
        for &b in &spec.batches {
            let entry = manifest.variant(&SyntheticServeSpec::variant_name(b)).unwrap();
            assert!(entry.is_step());
            assert_eq!(entry.config.batch, b);
            assert_eq!(entry.config.d_in, spec.d_in);
            // the blob must parse into params of exactly the spec'd shapes
            let p = ModelParams::load(&dir, entry).unwrap();
            assert_eq!(p.layers.len(), spec.n_layers);
            assert_eq!(p.w_in.rows, spec.d_in);
            assert_eq!(p.w_cls.cols, spec.n_classes);
        }
        // rewrite is idempotent (same spec → same bytes, atomic swap)
        spec.write_to(&dir).unwrap();
    }
}
