//! Serving configuration: defaults + CLI wiring for the engine and the
//! bench/exp binaries.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use crate::fault::FaultPlan;
use crate::nn::simd::DispatchChoice;
use crate::obs::ObsLevel;
use crate::util::cli::{Args, Cli};

/// Which execution backend the engine thread drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineBackend {
    /// PJRT if it initializes, otherwise fall back to the batched
    /// scalar engine (same manifest + weights).
    #[default]
    Auto,
    /// Require the PJRT (XLA AOT) runtime.
    Pjrt,
    /// Require the pure-Rust batched scalar engine.
    Scalar,
}

impl std::str::FromStr for EngineBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "pjrt" => Ok(Self::Pjrt),
            "scalar" => Ok(Self::Scalar),
            other => anyhow::bail!("unknown backend {other:?} (want auto|pjrt|scalar)"),
        }
    }
}

/// How the cluster front door picks a shard for a new stream. Whatever
/// the policy, a full primary falls back to the remaining shards in
/// least-loaded order before the open is rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Deterministic hash of the stream id — stable placement with no
    /// shared state beyond the id.
    #[default]
    Hash,
    /// Pick the shard with the fewest front-door-tracked streams.
    LeastLoaded,
    /// Cycle shards in order.
    RoundRobin,
}

impl std::str::FromStr for PlacementPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "hash" => Ok(Self::Hash),
            "least-loaded" => Ok(Self::LeastLoaded),
            "round-robin" => Ok(Self::RoundRobin),
            other => {
                anyhow::bail!("unknown placement {other:?} (want hash|least-loaded|round-robin)")
            }
        }
    }
}

/// Engine (coordinator) configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Batched step variant to serve (e.g. "serve_deepcot_b4").
    pub variant: String,
    /// Execution backend (PJRT, scalar, or auto-fallback).
    pub backend: EngineBackend,
    /// Flush a partial batch after this long (tail-latency bound).
    pub batch_deadline: Duration,
    /// Per-stream pending-token bound (backpressure).
    pub max_queue_per_stream: usize,
    /// Idle eviction horizon.
    pub idle_timeout: Duration,
    /// Engine request channel depth (per shard).
    pub request_queue: usize,
    /// Worker shards, each owning its own backend + batcher (0 = one
    /// per available core). 1 reproduces the old single-thread engine.
    pub shards: usize,
    /// Stream → shard placement policy at the cluster front door.
    pub placement: PlacementPolicy,
    /// Per-shard slot capacity override (scalar backend only; 0 = the
    /// variant's compiled batch size).
    pub slots_per_shard: usize,
    /// Kernel path for the scalar backend's hot-tick kernels: `Auto`
    /// (env override via `DEEPCOT_KERNEL_DISPATCH`, else the best
    /// detected native SIMD path) or an explicit scalar/avx2/neon
    /// force. Dispatch is bitwise-invisible (see `nn::simd`); this
    /// knob exists so tests, CI, and benches can pin a path.
    pub kernel_dispatch: DispatchChoice,
    /// Observability level (`off|counters|spans|journal`): how much
    /// the serving stack records beyond the always-on base counters.
    /// Defaults from `DEEPCOT_OBS` (else `journal`); never changes
    /// results, only what gets measured.
    pub obs: ObsLevel,
    /// Journal a slow-tick event (and bump `slow_ticks`) when a tick's
    /// end-to-end pipeline time exceeds this.
    pub slow_tick: Duration,
    /// Hibernation: when slots run out, spill the least-recently-active
    /// stream to the state store instead of rejecting/evicting, so slot
    /// capacity bounds *active* streams, not registered ones. Implied by
    /// `state_dir`; on its own it uses an in-memory store (overcommit
    /// without durability).
    pub hibernate: bool,
    /// Session persistence directory. When set, stream state spills to
    /// (and recovers from) a log-structured file in this directory and
    /// hibernation is enabled; `None` = no durability.
    pub state_dir: Option<PathBuf>,
    /// Periodic full-cluster snapshot interval for `deepcot_serve`
    /// (crash-recovery checkpoint; `Duration::ZERO` = only snapshot on
    /// clean shutdown). Only meaningful with `state_dir`.
    pub snapshot_every: Duration,
    /// Deterministic fault-injection plan (chaos testing). Defaults
    /// from `DEEPCOT_FAULT` (else disabled). When disabled every
    /// injection site is a single branch — no counting, no allocation,
    /// no behavior change.
    pub fault: FaultPlan,
    /// TCP front door worker threads decoding frames and driving the
    /// engine (0 = auto: available cores clamped to 2..=8). Thread
    /// count stays O(workers) however many connections are open.
    pub net_workers: usize,
    /// Concurrent TCP connections admitted before the front door
    /// replies `Saturated` and drops the socket.
    pub net_max_conns: usize,
    /// Open streams allowed per TCP connection before OPEN replies
    /// `Saturated`.
    pub net_max_streams_per_conn: usize,
    /// Shared-secret OPEN token for the TCP front door (empty = no
    /// authentication). When set, a connection's requests are rejected
    /// until its first OPEN carries this token.
    pub net_auth_token: String,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::artifacts_dir(),
            variant: "serve_deepcot_b4".to_string(),
            backend: EngineBackend::Auto,
            batch_deadline: Duration::from_millis(2),
            max_queue_per_stream: 8,
            idle_timeout: Duration::from_secs(30),
            request_queue: 1024,
            shards: 1,
            placement: PlacementPolicy::Hash,
            slots_per_shard: 0,
            kernel_dispatch: DispatchChoice::Auto,
            obs: ObsLevel::default_from_env(),
            slow_tick: Duration::from_millis(100),
            hibernate: false,
            state_dir: None,
            snapshot_every: Duration::ZERO,
            fault: FaultPlan::default_from_env(),
            net_workers: 0,
            net_max_conns: 16_384,
            net_max_streams_per_conn: 1024,
            net_auth_token: String::new(),
        }
    }
}

/// Builder-style construction of an [`EngineConfig`]: start from the
/// defaults, override what the call site cares about, `build()`. The
/// idiomatic way for examples/benches/tests to configure an engine
/// without hand-rolling struct literals or CLI plumbing.
///
/// ```no_run
/// use deepcot::config::{EngineBackend, EngineConfig};
///
/// let cfg = EngineConfig::builder()
///     .variant("serve_deepcot_b4")
///     .backend(EngineBackend::Scalar)
///     .shards(2)
///     .build();
/// ```
#[derive(Debug, Clone, Default)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    /// Artifacts directory (manifest + weights).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Batched step variant to serve.
    pub fn variant(mut self, v: impl Into<String>) -> Self {
        self.cfg.variant = v.into();
        self
    }

    /// Execution backend (PJRT, scalar, or auto-fallback).
    pub fn backend(mut self, b: EngineBackend) -> Self {
        self.cfg.backend = b;
        self
    }

    /// Partial-batch flush deadline (tail-latency bound).
    pub fn batch_deadline(mut self, d: Duration) -> Self {
        self.cfg.batch_deadline = d;
        self
    }

    /// Per-stream pending-token bound (backpressure).
    pub fn max_queue_per_stream(mut self, n: usize) -> Self {
        self.cfg.max_queue_per_stream = n;
        self
    }

    /// Idle eviction horizon.
    pub fn idle_timeout(mut self, d: Duration) -> Self {
        self.cfg.idle_timeout = d;
        self
    }

    /// Engine request channel depth (per shard).
    pub fn request_queue(mut self, n: usize) -> Self {
        self.cfg.request_queue = n;
        self
    }

    /// Worker shard count (0 = one per available core).
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Stream → shard placement policy at the cluster front door.
    pub fn placement(mut self, p: PlacementPolicy) -> Self {
        self.cfg.placement = p;
        self
    }

    /// Per-shard slot capacity override (scalar backend only; 0 = the
    /// variant's compiled batch size).
    pub fn slots_per_shard(mut self, n: usize) -> Self {
        self.cfg.slots_per_shard = n;
        self
    }

    /// Kernel path for the scalar backend (auto / scalar / avx2 / neon).
    pub fn kernel_dispatch(mut self, d: DispatchChoice) -> Self {
        self.cfg.kernel_dispatch = d;
        self
    }

    /// Observability level (off / counters / spans / journal).
    pub fn obs(mut self, level: ObsLevel) -> Self {
        self.cfg.obs = level;
        self
    }

    /// Slow-tick journal threshold.
    pub fn slow_tick(mut self, d: Duration) -> Self {
        self.cfg.slow_tick = d;
        self
    }

    /// Enable hibernation (spill-don't-reject) with an in-memory store.
    pub fn hibernate(mut self, on: bool) -> Self {
        self.cfg.hibernate = on;
        self
    }

    /// Session persistence directory (enables hibernation + recovery).
    pub fn state_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.state_dir = Some(dir.into());
        self
    }

    /// Periodic snapshot interval for the serving loop.
    pub fn snapshot_every(mut self, d: Duration) -> Self {
        self.cfg.snapshot_every = d;
        self
    }

    /// Deterministic fault-injection plan (chaos testing).
    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.cfg.fault = plan;
        self
    }

    /// TCP front door worker threads (0 = auto).
    pub fn net_workers(mut self, n: usize) -> Self {
        self.cfg.net_workers = n;
        self
    }

    /// Concurrent TCP connection admission limit.
    pub fn net_max_conns(mut self, n: usize) -> Self {
        self.cfg.net_max_conns = n;
        self
    }

    /// Open-stream quota per TCP connection.
    pub fn net_max_streams_per_conn(mut self, n: usize) -> Self {
        self.cfg.net_max_streams_per_conn = n;
        self
    }

    /// Shared-secret OPEN token for the TCP front door (empty = none).
    pub fn net_auth_token(mut self, token: impl Into<String>) -> Self {
        self.cfg.net_auth_token = token.into();
        self
    }

    /// Finish the build.
    pub fn build(self) -> EngineConfig {
        self.cfg
    }
}

impl EngineConfig {
    /// Start a builder at the default configuration.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder::default()
    }

    /// Register the engine's options on a CLI.
    pub fn cli(cli: Cli) -> Cli {
        cli.opt("variant", "serve_deepcot_b4", "batched step variant to serve")
            .opt("artifacts", "", "artifacts dir (default: $DEEPCOT_ARTIFACTS or ./artifacts)")
            .opt("backend", "auto", "execution backend: auto|pjrt|scalar")
            .opt("deadline-us", "2000", "partial-batch flush deadline (µs)")
            .opt("max-queue", "8", "per-stream pending token bound")
            .opt("idle-timeout-ms", "30000", "idle stream eviction (ms)")
            .opt("shards", "1", "engine worker shards (0 = one per core)")
            .opt("placement", "hash", "stream placement: hash|least-loaded|round-robin")
            .opt("slots-per-shard", "0", "per-shard slot capacity (scalar; 0 = variant batch)")
            .opt("kernel-dispatch", "auto", "kernel path: auto|scalar|avx2|neon")
            .opt("obs", "auto", "observability: off|counters|spans|journal (auto = $DEEPCOT_OBS)")
            .opt("slow-tick-us", "100000", "journal a slow-tick event past this pipeline time (µs)")
            .flag("hibernate", "spill idle streams to an in-memory store instead of rejecting")
            .opt("state-dir", "", "session persistence dir (enables hibernation + crash recovery)")
            .opt("snapshot-every-ms", "0", "periodic full snapshot interval (ms; 0 = shutdown only)")
            .opt("fault", "auto", "fault-injection plan, e.g. seed=7,shard_step=@40 (auto = $DEEPCOT_FAULT)")
            .opt("net-workers", "0", "TCP front door worker threads (0 = auto, 2..=8 cores)")
            .opt("net-max-conns", "16384", "concurrent TCP connection admission limit")
            .opt("net-max-streams", "1024", "open-stream quota per TCP connection")
            .opt("net-auth-token", "", "shared-secret OPEN token for the TCP front door (empty = none)")
    }

    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = EngineConfig::default();
        if !args.get("artifacts").is_empty() {
            cfg.artifacts_dir = args.get("artifacts").into();
        }
        cfg.variant = args.get("variant").to_string();
        cfg.backend = args.get("backend").parse()?;
        cfg.batch_deadline = Duration::from_micros(args.get_u64("deadline-us")?);
        cfg.max_queue_per_stream = args.get_usize("max-queue")?;
        cfg.idle_timeout = Duration::from_millis(args.get_u64("idle-timeout-ms")?);
        cfg.shards = args.get_usize("shards")?;
        cfg.placement = args.get("placement").parse()?;
        cfg.slots_per_shard = args.get_usize("slots-per-shard")?;
        cfg.kernel_dispatch = args.get("kernel-dispatch").parse()?;
        if args.get("obs") != "auto" {
            cfg.obs = args.get("obs").parse()?;
        }
        cfg.slow_tick = Duration::from_micros(args.get_u64("slow-tick-us")?);
        cfg.hibernate = args.has("hibernate");
        if !args.get("state-dir").is_empty() {
            cfg.state_dir = Some(args.get("state-dir").into());
        }
        cfg.snapshot_every = Duration::from_millis(args.get_u64("snapshot-every-ms")?);
        if args.get("fault") != "auto" {
            cfg.fault = args.get("fault").parse().map_err(anyhow::Error::msg)?;
        }
        cfg.net_workers = args.get_usize("net-workers")?;
        cfg.net_max_conns = args.get_usize("net-max-conns")?;
        cfg.net_max_streams_per_conn = args.get_usize("net-max-streams")?;
        cfg.net_auth_token = args.get("net-auth-token").to_string();
        Ok(cfg)
    }

    /// Shard count with `0 = one per available core` resolved.
    pub fn effective_shards(&self) -> usize {
        if self.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.shards
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = EngineConfig::default();
        assert!(c.batch_deadline > Duration::ZERO);
        assert!(c.max_queue_per_stream >= 1);
    }

    #[test]
    fn from_args_overrides() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(
                ["--variant", "serve_deepcot_b1", "--deadline-us", "500", "--backend", "scalar"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.variant, "serve_deepcot_b1");
        assert_eq!(c.batch_deadline, Duration::from_micros(500));
        assert_eq!(c.backend, EngineBackend::Scalar);
        assert_eq!(c.kernel_dispatch, DispatchChoice::Auto);
    }

    #[test]
    fn kernel_dispatch_parses() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(["--kernel-dispatch", "scalar"].iter().map(|s| s.to_string()))
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.kernel_dispatch, DispatchChoice::Scalar);
        assert_eq!(EngineConfig::default().kernel_dispatch, DispatchChoice::Auto);
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(["--kernel-dispatch", "sse9"].iter().map(|s| s.to_string()))
            .unwrap();
        assert!(EngineConfig::from_args(&args).is_err(), "bad dispatch must fail to parse");
    }

    #[test]
    fn obs_options_parse() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(["--obs", "spans", "--slow-tick-us", "2500"].iter().map(|s| s.to_string()))
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.obs, ObsLevel::Spans);
        assert_eq!(c.slow_tick, Duration::from_micros(2500));
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli.parse_from(["--obs", "loud"].iter().map(|s| s.to_string())).unwrap();
        assert!(EngineConfig::from_args(&args).is_err(), "bad obs level must fail to parse");
        // builder knob + default threshold
        let b = EngineConfig::builder().obs(ObsLevel::Off).build();
        assert_eq!(b.obs, ObsLevel::Off);
        assert_eq!(b.slow_tick, Duration::from_millis(100));
    }

    #[test]
    fn cluster_options_parse() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(
                ["--shards", "4", "--placement", "round-robin", "--slots-per-shard", "2"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.effective_shards(), 4);
        assert_eq!(c.placement, PlacementPolicy::RoundRobin);
        assert_eq!(c.slots_per_shard, 2);
        // defaults reproduce the single-engine layout
        let d = EngineConfig::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.placement, PlacementPolicy::Hash);
        assert_eq!(d.slots_per_shard, 0);
        // 0 = auto: at least one shard, whatever the host
        let auto = EngineConfig { shards: 0, ..EngineConfig::default() };
        assert!(auto.effective_shards() >= 1);
    }

    #[test]
    fn builder_overrides_defaults() {
        let c = EngineConfig::builder()
            .variant("serve_deepcot_b1")
            .backend(EngineBackend::Scalar)
            .batch_deadline(Duration::from_micros(500))
            .shards(4)
            .placement(PlacementPolicy::LeastLoaded)
            .slots_per_shard(2)
            .idle_timeout(Duration::from_secs(5))
            .max_queue_per_stream(3)
            .request_queue(64)
            .artifacts_dir("/tmp/x")
            .kernel_dispatch(DispatchChoice::Scalar)
            .build();
        assert_eq!(c.variant, "serve_deepcot_b1");
        assert_eq!(c.backend, EngineBackend::Scalar);
        assert_eq!(c.batch_deadline, Duration::from_micros(500));
        assert_eq!(c.shards, 4);
        assert_eq!(c.placement, PlacementPolicy::LeastLoaded);
        assert_eq!(c.slots_per_shard, 2);
        assert_eq!(c.idle_timeout, Duration::from_secs(5));
        assert_eq!(c.max_queue_per_stream, 3);
        assert_eq!(c.request_queue, 64);
        assert_eq!(c.artifacts_dir, PathBuf::from("/tmp/x"));
        assert_eq!(c.kernel_dispatch, DispatchChoice::Scalar);
        // untouched fields keep their defaults
        let d = EngineConfig::default();
        assert_eq!(EngineConfig::builder().build().variant, d.variant);
    }

    #[test]
    fn persistence_options_parse() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(
                ["--state-dir", "/tmp/deepcot-state", "--snapshot-every-ms", "250", "--hibernate"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.state_dir, Some(PathBuf::from("/tmp/deepcot-state")));
        assert_eq!(c.snapshot_every, Duration::from_millis(250));
        assert!(c.hibernate);
        // defaults: no persistence, no hibernation
        let d = EngineConfig::default();
        assert_eq!(d.state_dir, None);
        assert_eq!(d.snapshot_every, Duration::ZERO);
        assert!(!d.hibernate);
        // builder knobs
        let b = EngineConfig::builder()
            .hibernate(true)
            .state_dir("/tmp/x")
            .snapshot_every(Duration::from_secs(1))
            .build();
        assert!(b.hibernate);
        assert_eq!(b.state_dir, Some(PathBuf::from("/tmp/x")));
        assert_eq!(b.snapshot_every, Duration::from_secs(1));
    }

    #[test]
    fn fault_option_parses() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(["--fault", "seed=7,shard=1,shard_step=@40"].iter().map(|s| s.to_string()))
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert!(c.fault.is_enabled());
        assert_eq!(c.fault.seed, 7);
        assert_eq!(c.fault.target_shard, 1);
        // "off" beats any DEEPCOT_FAULT the test environment could
        // carry — it parses to the disabled plan
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli.parse_from(["--fault", "off"].iter().map(|s| s.to_string())).unwrap();
        assert!(!EngineConfig::from_args(&args).unwrap().fault.is_enabled());
        // malformed specs are typed CLI errors, not panics
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli.parse_from(["--fault", "shard_step=0"].iter().map(|s| s.to_string())).unwrap();
        assert!(EngineConfig::from_args(&args).is_err());
        // builder knob
        let b = EngineConfig::builder()
            .fault("seed=3,store_put=5".parse().unwrap())
            .build();
        assert!(b.fault.is_enabled());
    }

    #[test]
    fn net_options_parse() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(
                [
                    "--net-workers",
                    "4",
                    "--net-max-conns",
                    "100",
                    "--net-max-streams",
                    "8",
                    "--net-auth-token",
                    "s3cret",
                ]
                .iter()
                .map(|s| s.to_string()),
            )
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.net_workers, 4);
        assert_eq!(c.net_max_conns, 100);
        assert_eq!(c.net_max_streams_per_conn, 8);
        assert_eq!(c.net_auth_token, "s3cret");
        // defaults: auto workers, generous limits, no auth
        let d = EngineConfig::default();
        assert_eq!(d.net_workers, 0);
        assert!(d.net_max_conns >= 1024);
        assert!(d.net_max_streams_per_conn >= 1);
        assert!(d.net_auth_token.is_empty());
        // builder knobs
        let b = EngineConfig::builder()
            .net_workers(2)
            .net_max_conns(10)
            .net_max_streams_per_conn(3)
            .net_auth_token("t")
            .build();
        assert_eq!(b.net_workers, 2);
        assert_eq!(b.net_max_conns, 10);
        assert_eq!(b.net_max_streams_per_conn, 3);
        assert_eq!(b.net_auth_token, "t");
    }

    #[test]
    fn placement_parses() {
        assert_eq!("hash".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::Hash);
        assert_eq!(
            "least-loaded".parse::<PlacementPolicy>().unwrap(),
            PlacementPolicy::LeastLoaded
        );
        assert_eq!(
            "round-robin".parse::<PlacementPolicy>().unwrap(),
            PlacementPolicy::RoundRobin
        );
        assert!("random".parse::<PlacementPolicy>().is_err());
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::Hash);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("auto".parse::<EngineBackend>().unwrap(), EngineBackend::Auto);
        assert_eq!("pjrt".parse::<EngineBackend>().unwrap(), EngineBackend::Pjrt);
        assert_eq!("scalar".parse::<EngineBackend>().unwrap(), EngineBackend::Scalar);
        assert!("gpu".parse::<EngineBackend>().is_err());
        assert_eq!(EngineBackend::default(), EngineBackend::Auto);
    }
}
