//! Serving configuration: defaults + CLI wiring for the engine and the
//! bench/exp binaries.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use crate::util::cli::{Args, Cli};

/// Which execution backend the engine thread drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineBackend {
    /// PJRT if it initializes, otherwise fall back to the batched
    /// scalar engine (same manifest + weights).
    #[default]
    Auto,
    /// Require the PJRT (XLA AOT) runtime.
    Pjrt,
    /// Require the pure-Rust batched scalar engine.
    Scalar,
}

impl std::str::FromStr for EngineBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "pjrt" => Ok(Self::Pjrt),
            "scalar" => Ok(Self::Scalar),
            other => anyhow::bail!("unknown backend {other:?} (want auto|pjrt|scalar)"),
        }
    }
}

/// Engine (coordinator) configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Batched step variant to serve (e.g. "serve_deepcot_b4").
    pub variant: String,
    /// Execution backend (PJRT, scalar, or auto-fallback).
    pub backend: EngineBackend,
    /// Flush a partial batch after this long (tail-latency bound).
    pub batch_deadline: Duration,
    /// Per-stream pending-token bound (backpressure).
    pub max_queue_per_stream: usize,
    /// Idle eviction horizon.
    pub idle_timeout: Duration,
    /// Engine request channel depth.
    pub request_queue: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::artifacts_dir(),
            variant: "serve_deepcot_b4".to_string(),
            backend: EngineBackend::Auto,
            batch_deadline: Duration::from_millis(2),
            max_queue_per_stream: 8,
            idle_timeout: Duration::from_secs(30),
            request_queue: 1024,
        }
    }
}

impl EngineConfig {
    /// Register the engine's options on a CLI.
    pub fn cli(cli: Cli) -> Cli {
        cli.opt("variant", "serve_deepcot_b4", "batched step variant to serve")
            .opt("artifacts", "", "artifacts dir (default: $DEEPCOT_ARTIFACTS or ./artifacts)")
            .opt("backend", "auto", "execution backend: auto|pjrt|scalar")
            .opt("deadline-us", "2000", "partial-batch flush deadline (µs)")
            .opt("max-queue", "8", "per-stream pending token bound")
            .opt("idle-timeout-ms", "30000", "idle stream eviction (ms)")
    }

    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = EngineConfig::default();
        if !args.get("artifacts").is_empty() {
            cfg.artifacts_dir = args.get("artifacts").into();
        }
        cfg.variant = args.get("variant").to_string();
        cfg.backend = args.get("backend").parse()?;
        cfg.batch_deadline = Duration::from_micros(args.get_u64("deadline-us")?);
        cfg.max_queue_per_stream = args.get_usize("max-queue")?;
        cfg.idle_timeout = Duration::from_millis(args.get_u64("idle-timeout-ms")?);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = EngineConfig::default();
        assert!(c.batch_deadline > Duration::ZERO);
        assert!(c.max_queue_per_stream >= 1);
    }

    #[test]
    fn from_args_overrides() {
        let cli = EngineConfig::cli(Cli::new("t"));
        let args = cli
            .parse_from(
                ["--variant", "serve_deepcot_b1", "--deadline-us", "500", "--backend", "scalar"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.variant, "serve_deepcot_b1");
        assert_eq!(c.batch_deadline, Duration::from_micros(500));
        assert_eq!(c.backend, EngineBackend::Scalar);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("auto".parse::<EngineBackend>().unwrap(), EngineBackend::Auto);
        assert_eq!("pjrt".parse::<EngineBackend>().unwrap(), EngineBackend::Pjrt);
        assert_eq!("scalar".parse::<EngineBackend>().unwrap(), EngineBackend::Scalar);
        assert!("gpu".parse::<EngineBackend>().is_err());
        assert_eq!(EngineBackend::default(), EngineBackend::Auto);
    }
}
