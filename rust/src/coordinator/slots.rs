//! Slot-based continual batching (DESIGN.md §3).
//!
//! DeepCoT state per stream is *fixed-size* O(n·d·l) — unlike growing
//! decoder KV caches — so streams bind to fixed slots of a batched
//! executable: batch dim = slot count, inactive slots run masked (their
//! lanes carry zero tokens; their outputs are dropped). This is the
//! encoder-side analogue of vLLM's paged batching, radically simplified
//! by the fixed state footprint.

use std::collections::BTreeMap;

/// Stable stream identifier handed to clients.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamId(pub u64);

/// Assignment of streams to batch lanes.
#[derive(Debug, Clone)]
pub struct SlotMap {
    capacity: usize,
    free: Vec<usize>,
    by_stream: BTreeMap<StreamId, usize>,
    by_slot: Vec<Option<StreamId>>,
}

impl SlotMap {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            free: (0..capacity).rev().collect(),
            by_stream: BTreeMap::new(),
            by_slot: vec![None; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn occupied(&self) -> usize {
        self.capacity - self.free.len()
    }

    pub fn is_full(&self) -> bool {
        self.free.is_empty()
    }

    /// Bind a stream to a free slot; None when full (admission reject /
    /// backpressure upstream).
    pub fn bind(&mut self, id: StreamId) -> Option<usize> {
        if self.by_stream.contains_key(&id) {
            return self.by_stream.get(&id).copied();
        }
        let slot = self.free.pop()?;
        self.by_stream.insert(id, slot);
        self.by_slot[slot] = Some(id);
        Some(slot)
    }

    /// Release a stream's slot; returns the freed slot index.
    pub fn release(&mut self, id: StreamId) -> Option<usize> {
        let slot = self.by_stream.remove(&id)?;
        self.by_slot[slot] = None;
        self.free.push(slot);
        Some(slot)
    }

    pub fn slot_of(&self, id: StreamId) -> Option<usize> {
        self.by_stream.get(&id).copied()
    }

    pub fn stream_at(&self, slot: usize) -> Option<StreamId> {
        self.by_slot.get(slot).copied().flatten()
    }

    pub fn streams(&self) -> impl Iterator<Item = (StreamId, usize)> + '_ {
        self.by_stream.iter().map(|(&id, &s)| (id, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn bind_release_roundtrip() {
        let mut m = SlotMap::new(2);
        let a = m.bind(StreamId(1)).unwrap();
        let b = m.bind(StreamId(2)).unwrap();
        assert_ne!(a, b);
        assert!(m.is_full());
        assert!(m.bind(StreamId(3)).is_none());
        assert_eq!(m.release(StreamId(1)), Some(a));
        assert_eq!(m.bind(StreamId(3)), Some(a));
    }

    #[test]
    fn bind_is_idempotent() {
        let mut m = SlotMap::new(2);
        let a = m.bind(StreamId(9)).unwrap();
        assert_eq!(m.bind(StreamId(9)), Some(a));
        assert_eq!(m.occupied(), 1);
    }

    #[test]
    fn release_unknown_is_none() {
        let mut m = SlotMap::new(1);
        assert!(m.release(StreamId(5)).is_none());
    }

    /// Property: under any operation sequence, (1) no two streams share
    /// a slot, (2) occupied + free == capacity, (3) by_slot and
    /// by_stream stay mutually consistent.
    #[test]
    fn prop_slotmap_invariants() {
        prop::check("slotmap-invariants", 200, |rng| {
            let cap = rng.range(1, 9);
            let mut m = SlotMap::new(cap);
            for step in 0..rng.range(1, 60) {
                let id = StreamId(rng.below(12) as u64);
                if rng.chance(0.55) {
                    m.bind(id);
                } else {
                    m.release(id);
                }
                // invariant checks
                let mut seen = std::collections::BTreeSet::new();
                for (id, slot) in m.streams() {
                    if slot >= cap {
                        return Err(format!("step {step}: slot {slot} >= cap {cap}"));
                    }
                    if !seen.insert(slot) {
                        return Err(format!("step {step}: slot {slot} double-booked"));
                    }
                    if m.stream_at(slot) != Some(id) {
                        return Err(format!("step {step}: by_slot/by_stream diverge"));
                    }
                }
                if m.occupied() + (cap - m.occupied()) != cap {
                    return Err("capacity accounting broke".into());
                }
                if m.occupied() != seen.len() {
                    return Err(format!(
                        "step {step}: occupied {} != distinct slots {}",
                        m.occupied(),
                        seen.len()
                    ));
                }
            }
            Ok(())
        });
    }
}
