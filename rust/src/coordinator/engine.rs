//! The serving engine: a dedicated thread owning the execution backend
//! (PJRT handles are `Rc`-based, so everything device-touching lives
//! here; the scalar fallback backend is plain host memory), fronted by
//! bounded std::sync::mpsc channels — the offline stand-in for a
//! tokio-based front-end, with identical backpressure semantics.
//!
//! Data flow per tick:
//!   clients → Push ─┐
//!                   ├→ Batcher (deadline / all-slots policy)
//!   Router (slots) ─┘        │
//!                            ▼
//!                     SlotStepper.tick (one batched PJRT execute)
//!                            │
//!        per-stream output channels ← scatter lanes + metrics

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{EngineBackend, EngineConfig};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::router::{Admission, Router};
use crate::coordinator::slot_stepper::SlotStepper;
use crate::coordinator::slots::StreamId;
use crate::manifest::Manifest;
use crate::nn::params::ModelParams;
use crate::runtime::Runtime;

/// One tick's result delivered to a stream's owner.
#[derive(Debug, Clone)]
pub struct TickResult {
    pub logits: Vec<f32>,
    pub out: Vec<f32>,
    pub tick: u64,
}

enum Request {
    Open { reply: Sender<Result<(StreamId, Receiver<TickResult>)>> },
    Push { id: StreamId, tokens: Vec<f32>, reply: Sender<Result<()>> },
    Close { id: StreamId },
    Metrics { reply: Sender<EngineMetrics> },
    Shutdown,
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: SyncSender<Request>,
}

pub struct EngineThread {
    handle: EngineHandle,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

impl EngineThread {
    /// Spawn the engine thread; blocks until the model is compiled and
    /// ready (so the first Push never pays compile latency).
    pub fn spawn(cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.request_queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("deepcot-engine".into())
            .spawn(move || engine_main(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Self { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("engine thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for EngineThread {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    /// Open a stream; returns its id and the output channel.
    pub fn open(&self) -> Result<(StreamId, Receiver<TickResult>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Open { reply })
            .map_err(|_| anyhow!("engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Submit the next token(s) for a stream (m*d_in f32s).
    pub fn push(&self, id: StreamId, tokens: Vec<f32>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Push { id, tokens, reply })
            .map_err(|_| anyhow!("engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    pub fn close(&self, id: StreamId) {
        let _ = self.tx.send(Request::Close { id });
    }

    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Metrics { reply })
            .map_err(|_| anyhow!("engine is gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))
    }
}

struct StreamPort {
    out: Sender<TickResult>,
    ticks: u64,
}

fn engine_main(
    cfg: EngineConfig,
    rx: Receiver<Request>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    // Backend selection: PJRT when the XLA runtime is available, the
    // pure-Rust batched scalar engine otherwise (or on request) — same
    // manifest, same weights, same lane semantics.
    let pjrt = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper)> {
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        let variant = rt.load(&cfg.variant)?;
        let stepper = SlotStepper::new(variant)?;
        Ok((Some(rt), stepper))
    };
    let scalar = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper)> {
        let (manifest, dir) = Manifest::load(&cfg.artifacts_dir)?;
        let entry = manifest.variant(&cfg.variant)?;
        let params = ModelParams::load(&dir, entry)?;
        Ok((None, SlotStepper::new_scalar(entry, params)?))
    };
    let init = match cfg.backend {
        EngineBackend::Pjrt => pjrt(&cfg),
        EngineBackend::Scalar => scalar(&cfg),
        EngineBackend::Auto => pjrt(&cfg).or_else(|pe| {
            scalar(&cfg)
                .map_err(|se| anyhow!("pjrt backend: {pe}; scalar fallback: {se}"))
        }),
    };
    let (_rt, mut stepper) = match init {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e}")));
            bail!("engine init failed");
        }
    };
    // auto-fallback silently changes the latency class — always say
    // which backend actually came up
    eprintln!(
        "deepcot engine: serving {} on the {} backend (B={})",
        cfg.variant,
        stepper.backend_name(),
        stepper.capacity()
    );
    let lane_elems = {
        let c = stepper.config();
        c.m_tokens * c.d_in
    };
    let mut router = Router::new(stepper.capacity(), cfg.idle_timeout);
    let mut batcher = Batcher::new(cfg.batch_deadline, cfg.max_queue_per_stream);
    let mut ports: std::collections::BTreeMap<StreamId, StreamPort> = Default::default();
    let mut metrics = EngineMetrics::new();

    loop {
        // 1. drain / wait for requests up to the batching deadline
        let wait = if batcher.pending_len() > 0 {
            cfg.batch_deadline / 4
        } else {
            Duration::from_millis(50)
        };
        match rx.recv_timeout(wait) {
            Ok(req) => {
                let now = Instant::now();
                match req {
                    Request::Open { reply } => {
                        let (id, adm) = router.open(now);
                        let res = match adm {
                            Admission::Accepted(slot) => {
                                stepper.clear_lane(slot);
                                let (out_tx, out_rx) = mpsc::channel();
                                ports.insert(id, StreamPort { out: out_tx, ticks: 0 });
                                metrics.streams_opened += 1;
                                Ok((id, out_rx))
                            }
                            Admission::Rejected => {
                                metrics.admission_rejects += 1;
                                Err(anyhow!("no free slots (capacity {})", router.capacity()))
                            }
                        };
                        let _ = reply.send(res);
                    }
                    Request::Push { id, tokens, reply } => {
                        let res = if router.slot_of(id).is_none() {
                            Err(anyhow!("unknown stream {id:?}"))
                        } else if tokens.len() != lane_elems {
                            Err(anyhow!(
                                "expected {lane_elems} f32 tokens, got {}",
                                tokens.len()
                            ))
                        } else if batcher.push(id, tokens, now) {
                            metrics.tokens_in += 1;
                            Ok(())
                        } else {
                            Err(anyhow!("stream {id:?} queue full (backpressure)"))
                        };
                        let _ = reply.send(res);
                    }
                    Request::Close { id } => {
                        if let Some(slot) = router.close(id) {
                            stepper.clear_lane(slot);
                        }
                        batcher.forget(id);
                        ports.remove(&id);
                        metrics.streams_closed += 1;
                    }
                    Request::Metrics { reply } => {
                        let _ = reply.send(metrics.clone());
                    }
                    Request::Shutdown => return Ok(()),
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return Ok(()),
        }

        // 2. tick when the policy says so
        let now = Instant::now();
        if batcher.ready(router.occupied(), now) {
            let plan = batcher.take_tick(|id| router.slot_of(id));
            if plan.lanes.is_empty() {
                continue;
            }
            for (_, _, _, enq) in &plan.lanes {
                metrics.queue_latency.record(now.duration_since(*enq));
            }
            let t0 = Instant::now();
            let lanes = stepper.tick(&plan)?;
            metrics.tick_latency.record(t0.elapsed());
            metrics.ticks += 1;
            let done = Instant::now();
            for lane in lanes {
                router.touch(lane.stream, done);
                if let Some(port) = ports.get_mut(&lane.stream) {
                    port.ticks += 1;
                    metrics.outputs += 1;
                    let _ = port.out.send(TickResult {
                        logits: lane.logits,
                        out: lane.out,
                        tick: port.ticks,
                    });
                }
            }
        }
    }
}
