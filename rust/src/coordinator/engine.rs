//! The serving engine's public front: [`EngineThread`] + [`EngineHandle`].
//!
//! Since the cluster refactor the engine *is* a shard cluster
//! ([`ShardedEngine`], `coordinator::cluster`): `spawn` starts
//! `cfg.effective_shards()` worker threads (each a complete serving
//! cell — backend, router, batcher; see `coordinator::shard`) and the
//! handle is the cluster front door that pins streams to shards. The
//! default `shards = 1` reproduces the old single-threaded engine
//! exactly, so existing callers are unchanged in behavior *and* in API:
//!
//! ```text
//!   clients ──► EngineHandle::open / push / close / metrics
//!                 │  ShardRouter (hash placement, least-loaded
//!                 │  fallback, stream → shard pinning)
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1   Router + Batcher + SlotStepper
//!        │        │          │         per worker thread
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! `metrics()` now returns [`ClusterMetrics`]: the aggregate fields
//! carry the same names the single-engine metrics had, plus a
//! per-shard breakdown and the front door's placement counters.
//!
//! [`ClusterMetrics`]: crate::coordinator::metrics::ClusterMetrics

pub use crate::coordinator::cluster::{EngineHandle, ShardedEngine};
pub use crate::coordinator::shard::TickResult;

/// The spawned serving engine (compat name: a 1-shard cluster is the
/// old engine thread; N shards scale it across cores).
pub type EngineThread = ShardedEngine;
