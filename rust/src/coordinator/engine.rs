//! The serving engine's public front: [`EngineThread`] +
//! [`EngineHandle`] + the RAII [`Session`] client handle.
//!
//! Since the cluster refactor the engine *is* a shard cluster
//! ([`ShardedEngine`], `coordinator::cluster`): `spawn` starts
//! `cfg.effective_shards()` worker threads (each a complete serving
//! cell — backend, router, batcher; see `coordinator::shard`) and the
//! handle is the cluster front door. Clients hold [`Session`]s —
//! `open` returns one, `push`/`recv` flow through it, and dropping it
//! closes the stream — over the typed [`EngineError`] enum. The
//! default `shards = 1` reproduces the old single-threaded engine
//! exactly:
//!
//! ```text
//!   clients ──► Session::push / recv / try_recv   (close-on-drop)
//!                 │
//!                 ▼
//!              EngineHandle::open / resume / metrics / migrate /
//!                 │            rebalance / snapshot
//!                 │  ShardRouter (hash placement, least-loaded
//!                 │  fallback, stream → shard pinning)
//!                 │  migrate: quiesce → export StreamState →
//!                 │           import on target → rebind
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1   Router + Batcher + StreamBackend
//!        │        │          │         per worker thread
//!        │        │          │  full? spill LRU stream ──► StateStore
//!        │        │          │  push to spilled stream ◄── restore
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! With `cfg.hibernate` / `cfg.state_dir` set, slot capacity bounds
//! *active* streams, not registered ones: full shards spill their
//! coldest stream to a [`StateStore`](crate::store::StateStore) and a
//! push wakes it back transparently. A `state_dir` additionally makes
//! sessions durable — `snapshot()` checkpoints every live lane, a
//! restarted engine recovers every registered stream as hibernated,
//! and `resume(id)` reattaches a client bitwise-exactly where it
//! left off.
//!
//! Execution backends implement the [`StreamBackend`] trait (scalar and
//! PJRT ship built-in); a stream's whole serving identity exports as a
//! portable [`StreamState`] snapshot, which is what `migrate` /
//! `rebalance` move between shards — bitwise-transparently to the
//! stream's owner.
//!
//! `metrics()` returns [`ClusterMetrics`]: the aggregate fields carry
//! the same names the single-engine metrics had, plus a per-shard
//! breakdown, the front door's placement counters, the migration
//! counters (attempted/completed/aborted, quiesce-time quantiles), and
//! the kernel path the shard backends resolved at startup
//! (`kernel_dispatch`: scalar / avx2 / neon — see `nn::simd`; dispatch
//! never changes stream bits, only latency).
//!
//! [`ClusterMetrics`]: crate::coordinator::metrics::ClusterMetrics

pub use crate::coordinator::cluster::{EngineHandle, RebalanceReport, ShardedEngine};
pub use crate::coordinator::session::{EngineError, Session, TickReceiver};
pub use crate::coordinator::shard::TickResult;
pub use crate::coordinator::slot_stepper::{StreamBackend, StreamState};

/// The spawned serving engine (compat name: a 1-shard cluster is the
/// old engine thread; N shards scale it across cores).
pub type EngineThread = ShardedEngine;
