//! Layer-3 coordinator — the serving system around the AOT executables.
//!
//! Pieces (DESIGN.md §3):
//! - [`slots`]   — slot-based continual batching (fixed-size DeepCoT
//!   state ⇒ fixed batch lanes; the encoder-side KV-cache analogue of a
//!   vLLM-style router).
//! - [`batcher`] — tick assembly: all-slots-ready or deadline flush,
//!   per-stream FIFO queues with backpressure.
//! - [`router`]  — admission, placement, idle eviction.
//! - [`slot_stepper`] — batched PJRT step with per-lane state masking.
//! - [`engine`]  — the engine thread + `Send` client handle.
//! - [`metrics`] — latency histograms and serving counters.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod slot_stepper;
pub mod slots;
