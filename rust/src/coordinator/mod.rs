//! Layer-3 coordinator — the serving system around the AOT executables.
//!
//! Cluster data flow (sessions → front door → shard router → per-shard
//! batcher/stepper):
//!
//! ```text
//!   clients ──► Session (RAII: push / recv / close-on-drop)
//!                 │
//!                 ▼
//!              EngineHandle (cluster front door, Clone + Send)
//!                 │  ShardRouter: hash placement, least-loaded
//!                 │  fallback, stream → shard pinning
//!                 │  migrate/rebalance: StreamState export → import
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1      one worker thread each
//!     Router    Router     Router         admission + idle eviction
//!     Batcher   Batcher    Batcher        deadline / all-slots ticks
//!     Stepper   Stepper    Stepper        StreamBackend (scalar | PJRT)
//!        │        │          │
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! Pieces (DESIGN.md §3):
//! - [`slots`]   — slot-based continual batching (fixed-size DeepCoT
//!   state ⇒ fixed batch lanes; the encoder-side KV-cache analogue of a
//!   vLLM-style router).
//! - [`batcher`] — tick assembly: all-slots-ready or deadline flush,
//!   per-stream FIFO queues with backpressure (plus extract/restore,
//!   the migration quiesce path).
//! - [`router`]  — per-shard admission, slot placement, idle eviction.
//! - [`slot_stepper`] — the [`slot_stepper::StreamBackend`] trait
//!   (batched stepping with per-lane state masking and portable
//!   [`slot_stepper::StreamState`] snapshots) and its built-in scalar /
//!   PJRT implementations.
//! - [`shard`]   — one shard worker: the per-tick serving loop around
//!   a backend, with stream export/import for live migration and
//!   drain-on-shutdown semantics.
//! - [`cluster`] — the multi-shard subsystem: [`cluster::ShardRouter`]
//!   placement (hash / least-loaded / round-robin with least-loaded
//!   fallback), the [`cluster::ShardedEngine`] front door, and live
//!   stream migration ([`cluster::EngineHandle::migrate`] /
//!   [`cluster::EngineHandle::rebalance`]).
//! - [`hibernate`] — the hibernation policy layer: the cluster-wide
//!   table of streams spilled out of backend lanes into a
//!   `crate::store::StateStore`, plus the conversions between live
//!   coordinator state and durable `store::codec::StreamRecord`s.
//!   Spill happens shard-side when admission needs a lane; restore
//!   happens at the front door on the next PUSH or resume.
//! - [`session`] — the client layer: RAII [`session::Session`] stream
//!   handles over the typed [`session::EngineError`] enum, with a
//!   splittable [`session::TickReceiver`] half so pushes and receives
//!   can live on different threads (the net server's executor polls
//!   the receiver halves to multiplex ticks onto per-connection write
//!   queues; see `crate::net`).
//! - [`engine`]  — the public facade (`EngineThread`, `EngineHandle`,
//!   `Session`, `EngineError` re-exports).
//! - [`metrics`] — latency histograms, per-shard counters, and the
//!   merged [`metrics::ClusterMetrics`] view with migration
//!   observability. Stage-span breakdowns, the event journal, and the
//!   Prometheus/JSON exposition of all of it live in `crate::obs`,
//!   governed by the `EngineConfig::obs` level knob.

pub mod batcher;
#[deny(missing_docs)]
pub mod cluster;
#[deny(missing_docs)]
pub mod hibernate;
#[deny(missing_docs)]
pub mod engine;
#[deny(missing_docs)]
pub mod metrics;
pub mod router;
#[deny(missing_docs)]
pub mod session;
pub mod shard;
#[deny(missing_docs)]
pub mod slot_stepper;
pub mod slots;
