//! Layer-3 coordinator — the serving system around the AOT executables.
//!
//! Cluster data flow (front door → shard router → per-shard
//! batcher/stepper):
//!
//! ```text
//!   clients ──► EngineHandle (cluster front door, Clone + Send)
//!                 │  ShardRouter: hash placement, least-loaded
//!                 │  fallback, stream → shard pinning
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1      one worker thread each
//!     Router    Router     Router         admission + idle eviction
//!     Batcher   Batcher    Batcher        deadline / all-slots ticks
//!     Stepper   Stepper    Stepper        batched scalar | PJRT
//!        │        │          │
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! Pieces (DESIGN.md §3):
//! - [`slots`]   — slot-based continual batching (fixed-size DeepCoT
//!   state ⇒ fixed batch lanes; the encoder-side KV-cache analogue of a
//!   vLLM-style router).
//! - [`batcher`] — tick assembly: all-slots-ready or deadline flush,
//!   per-stream FIFO queues with backpressure.
//! - [`router`]  — per-shard admission, slot placement, idle eviction.
//! - [`slot_stepper`] — batched PJRT/scalar step with per-lane state
//!   masking and (scalar) per-lane position clocks.
//! - [`shard`]   — one shard worker: the per-tick serving loop around
//!   a backend, with drain-on-shutdown semantics.
//! - [`cluster`] — the multi-shard subsystem: [`cluster::ShardRouter`]
//!   placement (hash / least-loaded / round-robin with least-loaded
//!   fallback) and the [`cluster::ShardedEngine`] front door.
//! - [`engine`]  — the public compat facade (`EngineThread`,
//!   `EngineHandle`).
//! - [`metrics`] — latency histograms, per-shard counters, and the
//!   merged [`metrics::ClusterMetrics`] view.

pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod shard;
pub mod slot_stepper;
pub mod slots;
