//! Sharded multi-core serving: the cluster front door over N shard
//! workers, with live stream migration between them.
//!
//! DeepCoT's per-stream state is fixed-size, so scaling the engine is a
//! placement problem, not a memory problem: [`ShardedEngine`] spawns
//! `cfg.effective_shards()` copies of the single-engine serving cell
//! (`coordinator::shard`), each on its own thread with its own
//! [`SlotStepper`] backend, and [`ShardRouter`] pins every stream to
//! one shard — until a [`EngineHandle::migrate`] moves it. Within a
//! shard nothing changed — same router, batcher, masked-lane tick —
//! which is why a stream's outputs are bitwise-identical whether it
//! serves on a 1-shard or an N-shard cluster, and across a mid-run
//! migration (per-lane position clocks + portable `StreamState`
//! snapshots make them depend on nothing but the stream's own history).
//!
//! Data flow:
//!
//! ```text
//!   clients ──► Session (RAII stream handle: push / recv / drop-closes)
//!                 │
//!                 ▼
//!              EngineHandle (cluster front door, Clone + Send)
//!                 │ ShardRouter: hash placement, least-loaded
//!                 │ fallback, stream → shard pinning
//!                 │ migrate/rebalance: export → import → rebind
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1      one worker thread each
//!     Router    Router     Router         admission + idle eviction
//!     Batcher   Batcher    Batcher        deadline / all-slots ticks
//!     Stepper   Stepper    Stepper        StreamBackend (scalar | PJRT)
//!        │        │          │
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! **Hibernation** (when `cfg.hibernate` or `cfg.state_dir` is set)
//! decouples registered streams from slot capacity: a full shard spills
//! its least-recently-active stream to the
//! [`StateStore`](crate::store::StateStore) instead of rejecting the
//! newcomer, and a push to a spilled stream transparently restores it
//! into a free lane (possibly spilling a colder victim). With a
//! `state_dir` the store is a durable on-disk log: periodic
//! [`EngineHandle::snapshot`]s checkpoint every lane-resident stream,
//! recover-on-boot re-registers everything found on disk as hibernated,
//! and [`EngineHandle::resume`] reattaches a client to a recovered
//! stream — same id, same tick ordinals, bitwise-identical outputs.
//!
//! The front door serializes only `open`/`close`/`migrate` bookkeeping
//! (write locks on the shard map); `push` takes a read lock for one map
//! lookup and then talks straight to the owning shard, so concurrent
//! pushes to different shards never serialize and the tick hot path
//! never crosses shard boundaries. A migration holds the write lock
//! across its export → import round-trip: that *is* the quiesce — no
//! push can route while the stream's state is in flight. Note the
//! blast radius: because the quiesce is the one front-door lock, a
//! migration briefly blocks routing to EVERY shard (and `rebalance`
//! repeats that once per move), bounded by one export + import
//! round-trip against otherwise-responsive shard loops; the window is
//! recorded in the quiesce histogram. A per-stream tombstone in the
//! routing map would narrow the stall to the migrating stream — see
//! ROADMAP if migration ever becomes hot-path. A push already in
//! flight to the source shard when migration starts is handed back by
//! the shard with its tokens and transparently re-routed to the
//! stream's new home.
//!
//! [`SlotStepper`]: crate::coordinator::slot_stepper::SlotStepper

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

use crate::config::{EngineConfig, PlacementPolicy};
use crate::coordinator::hibernate::{self, HibernatePool};
use crate::coordinator::metrics::{ClusterMetrics, LatencyHisto};
use crate::coordinator::session::{EngineError, Session};
use crate::coordinator::shard::{ImportReason, ShardHandle, ShardThread};
use crate::coordinator::slots::StreamId;
use crate::obs::journal::EventKind;
use crate::obs::span::Stage;
use crate::obs::ObsHandle;
use crate::store::disk::DiskStore;
use crate::store::MemStore;

/// Cluster-level placement: pins streams to shards and tracks the load
/// the front door believes each shard carries (opens minus closes). A
/// shard-side idle eviction is reconciled structurally: evictions only
/// happen while admitting a new stream, and the admitting shard's reply
/// names the victim, which `EngineHandle::open` unbinds — so abandoned
/// streams cannot leak bindings or inflate load counts. Pure
/// bookkeeping with no I/O — property-testable without threads.
#[derive(Debug)]
pub struct ShardRouter {
    policy: PlacementPolicy,
    /// Front-door-tracked stream count per shard.
    load: Vec<usize>,
    assigned: BTreeMap<StreamId, usize>,
    rr_cursor: usize,
}

impl ShardRouter {
    /// A router over `n_shards` shards with the given placement policy.
    pub fn new(n_shards: usize, policy: PlacementPolicy) -> Self {
        assert!(n_shards >= 1, "cluster needs at least one shard");
        Self { policy, load: vec![0; n_shards], assigned: BTreeMap::new(), rr_cursor: 0 }
    }

    /// Number of shards this router places over.
    pub fn n_shards(&self) -> usize {
        self.load.len()
    }

    /// Fibonacci-hash the id onto a shard (deterministic, well-mixed
    /// for sequential ids).
    fn hash_shard(&self, id: StreamId) -> usize {
        ((id.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % self.load.len()
    }

    /// Shard candidates for a new stream, in preference order: the
    /// policy's primary first, then every other shard by ascending
    /// tracked load (ties to the lower index) — the least-loaded
    /// fallback chain a full primary hands the open to.
    pub fn plan(&mut self, id: StreamId) -> Vec<usize> {
        let n = self.load.len();
        let primary = match self.policy {
            PlacementPolicy::Hash => self.hash_shard(id),
            PlacementPolicy::LeastLoaded => {
                (0..n).min_by_key(|&s| (self.load[s], s)).unwrap_or(0)
            }
            PlacementPolicy::RoundRobin => {
                let s = self.rr_cursor % n;
                self.rr_cursor = (self.rr_cursor + 1) % n;
                s
            }
        };
        let mut order = Vec::with_capacity(n);
        order.push(primary);
        let mut rest: Vec<usize> = (0..n).filter(|&s| s != primary).collect();
        rest.sort_by_key(|&s| (self.load[s], s));
        order.extend(rest);
        order
    }

    /// Pin a stream to a shard (counted toward that shard's load).
    pub fn bind(&mut self, id: StreamId, shard: usize) {
        self.assigned.insert(id, shard);
        self.load[shard] += 1;
    }

    /// The shard a stream is pinned to, if any.
    pub fn shard_of(&self, id: StreamId) -> Option<usize> {
        self.assigned.get(&id).copied()
    }

    /// Drop a stream's pinning; returns the shard it was on.
    pub fn unbind(&mut self, id: StreamId) -> Option<usize> {
        let shard = self.assigned.remove(&id)?;
        self.load[shard] = self.load[shard].saturating_sub(1);
        Some(shard)
    }

    /// Front-door-tracked stream count per shard.
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// The streams currently pinned to one shard.
    pub fn streams_on(&self, shard: usize) -> Vec<StreamId> {
        self.assigned
            .iter()
            .filter(|&(_, &s)| s == shard)
            .map(|(&id, _)| id)
            .collect()
    }
}

struct FrontDoor {
    router: ShardRouter,
    next_id: u64,
    placed_primary: u64,
    placed_fallback: u64,
    cluster_rejects: u64,
    migrations_attempted: u64,
    migrations_completed: u64,
    migrations_aborted: u64,
    quiesce_latency: LatencyHisto,
    /// Streams re-registered as hibernated by recover-on-boot.
    streams_recovered: u64,
    /// Full-cluster snapshots completed.
    snapshots_taken: u64,
    snapshot_latency: LatencyHisto,
}

// the front door is read-mostly on the hot path (push only needs the
// stream → shard lookup), so an RwLock keeps pushes to different shards
// from serializing on placement bookkeeping
fn read(door: &RwLock<FrontDoor>) -> RwLockReadGuard<'_, FrontDoor> {
    door.read().unwrap_or_else(|p| p.into_inner())
}

fn write(door: &RwLock<FrontDoor>) -> RwLockWriteGuard<'_, FrontDoor> {
    door.write().unwrap_or_else(|p| p.into_inner())
}

/// What a [`EngineHandle::rebalance`] sweep did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RebalanceReport {
    /// Migrations the sweep planned from the load snapshot.
    pub planned: usize,
    /// Migrations that completed.
    pub moved: usize,
    /// Migrations that failed (stream stayed on, or returned to, its
    /// source shard when possible).
    pub failed: usize,
}

/// Cloneable, `Send` front-door handle to the shard cluster. `open`
/// hands out RAII [`Session`]s — the only public path for pushing
/// tokens — while `metrics`, `migrate` and `rebalance` expose the
/// cluster's observability and placement controls.
#[derive(Clone)]
pub struct EngineHandle {
    shards: Arc<[ShardHandle]>,
    door: Arc<RwLock<FrontDoor>>,
    obs: ObsHandle,
    /// Hibernation table + state store; `None` when neither
    /// `cfg.hibernate` nor `cfg.state_dir` is set (legacy semantics:
    /// full shards evict-or-reject).
    pool: Option<HibernatePool>,
}

impl EngineHandle {
    /// Open a stream: assign a cluster-unique id, walk the placement
    /// plan (primary, then least-loaded fallbacks) until a shard admits
    /// it, and pin the stream there. Returns the RAII [`Session`] that
    /// owns the stream (closed on drop).
    ///
    /// The door lock is held only for id/plan assignment and for the
    /// final bind — never across the blocking shard round-trips — so an
    /// open walking a slow fallback chain cannot stall pushes to other
    /// shards.
    pub fn open(&self) -> Result<Session, EngineError> {
        let (id, order) = {
            let mut door = write(&self.door);
            let id = StreamId(door.next_id);
            door.next_id += 1;
            (id, door.router.plan(id))
        };
        let mut last_err = None;
        for (rank, &shard) in order.iter().enumerate() {
            match self.shards[shard].open(id) {
                Ok((rx, evicted)) => {
                    let mut door = write(&self.door);
                    if let Some(eid) = evicted {
                        // the shard reclaimed an idle session to admit
                        // us; drop the victim's front-door binding too
                        // (a no-op if its owner already closed it)
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    if rank == 0 {
                        door.placed_primary += 1;
                    } else {
                        door.placed_fallback += 1;
                    }
                    drop(door);
                    return Ok(Session::attach(id, rx, self.clone()));
                }
                Err(e) => last_err = Some(e),
            }
        }
        write(&self.door).cluster_rejects += 1;
        Err(last_err.unwrap_or(EngineError::ShuttingDown))
    }

    /// Submit the next token(s) for a stream (m*d_in f32s); routed to
    /// the stream's pinned shard. If the binding raced a live migration
    /// (the shard hands the unaccepted tokens back), the push re-routes
    /// to the stream's new shard transparently — and if the stream was
    /// hibernated (spilled by an overcommitted shard), it is restored
    /// into a lane first, possibly spilling a colder victim to make
    /// room. The pushing client notices neither.
    pub(crate) fn push_raw(&self, id: StreamId, mut tokens: Vec<f32>) -> Result<(), EngineError> {
        // bounded retries: a shard disowns a push (handing the tokens
        // back) when the stream just migrated away — the re-read of the
        // binding blocks behind the in-flight migration's write lock
        // and then routes to the stream's current home. That home can
        // legitimately be the SAME shard again (the migration aborted
        // and restored the stream), so retry on the binding, not on
        // shard inequality; a genuinely-gone stream exits via the
        // unbound binding or the retry bound.
        for _ in 0..4 {
            let shard = match read(&self.door).router.shard_of(id) {
                Some(s) => s,
                None => {
                    // unbound: transparently wake the stream if it is
                    // hibernated, then re-read the fresh binding
                    self.try_restore(id)?;
                    match read(&self.door).router.shard_of(id) {
                        Some(s) => s,
                        None => return Err(EngineError::StreamClosed(id)),
                    }
                }
            };
            match self.shards[shard].push(id, tokens) {
                Ok(()) => return Ok(()),
                Err((EngineError::StreamClosed(_), Some(rejected))) => tokens = rejected,
                Err((e, _)) => return Err(e),
            }
        }
        Err(EngineError::StreamClosed(id))
    }

    /// Wake a hibernated stream that still has a live owner: import its
    /// stored record into a lane (walking the placement plan; a full
    /// shard spills its coldest stream to make room) and rebind it. The
    /// door write lock is the quiesce, exactly as in [`Self::migrate`].
    ///
    /// Errors: [`EngineError::StreamClosed`] when the id is neither
    /// bound nor hibernated, [`EngineError::Hibernated`] when the
    /// stream exists but has no live output channel (recovered from
    /// disk after a restart — only [`Self::resume`] can mint one).
    fn try_restore(&self, id: StreamId) -> Result<(), EngineError> {
        let Some(pool) = &self.pool else {
            return Err(EngineError::StreamClosed(id));
        };
        let mut door = write(&self.door);
        if door.router.shard_of(id).is_some() {
            // a racing push already restored it while we waited
            return Ok(());
        }
        let Some((rec, port)) = pool.begin_restore(id).map_err(EngineError::internal)? else {
            return Err(EngineError::StreamClosed(id));
        };
        let Some(port) = port else {
            pool.abort_restore(id, None);
            return Err(EngineError::Hibernated(id));
        };
        let order = door.router.plan(id);
        let mut payload = Some(hibernate::payload_of(rec, port.clone(), Instant::now()));
        let mut last_err = None;
        for &shard in &order {
            let Some(p) = payload.take() else { break };
            match self.shards[shard].import(id, p, ImportReason::Restore) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    pool.commit_restore(id);
                    return Ok(());
                }
                Err((e, p, evicted)) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    payload = p;
                    last_err = Some(e);
                }
            }
        }
        // nowhere to land: the stream stays hibernated and resumable
        pool.abort_restore(id, Some(port));
        Err(last_err.unwrap_or(EngineError::ShuttingDown))
    }

    /// Resume a hibernated stream that has no live owner (recovered
    /// from the state store after a restart): mint a fresh output
    /// channel, restore the stream into a lane, and hand back a
    /// [`Session`] that continues exactly where the stream left off —
    /// same id, same tick ordinals, bitwise-identical outputs.
    ///
    /// A stream whose original owner still holds its channel cannot be
    /// resumed (that would silently steal its output); pushes from that
    /// owner wake it transparently instead.
    pub fn resume(&self, id: StreamId) -> Result<Session, EngineError> {
        let Some(pool) = &self.pool else {
            return Err(EngineError::InvalidRequest(
                "resume requires hibernation (set hibernate or state_dir)".to_string(),
            ));
        };
        let mut door = write(&self.door);
        if door.router.shard_of(id).is_some() {
            return Err(EngineError::InvalidRequest(format!(
                "stream {} is live; resume only applies to hibernated streams",
                id.0
            )));
        }
        let Some((rec, old_port)) = pool.begin_restore(id).map_err(EngineError::internal)? else {
            return Err(EngineError::StreamClosed(id));
        };
        if let Some(port) = old_port {
            pool.abort_restore(id, Some(port));
            return Err(EngineError::InvalidRequest(format!(
                "stream {} still has a live owner; it wakes on push, not resume",
                id.0
            )));
        }
        let (tx, rx) = mpsc::channel();
        let order = door.router.plan(id);
        let mut payload = Some(hibernate::payload_of(rec, tx, Instant::now()));
        let mut last_err = None;
        for &shard in &order {
            let Some(p) = payload.take() else { break };
            match self.shards[shard].import(id, p, ImportReason::Restore) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    pool.commit_restore(id);
                    drop(door);
                    return Ok(Session::attach(id, rx, self.clone()));
                }
                Err((e, p, evicted)) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    payload = p;
                    last_err = Some(e);
                }
            }
        }
        pool.abort_restore(id, None);
        Err(last_err.unwrap_or(EngineError::ShuttingDown))
    }

    /// Whether a stream is currently hibernated (no lane anywhere; its
    /// state lives in the store and wakes on push or resume).
    pub fn is_hibernated(&self, id: StreamId) -> bool {
        self.pool.as_ref().map_or(false, |p| p.contains(id))
    }

    /// Every currently hibernated stream id (ascending).
    pub fn hibernated_streams(&self) -> Vec<StreamId> {
        self.pool.as_ref().map(|p| p.ids()).unwrap_or_default()
    }

    /// Checkpoint every lane-resident stream to the state store and
    /// flush it: export each bound stream, persist its record, and put
    /// it straight back in its lane (counter-neutral — the stream never
    /// logically moved; its owner keeps pushing through the snapshot).
    /// Hibernated streams are already durable, so after a snapshot the
    /// store holds every registered stream and a crash loses nothing.
    ///
    /// Returns the number of streams checkpointed. A no-op `Ok(0)`
    /// without a configured pool.
    pub fn snapshot(&self) -> Result<usize, EngineError> {
        let Some(pool) = &self.pool else {
            return Ok(0);
        };
        let t0 = Instant::now();
        let mut door = write(&self.door);
        let bound: Vec<(StreamId, usize)> = (0..self.shards.len())
            .flat_map(|s| door.router.streams_on(s).into_iter().map(move |id| (id, s)))
            .collect();
        let mut n = 0usize;
        for (id, shard) in bound {
            let payload = match self.shards[shard].export(id, false) {
                Ok(p) => p,
                // the stream closed between the load snapshot and now
                Err(_) => continue,
            };
            let rec = hibernate::record_of(id, &payload);
            let ckpt = pool.checkpoint(&rec);
            match self.shards[shard].import(id, payload, ImportReason::Snapshot) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                }
                Err((_, payload, evicted)) => {
                    // an open racing its lock-free shard round-trip took
                    // the freed slot; park the stream as hibernated
                    // rather than lose it (its channel stays live)
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.unbind(id);
                    if let Some(p) = payload {
                        let port = p.port.clone();
                        let rec = hibernate::record_of(id, &p);
                        let _ = pool.spill(&rec, port);
                    }
                }
            }
            if ckpt.is_ok() {
                n += 1;
            }
        }
        pool.sync().map_err(EngineError::internal)?;
        door.snapshots_taken += 1;
        let dt = t0.elapsed();
        door.snapshot_latency.record(dt);
        drop(door);
        self.obs.event(EventKind::Snapshot, 0, -1, n as u64);
        Ok(n)
    }

    /// Close a stream by id (sessions call this on drop). Hibernated
    /// streams are forgotten entirely — table row and stored blob.
    pub(crate) fn close_raw(&self, id: StreamId) {
        let shard = write(&self.door).router.unbind(id);
        if let Some(s) = shard {
            self.shards[s].close(id);
        }
        if let Some(pool) = &self.pool {
            let _ = pool.remove(id);
        }
    }

    /// Number of shards behind this front door.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The cluster's observability handle (level, journal, exposition
    /// sequence / rate state) — shared by every shard and the net layer.
    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// The shard a stream currently serves on (observability; may be
    /// stale by the time the caller acts on it).
    pub fn shard_of(&self, id: StreamId) -> Option<usize> {
        read(&self.door).router.shard_of(id)
    }

    /// Snapshot of the front-door-tracked stream count per shard.
    pub fn shard_loads(&self) -> Vec<usize> {
        read(&self.door).router.load().to_vec()
    }

    /// Live-migrate a stream to another shard: quiesce it (no push can
    /// route while the write lock is held), export its portable
    /// [`StreamState`] snapshot — K/V rings, position clock, queued
    /// tokens, output port — from the source shard, import on the
    /// target, and rebind the front door. The stream's owner notices
    /// nothing: its `Session` keeps pushing and receiving, and outputs
    /// stay bitwise-identical to an unmigrated run.
    ///
    /// On failure the stream is left (or put back) on its source shard
    /// whenever possible; the attempt is counted in the migration
    /// metrics either way. A migrate to the stream's current shard is
    /// an uncounted no-op.
    ///
    /// [`StreamState`]: crate::coordinator::slot_stepper::StreamState
    pub fn migrate(&self, id: StreamId, to_shard: usize) -> Result<(), EngineError> {
        if to_shard >= self.shards.len() {
            return Err(EngineError::InvalidRequest(format!(
                "shard {to_shard} out of range ({} shards)",
                self.shards.len()
            )));
        }
        let t0 = Instant::now();
        let mut door = write(&self.door);
        let Some(from) = door.router.shard_of(id) else {
            door.migrations_attempted += 1;
            door.migrations_aborted += 1;
            self.obs.event(EventKind::MigrationAttempt, id.0, -1, to_shard as u64);
            self.obs.event(EventKind::MigrationAbort, id.0, -1, to_shard as u64);
            return Err(EngineError::StreamClosed(id));
        };
        if from == to_shard {
            // already home: an uncounted no-op, so degenerate requests
            // (e.g. a 1-shard round-robin hop) don't skew the counters
            // or drag the quiesce histogram toward zero
            return Ok(());
        }
        door.migrations_attempted += 1;
        self.obs.event(EventKind::MigrationAttempt, id.0, from as i64, to_shard as u64);
        // export atomically detaches the stream from its source shard
        // (or fails with the stream still serving there, untouched)
        let payload = match self.shards[from].export(id, true) {
            Ok(p) => p,
            Err(e) => {
                door.migrations_aborted += 1;
                self.obs.event(EventKind::MigrationAbort, id.0, from as i64, to_shard as u64);
                return Err(e);
            }
        };
        door.router.unbind(id);
        match self.shards[to_shard].import(id, payload, ImportReason::Migrate) {
            Ok(evicted) => {
                if let Some(eid) = evicted {
                    door.router.unbind(eid);
                }
                door.router.bind(id, to_shard);
                door.migrations_completed += 1;
                let quiesce = t0.elapsed();
                door.quiesce_latency.record(quiesce);
                self.obs.event(
                    EventKind::MigrationComplete,
                    id.0,
                    to_shard as i64,
                    quiesce.as_micros() as u64,
                );
                Ok(())
            }
            Err((e, mut payload, evicted)) => {
                if let Some(eid) = evicted {
                    // a failed import may still have evicted an idle
                    // victim during admission — its binding must go
                    door.router.unbind(eid);
                }
                door.migrations_aborted += 1;
                self.obs.event(EventKind::MigrationAbort, id.0, from as i64, to_shard as u64);
                // abort: put the stream back on its source shard. The
                // slot the export freed is USUALLY still free, but an
                // open racing its lock-free shard round-trip can have
                // taken it — so if the source rejects, rescue the
                // stream onto any other shard with room rather than
                // dropping a live stream; only when every shard is
                // full does the owner see a disconnected channel.
                // `rollback` (source only) un-counts the export so an
                // aborted migration leaves its counters untouched.
                let rescue: Vec<usize> = std::iter::once(from)
                    .chain((0..self.shards.len()).filter(|&s| s != from && s != to_shard))
                    .collect();
                for shard in rescue {
                    let Some(p) = payload.take() else { break };
                    let reason = if shard == from {
                        ImportReason::MigrateRollback
                    } else {
                        ImportReason::Migrate
                    };
                    match self.shards[shard].import(id, p, reason) {
                        Ok(evicted) => {
                            if let Some(eid) = evicted {
                                door.router.unbind(eid);
                            }
                            door.router.bind(id, shard);
                            break;
                        }
                        Err((_, p, evicted)) => {
                            if let Some(eid) = evicted {
                                door.router.unbind(eid);
                            }
                            payload = p;
                        }
                    }
                }
                Err(e)
            }
        }
    }

    /// One placement sweep against load skew: plan migrations from the
    /// current load snapshot until no shard holds ≥2 more streams than
    /// the lightest one, then execute them via [`Self::migrate`]. Safe
    /// to call on a live cluster (long-lived sessions keep serving
    /// through their moves); a no-op on balanced clusters.
    pub fn rebalance(&self) -> Result<RebalanceReport, EngineError> {
        let moves: Vec<(StreamId, usize)> = {
            let door = read(&self.door);
            let n = door.router.n_shards();
            let mut load = door.router.load().to_vec();
            let mut movable: Vec<Vec<StreamId>> =
                (0..n).map(|s| door.router.streams_on(s)).collect();
            let mut moves = Vec::new();
            loop {
                let Some(max_s) = (0..n).max_by_key(|&s| load[s]) else {
                    break;
                };
                let Some(min_s) = (0..n).min_by_key(|&s| load[s]) else {
                    break;
                };
                if load[max_s] <= load[min_s] + 1 {
                    break;
                }
                let Some(id) = movable[max_s].pop() else {
                    break;
                };
                moves.push((id, min_s));
                load[max_s] -= 1;
                load[min_s] += 1;
            }
            moves
        };
        let mut report = RebalanceReport { planned: moves.len(), ..Default::default() };
        for (id, to) in moves {
            // a stream may have closed since planning; count that as a
            // failed move rather than erroring the whole sweep
            match self.migrate(id, to) {
                Ok(()) => report.moved += 1,
                Err(_) => report.failed += 1,
            }
        }
        Ok(report)
    }

    /// Cluster metrics: per-shard snapshots, their aggregate, and the
    /// front door's placement + migration counters.
    pub fn metrics(&self) -> Result<ClusterMetrics, EngineError> {
        let per_shard = self
            .shards
            .iter()
            .map(|s| s.metrics())
            .collect::<Result<Vec<_>, _>>()?;
        let mut m = ClusterMetrics::from_shards(per_shard);
        let door = read(&self.door);
        m.placed_primary = door.placed_primary;
        m.placed_fallback = door.placed_fallback;
        m.cluster_rejects = door.cluster_rejects;
        m.migrations_attempted = door.migrations_attempted;
        m.migrations_completed = door.migrations_completed;
        m.migrations_aborted = door.migrations_aborted;
        m.quiesce_latency = door.quiesce_latency.clone();
        m.streams_recovered = door.streams_recovered;
        m.snapshots_taken = door.snapshots_taken;
        m.snapshot_latency = door.snapshot_latency.clone();
        drop(door);
        if let Some(pool) = &self.pool {
            m.hibernated_resident = pool.resident() as u64;
        }
        m.uptime = self.obs.uptime();
        m.boot_unix_ms = self.obs.boot_unix_ms();
        if self.obs.spans_on() {
            // the quiesce + snapshot windows are front-door spans, not
            // shard ones; fold them into the stage family so exposition
            // sees one table
            m.stage_spans.merge_histo(Stage::MigQuiesce, &m.quiesce_latency);
            m.stage_spans.merge_histo(Stage::Snapshot, &m.snapshot_latency);
        }
        Ok(m)
    }
}

/// The sharded serving engine: N shard worker threads behind one
/// [`EngineHandle`] front door. With `cfg.shards == 1` this is exactly
/// the old single-threaded `EngineThread`.
pub struct ShardedEngine {
    shards: Vec<ShardThread>,
    handle: EngineHandle,
}

impl ShardedEngine {
    /// Spawn `cfg.effective_shards()` worker shards; blocks until every
    /// shard's model is loaded and ready (the first Push never pays
    /// compile latency). All shards are started before any is awaited,
    /// so their backends initialize in parallel.
    pub fn spawn(cfg: EngineConfig) -> Result<Self, EngineError> {
        let n = cfg.effective_shards().max(1);
        let obs = ObsHandle::new(cfg.obs);
        let pool = match (&cfg.state_dir, cfg.hibernate) {
            (Some(dir), _) => {
                std::fs::create_dir_all(dir).map_err(EngineError::internal)?;
                let store =
                    DiskStore::open(dir.join("streams.log")).map_err(EngineError::internal)?;
                Some(HibernatePool::new(Box::new(store)))
            }
            (None, true) => Some(HibernatePool::new(Box::new(MemStore::new()))),
            (None, false) => None,
        };
        // recover-on-boot: every stream a previous run persisted is
        // re-registered as hibernated (portless until resumed), and the
        // id counter moves past them so new opens never collide
        let mut next_id = 1u64;
        let mut recovered = 0u64;
        if let Some(pool) = &pool {
            for raw in pool.stored_ids().map_err(EngineError::internal)? {
                pool.register_recovered(StreamId(raw));
                next_id = next_id.max(raw + 1);
                recovered += 1;
            }
        }
        let mut shards = Vec::with_capacity(n);
        for s in 0..n {
            shards.push(ShardThread::start(s, cfg.clone(), obs.clone(), pool.clone())?);
        }
        for t in shards.iter_mut() {
            t.wait_ready()?;
        }
        let handles: Arc<[ShardHandle]> =
            shards.iter().map(|t| t.handle()).collect::<Vec<_>>().into();
        let door = FrontDoor {
            router: ShardRouter::new(n, cfg.placement),
            next_id,
            placed_primary: 0,
            placed_fallback: 0,
            cluster_rejects: 0,
            migrations_attempted: 0,
            migrations_completed: 0,
            migrations_aborted: 0,
            quiesce_latency: LatencyHisto::new(),
            streams_recovered: recovered,
            snapshots_taken: 0,
            snapshot_latency: LatencyHisto::new(),
        };
        let handle =
            EngineHandle { shards: handles, door: Arc::new(RwLock::new(door)), obs, pool };
        Ok(Self { shards, handle })
    }

    /// A cloneable front-door handle.
    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Number of worker shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Live-migrate a stream to another shard (see
    /// [`EngineHandle::migrate`]).
    pub fn migrate(&self, id: StreamId, to_shard: usize) -> Result<(), EngineError> {
        self.handle.migrate(id, to_shard)
    }

    /// Run one load-skew rebalancing sweep (see
    /// [`EngineHandle::rebalance`]).
    pub fn rebalance(&self) -> Result<RebalanceReport, EngineError> {
        self.handle.rebalance()
    }

    /// Signal every shard, then join them all: each shard drains its
    /// queued requests with terminal errors before exiting, so no
    /// in-flight caller is left blocked.
    pub fn shutdown(mut self) -> Result<(), EngineError> {
        for t in &self.shards {
            t.signal_shutdown();
        }
        let mut res = Ok(());
        for t in self.shards.iter_mut() {
            if let Err(e) = t.join() {
                res = Err(e);
            }
        }
        res
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        // broadcast first so shards drain in parallel; ShardThread's own
        // Drop joins each one
        for t in &self.shards {
            t.signal_shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn hash_placement_is_deterministic_and_covers_all_shards() {
        let mut r = ShardRouter::new(4, PlacementPolicy::Hash);
        for raw in 1..40u64 {
            let id = StreamId(raw);
            let a = r.plan(id);
            let b = r.plan(id);
            assert_eq!(a, b, "same id must plan identically");
            assert_eq!(a.len(), 4);
            let mut seen = a.clone();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3], "plan must cover every shard once");
        }
        // sequential ids must not all clump onto one shard
        let primaries: std::collections::BTreeSet<usize> =
            (1..40u64).map(|raw| r.plan(StreamId(raw))[0]).collect();
        assert!(primaries.len() > 1, "hash collapsed all ids to one shard");
    }

    #[test]
    fn fallbacks_are_least_loaded_first() {
        let mut r = ShardRouter::new(3, PlacementPolicy::Hash);
        let id = StreamId(7);
        let primary = r.plan(id)[0];
        // load the shards unevenly (skip the primary to keep it first)
        let others: Vec<usize> = (0..3).filter(|&s| s != primary).collect();
        r.bind(StreamId(100), others[0]);
        r.bind(StreamId(101), others[0]);
        r.bind(StreamId(102), others[1]);
        let plan = r.plan(id);
        assert_eq!(plan[0], primary);
        assert_eq!(plan[1], others[1], "lighter shard first in the fallback chain");
        assert_eq!(plan[2], others[0]);
    }

    #[test]
    fn least_loaded_policy_picks_min() {
        let mut r = ShardRouter::new(3, PlacementPolicy::LeastLoaded);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 1);
        assert_eq!(r.plan(StreamId(3))[0], 2);
        r.bind(StreamId(3), 2);
        r.bind(StreamId(4), 2);
        assert_eq!(r.plan(StreamId(5))[0], 0, "ties break to the lower index");
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = ShardRouter::new(3, PlacementPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.plan(StreamId(i))[0]).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn bind_unbind_track_load() {
        let mut r = ShardRouter::new(2, PlacementPolicy::Hash);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 0);
        r.bind(StreamId(3), 1);
        assert_eq!(r.load(), &[2, 1]);
        assert_eq!(r.shard_of(StreamId(2)), Some(0));
        assert_eq!(r.streams_on(0), vec![StreamId(1), StreamId(2)]);
        assert_eq!(r.streams_on(1), vec![StreamId(3)]);
        assert_eq!(r.unbind(StreamId(2)), Some(0));
        assert_eq!(r.unbind(StreamId(2)), None, "double unbind is inert");
        assert_eq!(r.load(), &[1, 1]);
        assert_eq!(r.shard_of(StreamId(2)), None);
        assert_eq!(r.streams_on(0), vec![StreamId(1)]);
    }

    #[test]
    fn rebind_models_migration() {
        let mut r = ShardRouter::new(2, PlacementPolicy::Hash);
        r.bind(StreamId(1), 0);
        assert_eq!(r.unbind(StreamId(1)), Some(0));
        r.bind(StreamId(1), 1);
        assert_eq!(r.shard_of(StreamId(1)), Some(1));
        assert_eq!(r.load(), &[0, 1]);
    }

    /// Property: under random bind/unbind churn the tracked load always
    /// equals the number of assigned streams per shard, and every plan
    /// is a permutation of the shard set.
    #[test]
    fn prop_router_load_accounting() {
        prop::check("shard-router-load", 150, |rng| {
            let n = rng.range(1, 5);
            let policy = match rng.below(3) {
                0 => PlacementPolicy::Hash,
                1 => PlacementPolicy::LeastLoaded,
                _ => PlacementPolicy::RoundRobin,
            };
            let mut r = ShardRouter::new(n, policy);
            let mut live: Vec<StreamId> = Vec::new();
            let mut next = 1u64;
            for _ in 0..rng.range(1, 60) {
                if rng.chance(0.6) {
                    let id = StreamId(next);
                    next += 1;
                    let plan = r.plan(id);
                    let mut sorted = plan.clone();
                    sorted.sort_unstable();
                    if sorted != (0..n).collect::<Vec<_>>() {
                        return Err(format!("plan {plan:?} is not a permutation of 0..{n}"));
                    }
                    r.bind(id, plan[0]);
                    live.push(id);
                } else if let Some(&id) = live.first() {
                    r.unbind(id);
                    live.retain(|&x| x != id);
                }
                let mut want = vec![0usize; n];
                for &id in &live {
                    want[r.shard_of(id).ok_or("live stream lost its shard")?] += 1;
                }
                if r.load() != want.as_slice() {
                    return Err(format!("load {:?} != assigned {:?}", r.load(), want));
                }
                for s in 0..n {
                    if r.streams_on(s).len() != want[s] {
                        return Err(format!("streams_on({s}) disagrees with load"));
                    }
                }
            }
            Ok(())
        });
    }
}
