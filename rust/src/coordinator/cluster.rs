//! Sharded multi-core serving: the cluster front door over N shard
//! workers.
//!
//! DeepCoT's per-stream state is fixed-size, so scaling the engine is a
//! placement problem, not a memory problem: [`ShardedEngine`] spawns
//! `cfg.effective_shards()` copies of the single-engine serving cell
//! (`coordinator::shard`), each on its own thread with its own
//! [`SlotStepper`] backend, and [`ShardRouter`] pins every stream to
//! one shard for its whole life. Within a shard nothing changed — same
//! router, batcher, masked-lane tick — which is why a stream's outputs
//! are bitwise-identical whether it serves on a 1-shard or an N-shard
//! cluster (per-lane position clocks make them depend on nothing but
//! the stream's own history).
//!
//! Data flow:
//!
//! ```text
//!   clients ──► EngineHandle (cluster front door, Clone + Send)
//!                 │ ShardRouter: hash placement, least-loaded
//!                 │ fallback, stream → shard pinning
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1      one worker thread each
//!     Router    Router     Router         admission + idle eviction
//!     Batcher   Batcher    Batcher        deadline / all-slots ticks
//!     Stepper   Stepper    Stepper        batched scalar | PJRT
//!        │        │          │
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! The front door serializes only `open`/`close` bookkeeping (brief
//! write locks on the shard map, never held across a shard round-trip);
//! `push` takes a read lock for one map lookup and then talks straight
//! to the owning shard, so concurrent pushes to different shards never
//! serialize and the tick hot path never crosses shard boundaries.
//!
//! [`SlotStepper`]: crate::coordinator::slot_stepper::SlotStepper

use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

use anyhow::{anyhow, Result};

use crate::config::{EngineConfig, PlacementPolicy};
use crate::coordinator::metrics::ClusterMetrics;
use crate::coordinator::shard::{ShardHandle, ShardThread, TickResult};
use crate::coordinator::slots::StreamId;

/// Cluster-level placement: pins streams to shards and tracks the load
/// the front door believes each shard carries (opens minus closes). A
/// shard-side idle eviction is reconciled structurally: evictions only
/// happen while admitting a new stream, and the admitting shard's reply
/// names the victim, which `EngineHandle::open` unbinds — so abandoned
/// streams cannot leak bindings or inflate load counts. Pure
/// bookkeeping with no I/O — property-testable without threads.
#[derive(Debug)]
pub struct ShardRouter {
    policy: PlacementPolicy,
    /// Front-door-tracked stream count per shard.
    load: Vec<usize>,
    assigned: BTreeMap<StreamId, usize>,
    rr_cursor: usize,
}

impl ShardRouter {
    pub fn new(n_shards: usize, policy: PlacementPolicy) -> Self {
        assert!(n_shards >= 1, "cluster needs at least one shard");
        Self { policy, load: vec![0; n_shards], assigned: BTreeMap::new(), rr_cursor: 0 }
    }

    pub fn n_shards(&self) -> usize {
        self.load.len()
    }

    /// Fibonacci-hash the id onto a shard (deterministic, well-mixed
    /// for sequential ids).
    fn hash_shard(&self, id: StreamId) -> usize {
        ((id.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % self.load.len()
    }

    /// Shard candidates for a new stream, in preference order: the
    /// policy's primary first, then every other shard by ascending
    /// tracked load (ties to the lower index) — the least-loaded
    /// fallback chain a full primary hands the open to.
    pub fn plan(&mut self, id: StreamId) -> Vec<usize> {
        let n = self.load.len();
        let primary = match self.policy {
            PlacementPolicy::Hash => self.hash_shard(id),
            PlacementPolicy::LeastLoaded => {
                (0..n).min_by_key(|&s| (self.load[s], s)).unwrap_or(0)
            }
            PlacementPolicy::RoundRobin => {
                let s = self.rr_cursor % n;
                self.rr_cursor = (self.rr_cursor + 1) % n;
                s
            }
        };
        let mut order = Vec::with_capacity(n);
        order.push(primary);
        let mut rest: Vec<usize> = (0..n).filter(|&s| s != primary).collect();
        rest.sort_by_key(|&s| (self.load[s], s));
        order.extend(rest);
        order
    }

    pub fn bind(&mut self, id: StreamId, shard: usize) {
        self.assigned.insert(id, shard);
        self.load[shard] += 1;
    }

    pub fn shard_of(&self, id: StreamId) -> Option<usize> {
        self.assigned.get(&id).copied()
    }

    pub fn unbind(&mut self, id: StreamId) -> Option<usize> {
        let shard = self.assigned.remove(&id)?;
        self.load[shard] = self.load[shard].saturating_sub(1);
        Some(shard)
    }

    pub fn load(&self) -> &[usize] {
        &self.load
    }
}

struct FrontDoor {
    router: ShardRouter,
    next_id: u64,
    placed_primary: u64,
    placed_fallback: u64,
    cluster_rejects: u64,
}

// the front door is read-mostly on the hot path (push only needs the
// stream → shard lookup), so an RwLock keeps pushes to different shards
// from serializing on placement bookkeeping
fn read(door: &RwLock<FrontDoor>) -> RwLockReadGuard<'_, FrontDoor> {
    door.read().unwrap_or_else(|p| p.into_inner())
}

fn write(door: &RwLock<FrontDoor>) -> RwLockWriteGuard<'_, FrontDoor> {
    door.write().unwrap_or_else(|p| p.into_inner())
}

/// Cloneable, `Send` front-door handle to the shard cluster — the same
/// `open`/`push`/`close`/`metrics` surface the single-threaded engine
/// exposed, so callers are unchanged by sharding.
#[derive(Clone)]
pub struct EngineHandle {
    shards: Arc<[ShardHandle]>,
    door: Arc<RwLock<FrontDoor>>,
}

impl EngineHandle {
    /// Open a stream: assign a cluster-unique id, walk the placement
    /// plan (primary, then least-loaded fallbacks) until a shard admits
    /// it, and pin the stream there. Returns the id and output channel.
    ///
    /// The door lock is held only for id/plan assignment and for the
    /// final bind — never across the blocking shard round-trips — so an
    /// open walking a slow fallback chain cannot stall pushes to other
    /// shards.
    pub fn open(&self) -> Result<(StreamId, Receiver<TickResult>)> {
        let (id, order) = {
            let mut door = write(&self.door);
            let id = StreamId(door.next_id);
            door.next_id += 1;
            (id, door.router.plan(id))
        };
        let mut last_err = None;
        for (rank, &shard) in order.iter().enumerate() {
            match self.shards[shard].open(id) {
                Ok((rx, evicted)) => {
                    let mut door = write(&self.door);
                    if let Some(eid) = evicted {
                        // the shard reclaimed an idle session to admit
                        // us; drop the victim's front-door binding too
                        // (a no-op if its owner already closed it)
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    if rank == 0 {
                        door.placed_primary += 1;
                    } else {
                        door.placed_fallback += 1;
                    }
                    return Ok((id, rx));
                }
                Err(e) => last_err = Some(e),
            }
        }
        write(&self.door).cluster_rejects += 1;
        Err(last_err.unwrap_or_else(|| anyhow!("cluster has no shards")))
    }

    /// Submit the next token(s) for a stream (m*d_in f32s); routed to
    /// the stream's pinned shard.
    pub fn push(&self, id: StreamId, tokens: Vec<f32>) -> Result<()> {
        let shard = read(&self.door)
            .router
            .shard_of(id)
            .ok_or_else(|| anyhow!("unknown stream {id:?}"))?;
        self.shards[shard].push(id, tokens)
    }

    pub fn close(&self, id: StreamId) {
        let shard = write(&self.door).router.unbind(id);
        if let Some(s) = shard {
            self.shards[s].close(id);
        }
    }

    /// Cluster metrics: per-shard snapshots, their aggregate, and the
    /// front door's placement counters.
    pub fn metrics(&self) -> Result<ClusterMetrics> {
        let per_shard = self
            .shards
            .iter()
            .map(|s| s.metrics())
            .collect::<Result<Vec<_>>>()?;
        let mut m = ClusterMetrics::from_shards(per_shard);
        let door = read(&self.door);
        m.placed_primary = door.placed_primary;
        m.placed_fallback = door.placed_fallback;
        m.cluster_rejects = door.cluster_rejects;
        Ok(m)
    }
}

/// The sharded serving engine: N shard worker threads behind one
/// [`EngineHandle`] front door. With `cfg.shards == 1` this is exactly
/// the old single-threaded `EngineThread`.
pub struct ShardedEngine {
    shards: Vec<ShardThread>,
    handle: EngineHandle,
}

impl ShardedEngine {
    /// Spawn `cfg.effective_shards()` worker shards; blocks until every
    /// shard's model is loaded and ready (the first Push never pays
    /// compile latency). All shards are started before any is awaited,
    /// so their backends initialize in parallel.
    pub fn spawn(cfg: EngineConfig) -> Result<Self> {
        let n = cfg.effective_shards().max(1);
        let mut shards = Vec::with_capacity(n);
        for s in 0..n {
            shards.push(ShardThread::start(s, cfg.clone())?);
        }
        for t in shards.iter_mut() {
            t.wait_ready()?;
        }
        let handles: Arc<[ShardHandle]> =
            shards.iter().map(|t| t.handle()).collect::<Vec<_>>().into();
        let door = FrontDoor {
            router: ShardRouter::new(n, cfg.placement),
            next_id: 1,
            placed_primary: 0,
            placed_fallback: 0,
            cluster_rejects: 0,
        };
        let handle = EngineHandle { shards: handles, door: Arc::new(RwLock::new(door)) };
        Ok(Self { shards, handle })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Signal every shard, then join them all: each shard drains its
    /// queued requests with terminal errors before exiting, so no
    /// in-flight caller is left blocked.
    pub fn shutdown(mut self) -> Result<()> {
        for t in &self.shards {
            t.signal_shutdown();
        }
        let mut res = Ok(());
        for t in self.shards.iter_mut() {
            if let Err(e) = t.join() {
                res = Err(e);
            }
        }
        res
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        // broadcast first so shards drain in parallel; ShardThread's own
        // Drop joins each one
        for t in &self.shards {
            t.signal_shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn hash_placement_is_deterministic_and_covers_all_shards() {
        let mut r = ShardRouter::new(4, PlacementPolicy::Hash);
        for raw in 1..40u64 {
            let id = StreamId(raw);
            let a = r.plan(id);
            let b = r.plan(id);
            assert_eq!(a, b, "same id must plan identically");
            assert_eq!(a.len(), 4);
            let mut seen = a.clone();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3], "plan must cover every shard once");
        }
        // sequential ids must not all clump onto one shard
        let primaries: std::collections::BTreeSet<usize> =
            (1..40u64).map(|raw| r.plan(StreamId(raw))[0]).collect();
        assert!(primaries.len() > 1, "hash collapsed all ids to one shard");
    }

    #[test]
    fn fallbacks_are_least_loaded_first() {
        let mut r = ShardRouter::new(3, PlacementPolicy::Hash);
        let id = StreamId(7);
        let primary = r.plan(id)[0];
        // load the shards unevenly (skip the primary to keep it first)
        let others: Vec<usize> = (0..3).filter(|&s| s != primary).collect();
        r.bind(StreamId(100), others[0]);
        r.bind(StreamId(101), others[0]);
        r.bind(StreamId(102), others[1]);
        let plan = r.plan(id);
        assert_eq!(plan[0], primary);
        assert_eq!(plan[1], others[1], "lighter shard first in the fallback chain");
        assert_eq!(plan[2], others[0]);
    }

    #[test]
    fn least_loaded_policy_picks_min() {
        let mut r = ShardRouter::new(3, PlacementPolicy::LeastLoaded);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 1);
        assert_eq!(r.plan(StreamId(3))[0], 2);
        r.bind(StreamId(3), 2);
        r.bind(StreamId(4), 2);
        assert_eq!(r.plan(StreamId(5))[0], 0, "ties break to the lower index");
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = ShardRouter::new(3, PlacementPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.plan(StreamId(i))[0]).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn bind_unbind_track_load() {
        let mut r = ShardRouter::new(2, PlacementPolicy::Hash);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 0);
        r.bind(StreamId(3), 1);
        assert_eq!(r.load(), &[2, 1]);
        assert_eq!(r.shard_of(StreamId(2)), Some(0));
        assert_eq!(r.unbind(StreamId(2)), Some(0));
        assert_eq!(r.unbind(StreamId(2)), None, "double unbind is inert");
        assert_eq!(r.load(), &[1, 1]);
        assert_eq!(r.shard_of(StreamId(2)), None);
    }

    /// Property: under random bind/unbind churn the tracked load always
    /// equals the number of assigned streams per shard, and every plan
    /// is a permutation of the shard set.
    #[test]
    fn prop_router_load_accounting() {
        prop::check("shard-router-load", 150, |rng| {
            let n = rng.range(1, 5);
            let policy = match rng.below(3) {
                0 => PlacementPolicy::Hash,
                1 => PlacementPolicy::LeastLoaded,
                _ => PlacementPolicy::RoundRobin,
            };
            let mut r = ShardRouter::new(n, policy);
            let mut live: Vec<StreamId> = Vec::new();
            let mut next = 1u64;
            for _ in 0..rng.range(1, 60) {
                if rng.chance(0.6) {
                    let id = StreamId(next);
                    next += 1;
                    let plan = r.plan(id);
                    let mut sorted = plan.clone();
                    sorted.sort_unstable();
                    if sorted != (0..n).collect::<Vec<_>>() {
                        return Err(format!("plan {plan:?} is not a permutation of 0..{n}"));
                    }
                    r.bind(id, plan[0]);
                    live.push(id);
                } else if let Some(&id) = live.first() {
                    r.unbind(id);
                    live.retain(|&x| x != id);
                }
                let mut want = vec![0usize; n];
                for &id in &live {
                    want[r.shard_of(id).ok_or("live stream lost its shard")?] += 1;
                }
                if r.load() != want.as_slice() {
                    return Err(format!("load {:?} != assigned {:?}", r.load(), want));
                }
            }
            Ok(())
        });
    }
}
