//! Sharded multi-core serving: the cluster front door over N shard
//! workers, with live stream migration between them.
//!
//! DeepCoT's per-stream state is fixed-size, so scaling the engine is a
//! placement problem, not a memory problem: [`ShardedEngine`] spawns
//! `cfg.effective_shards()` copies of the single-engine serving cell
//! (`coordinator::shard`), each on its own thread with its own
//! [`SlotStepper`] backend, and [`ShardRouter`] pins every stream to
//! one shard — until a [`EngineHandle::migrate`] moves it. Within a
//! shard nothing changed — same router, batcher, masked-lane tick —
//! which is why a stream's outputs are bitwise-identical whether it
//! serves on a 1-shard or an N-shard cluster, and across a mid-run
//! migration (per-lane position clocks + portable `StreamState`
//! snapshots make them depend on nothing but the stream's own history).
//!
//! Data flow:
//!
//! ```text
//!   clients ──► Session (RAII stream handle: push / recv / drop-closes)
//!                 │
//!                 ▼
//!              EngineHandle (cluster front door, Clone + Send)
//!                 │ ShardRouter: hash placement, least-loaded
//!                 │ fallback, stream → shard pinning
//!                 │ migrate/rebalance: export → import → rebind
//!        ┌────────┼──────────┐
//!        ▼        ▼          ▼
//!     shard 0   shard 1 …  shard N-1      one worker thread each
//!     Router    Router     Router         admission + idle eviction
//!     Batcher   Batcher    Batcher        deadline / all-slots ticks
//!     Stepper   Stepper    Stepper        StreamBackend (scalar | PJRT)
//!        │        │          │
//!        └────────┴──────────┴── per-stream channels ──► TickResult
//! ```
//!
//! **Hibernation** (when `cfg.hibernate` or `cfg.state_dir` is set)
//! decouples registered streams from slot capacity: a full shard spills
//! its least-recently-active stream to the
//! [`StateStore`](crate::store::StateStore) instead of rejecting the
//! newcomer, and a push to a spilled stream transparently restores it
//! into a free lane (possibly spilling a colder victim). With a
//! `state_dir` the store is a durable on-disk log: periodic
//! [`EngineHandle::snapshot`]s checkpoint every lane-resident stream,
//! recover-on-boot re-registers everything found on disk as hibernated,
//! and [`EngineHandle::resume`] reattaches a client to a recovered
//! stream — same id, same tick ordinals, bitwise-identical outputs.
//!
//! The front door serializes only `open`/`close`/`migrate` bookkeeping
//! (write locks on the shard map); `push` takes a read lock for one map
//! lookup and then talks straight to the owning shard, so concurrent
//! pushes to different shards never serialize and the tick hot path
//! never crosses shard boundaries. A migration holds the write lock
//! across its export → import round-trip: that *is* the quiesce — no
//! push can route while the stream's state is in flight. Note the
//! blast radius: because the quiesce is the one front-door lock, a
//! migration briefly blocks routing to EVERY shard (and `rebalance`
//! repeats that once per move), bounded by one export + import
//! round-trip against otherwise-responsive shard loops; the window is
//! recorded in the quiesce histogram. A per-stream tombstone in the
//! routing map would narrow the stall to the migrating stream — see
//! ROADMAP if migration ever becomes hot-path. A push already in
//! flight to the source shard when migration starts is handed back by
//! the shard with its tokens and transparently re-routed to the
//! stream's new home.
//!
//! [`SlotStepper`]: crate::coordinator::slot_stepper::SlotStepper

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use crate::config::{EngineConfig, PlacementPolicy};
use crate::coordinator::hibernate::{self, HibernatePool};
use crate::coordinator::metrics::{ClusterMetrics, EngineMetrics, LatencyHisto};
use crate::coordinator::session::{EngineError, Session};
use crate::coordinator::shard::{ImportReason, ShardFailure, ShardHandle, ShardThread};
use crate::coordinator::slots::StreamId;
use crate::fault::{FaultInjector, FaultStore};
use crate::obs::journal::EventKind;
use crate::obs::span::Stage;
use crate::obs::ObsHandle;
use crate::store::disk::DiskStore;
use crate::store::{self, MemStore, StateStore};

/// Cluster-level placement: pins streams to shards and tracks the load
/// the front door believes each shard carries (opens minus closes). A
/// shard-side idle eviction is reconciled structurally: evictions only
/// happen while admitting a new stream, and the admitting shard's reply
/// names the victim, which `EngineHandle::open` unbinds — so abandoned
/// streams cannot leak bindings or inflate load counts. Pure
/// bookkeeping with no I/O — property-testable without threads.
#[derive(Debug)]
pub struct ShardRouter {
    policy: PlacementPolicy,
    /// Front-door-tracked stream count per shard.
    load: Vec<usize>,
    assigned: BTreeMap<StreamId, usize>,
    rr_cursor: usize,
}

impl ShardRouter {
    /// A router over `n_shards` shards with the given placement policy.
    pub fn new(n_shards: usize, policy: PlacementPolicy) -> Self {
        assert!(n_shards >= 1, "cluster needs at least one shard");
        Self { policy, load: vec![0; n_shards], assigned: BTreeMap::new(), rr_cursor: 0 }
    }

    /// Number of shards this router places over.
    pub fn n_shards(&self) -> usize {
        self.load.len()
    }

    /// Fibonacci-hash the id onto a shard (deterministic, well-mixed
    /// for sequential ids).
    fn hash_shard(&self, id: StreamId) -> usize {
        ((id.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % self.load.len()
    }

    /// Shard candidates for a new stream, in preference order: the
    /// policy's primary first, then every other shard by ascending
    /// tracked load (ties to the lower index) — the least-loaded
    /// fallback chain a full primary hands the open to.
    pub fn plan(&mut self, id: StreamId) -> Vec<usize> {
        let n = self.load.len();
        let primary = match self.policy {
            PlacementPolicy::Hash => self.hash_shard(id),
            PlacementPolicy::LeastLoaded => {
                (0..n).min_by_key(|&s| (self.load[s], s)).unwrap_or(0)
            }
            PlacementPolicy::RoundRobin => {
                let s = self.rr_cursor % n;
                self.rr_cursor = (self.rr_cursor + 1) % n;
                s
            }
        };
        let mut order = Vec::with_capacity(n);
        order.push(primary);
        let mut rest: Vec<usize> = (0..n).filter(|&s| s != primary).collect();
        rest.sort_by_key(|&s| (self.load[s], s));
        order.extend(rest);
        order
    }

    /// Pin a stream to a shard (counted toward that shard's load).
    pub fn bind(&mut self, id: StreamId, shard: usize) {
        self.assigned.insert(id, shard);
        self.load[shard] += 1;
    }

    /// The shard a stream is pinned to, if any.
    pub fn shard_of(&self, id: StreamId) -> Option<usize> {
        self.assigned.get(&id).copied()
    }

    /// Drop a stream's pinning; returns the shard it was on.
    pub fn unbind(&mut self, id: StreamId) -> Option<usize> {
        let shard = self.assigned.remove(&id)?;
        self.load[shard] = self.load[shard].saturating_sub(1);
        Some(shard)
    }

    /// Front-door-tracked stream count per shard.
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// The streams currently pinned to one shard.
    pub fn streams_on(&self, shard: usize) -> Vec<StreamId> {
        self.assigned
            .iter()
            .filter(|&(_, &s)| s == shard)
            .map(|(&id, _)| id)
            .collect()
    }
}

struct FrontDoor {
    router: ShardRouter,
    next_id: u64,
    placed_primary: u64,
    placed_fallback: u64,
    cluster_rejects: u64,
    migrations_attempted: u64,
    migrations_completed: u64,
    migrations_aborted: u64,
    quiesce_latency: LatencyHisto,
    /// Streams re-registered as hibernated by recover-on-boot.
    streams_recovered: u64,
    /// Full-cluster snapshots completed.
    snapshots_taken: u64,
    snapshot_latency: LatencyHisto,
    /// Shard worker deaths observed by the supervisor.
    shard_failures: u64,
    /// Dead shards respawned back into service.
    shards_respawned: u64,
    /// Crashed-shard streams re-homed onto their last checkpoint
    /// (portless hibernation rows; a resume revives them).
    streams_rehomed: u64,
    /// Crashed-shard streams with no checkpoint: state lost, owner told
    /// so with a typed error.
    streams_lost: u64,
    /// Store operations that failed past their retry budget — the
    /// engine kept serving in degraded mode instead of aborting.
    store_degraded: u64,
    /// Retries spent by degraded-store exponential backoff.
    store_retries: u64,
}

// the front door is read-mostly on the hot path (push only needs the
// stream → shard lookup), so an RwLock keeps pushes to different shards
// from serializing on placement bookkeeping
fn read(door: &RwLock<FrontDoor>) -> RwLockReadGuard<'_, FrontDoor> {
    door.read().unwrap_or_else(|p| p.into_inner())
}

fn write(door: &RwLock<FrontDoor>) -> RwLockWriteGuard<'_, FrontDoor> {
    door.write().unwrap_or_else(|p| p.into_inner())
}

/// What a [`EngineHandle::rebalance`] sweep did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RebalanceReport {
    /// Migrations the sweep planned from the load snapshot.
    pub planned: usize,
    /// Migrations that completed.
    pub moved: usize,
    /// Migrations that failed (stream stayed on, or returned to, its
    /// source shard when possible).
    pub failed: usize,
}

/// One shard's slot in the front door's table: the live handle behind
/// a lock (the supervisor swaps a respawned worker's handle in after a
/// crash) plus a dead flag so the hot path fails fast with a typed,
/// retryable error instead of blocking on a corpse.
struct ShardCell {
    inner: RwLock<ShardHandle>,
    dead: AtomicBool,
}

impl ShardCell {
    fn new(handle: ShardHandle) -> ShardCell {
        ShardCell { inner: RwLock::new(handle), dead: AtomicBool::new(false) }
    }

    /// The live handle, or the retryable [`EngineError::ShardFailed`]
    /// while the shard is down (the supervisor is re-homing its
    /// streams and respawning its worker).
    fn get(&self) -> Result<ShardHandle, EngineError> {
        if self.dead.load(Ordering::Acquire) {
            return Err(EngineError::ShardFailed { retryable: true });
        }
        Ok(self.inner.read().unwrap_or_else(|p| p.into_inner()).clone())
    }

    fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    fn mark_dead(&self) {
        self.dead.store(true, Ordering::Release);
    }

    /// Swap in a respawned worker's handle and clear the dead flag —
    /// called only after the crashed worker's streams were re-homed,
    /// so a retrying caller can never land on the fresh shard through
    /// a stale binding.
    fn replace(&self, handle: ShardHandle) {
        *self.inner.write().unwrap_or_else(|p| p.into_inner()) = handle;
        self.dead.store(false, Ordering::Release);
    }
}

/// Cloneable, `Send` front-door handle to the shard cluster. `open`
/// hands out RAII [`Session`]s — the only public path for pushing
/// tokens — while `metrics`, `migrate` and `rebalance` expose the
/// cluster's observability and placement controls.
#[derive(Clone)]
pub struct EngineHandle {
    shards: Arc<[ShardCell]>,
    door: Arc<RwLock<FrontDoor>>,
    obs: ObsHandle,
    /// Hibernation table + state store; `None` when neither
    /// `cfg.hibernate` nor `cfg.state_dir` is set (legacy semantics:
    /// full shards evict-or-reject).
    pool: Option<HibernatePool>,
    /// Set for good when the engine starts tearing down: from then on
    /// shard-failure errors report as [`EngineError::ShuttingDown`]
    /// (the legacy contract), while a mid-flight crash before shutdown
    /// stays the retryable [`EngineError::ShardFailed`].
    shutting_down: Arc<AtomicBool>,
    /// Deterministic fault injection; the net layer's read/write sites
    /// fire through this shared injector. Disabled = one branch per
    /// check.
    fault: FaultInjector,
}

impl EngineHandle {
    /// A live handle to `shard`, with the dead-shard error translated
    /// for the engine's lifecycle phase.
    fn shard(&self, shard: usize) -> Result<ShardHandle, EngineError> {
        self.shards[shard].get().map_err(|e| self.translate(e))
    }

    /// During real shutdown a dead shard IS the engine going down;
    /// outside it, supervision must never masquerade as shutdown (a
    /// healthy cluster reporting [`EngineError::ShuttingDown`] for one
    /// crashed shard is the poisoning this subsystem exists to stop).
    fn translate(&self, e: EngineError) -> EngineError {
        match e {
            EngineError::ShardFailed { .. } if self.shutting_down.load(Ordering::Acquire) => {
                EngineError::ShuttingDown
            }
            other => other,
        }
    }

    /// The engine's shared fault injector (net sites fire through it;
    /// a disabled injector is a single branch per check).
    pub(crate) fn fault(&self) -> FaultInjector {
        self.fault.clone()
    }

    /// Open a stream: assign a cluster-unique id, walk the placement
    /// plan (primary, then least-loaded fallbacks) until a shard admits
    /// it, and pin the stream there. Returns the RAII [`Session`] that
    /// owns the stream (closed on drop).
    ///
    /// The door lock is held only for id/plan assignment and for the
    /// final bind — never across the blocking shard round-trips — so an
    /// open walking a slow fallback chain cannot stall pushes to other
    /// shards.
    pub fn open(&self) -> Result<Session, EngineError> {
        let (id, order) = {
            let mut door = write(&self.door);
            let id = StreamId(door.next_id);
            door.next_id += 1;
            (id, door.router.plan(id))
        };
        let mut last_err = None;
        for (rank, &shard) in order.iter().enumerate() {
            let handle = match self.shard(shard) {
                Ok(h) => h,
                Err(e) => {
                    // dead shard mid-supervision: skip it, the fallback
                    // chain covers the survivors
                    last_err = Some(e);
                    continue;
                }
            };
            match handle.open(id) {
                Ok((rx, evicted)) => {
                    let mut door = write(&self.door);
                    if let Some(eid) = evicted {
                        // the shard reclaimed an idle session to admit
                        // us; drop the victim's front-door binding too
                        // (a no-op if its owner already closed it)
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    if rank == 0 {
                        door.placed_primary += 1;
                    } else {
                        door.placed_fallback += 1;
                    }
                    drop(door);
                    return Ok(Session::attach(id, rx, self.clone()));
                }
                Err(e) => last_err = Some(e),
            }
        }
        write(&self.door).cluster_rejects += 1;
        Err(self.translate(last_err.unwrap_or(EngineError::ShuttingDown)))
    }

    /// Submit the next token(s) for a stream (m*d_in f32s); routed to
    /// the stream's pinned shard. If the binding raced a live migration
    /// (the shard hands the unaccepted tokens back), the push re-routes
    /// to the stream's new shard transparently — and if the stream was
    /// hibernated (spilled by an overcommitted shard), it is restored
    /// into a lane first, possibly spilling a colder victim to make
    /// room. The pushing client notices neither.
    pub(crate) fn push_raw(&self, id: StreamId, mut tokens: Vec<f32>) -> Result<(), EngineError> {
        // bounded retries: a shard disowns a push (handing the tokens
        // back) when the stream just migrated away — the re-read of the
        // binding blocks behind the in-flight migration's write lock
        // and then routes to the stream's current home. That home can
        // legitimately be the SAME shard again (the migration aborted
        // and restored the stream), so retry on the binding, not on
        // shard inequality; a genuinely-gone stream exits via the
        // unbound binding or the retry bound.
        for _ in 0..4 {
            let shard = match read(&self.door).router.shard_of(id) {
                Some(s) => s,
                None => {
                    // unbound: transparently wake the stream if it is
                    // hibernated, then re-read the fresh binding
                    self.try_restore(id)?;
                    match read(&self.door).router.shard_of(id) {
                        Some(s) => s,
                        None => return Err(EngineError::StreamClosed(id)),
                    }
                }
            };
            match self.shard(shard)?.push(id, tokens) {
                Ok(()) => return Ok(()),
                Err((EngineError::StreamClosed(_), Some(rejected))) => tokens = rejected,
                Err((e, _)) => return Err(self.translate(e)),
            }
        }
        Err(EngineError::StreamClosed(id))
    }

    /// Wake a hibernated stream that still has a live owner: import its
    /// stored record into a lane (walking the placement plan; a full
    /// shard spills its coldest stream to make room) and rebind it. The
    /// door write lock is the quiesce, exactly as in [`Self::migrate`].
    ///
    /// Errors: [`EngineError::StreamClosed`] when the id is neither
    /// bound nor hibernated, [`EngineError::Hibernated`] when the
    /// stream exists but has no live output channel (recovered from
    /// disk after a restart — only [`Self::resume`] can mint one).
    fn try_restore(&self, id: StreamId) -> Result<(), EngineError> {
        let Some(pool) = &self.pool else {
            return Err(EngineError::StreamClosed(id));
        };
        let mut door = write(&self.door);
        if door.router.shard_of(id).is_some() {
            // a racing push already restored it while we waited
            return Ok(());
        }
        let Some((rec, port)) = pool.begin_restore(id).map_err(EngineError::internal)? else {
            return Err(EngineError::StreamClosed(id));
        };
        let Some(port) = port else {
            pool.abort_restore(id, None);
            return Err(EngineError::Hibernated(id));
        };
        let order = door.router.plan(id);
        let mut payload = Some(hibernate::payload_of(rec, port.clone(), Instant::now()));
        let mut last_err = None;
        for &shard in &order {
            let Some(p) = payload.take() else { break };
            let handle = match self.shard(shard) {
                Ok(h) => h,
                Err(e) => {
                    payload = Some(p);
                    last_err = Some(e);
                    continue;
                }
            };
            match handle.import(id, p, ImportReason::Restore) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    pool.commit_restore(id);
                    return Ok(());
                }
                Err((e, p, evicted)) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    payload = p;
                    last_err = Some(self.translate(e));
                }
            }
        }
        // nowhere to land: the stream stays hibernated and resumable
        pool.abort_restore(id, Some(port));
        Err(last_err.unwrap_or(EngineError::ShuttingDown))
    }

    /// Resume a hibernated stream that has no live owner (recovered
    /// from the state store after a restart): mint a fresh output
    /// channel, restore the stream into a lane, and hand back a
    /// [`Session`] that continues exactly where the stream left off —
    /// same id, same tick ordinals, bitwise-identical outputs.
    ///
    /// A stream whose original owner still holds its channel cannot be
    /// resumed (that would silently steal its output); pushes from that
    /// owner wake it transparently instead.
    pub fn resume(&self, id: StreamId) -> Result<Session, EngineError> {
        let Some(pool) = &self.pool else {
            return Err(EngineError::InvalidRequest(
                "resume requires hibernation (set hibernate or state_dir)".to_string(),
            ));
        };
        let mut door = write(&self.door);
        if door.router.shard_of(id).is_some() {
            return Err(EngineError::InvalidRequest(format!(
                "stream {} is live; resume only applies to hibernated streams",
                id.0
            )));
        }
        let Some((rec, old_port)) = pool.begin_restore(id).map_err(EngineError::internal)? else {
            return Err(EngineError::StreamClosed(id));
        };
        if let Some(port) = old_port {
            pool.abort_restore(id, Some(port));
            return Err(EngineError::InvalidRequest(format!(
                "stream {} still has a live owner; it wakes on push, not resume",
                id.0
            )));
        }
        let (tx, rx) = mpsc::channel();
        let order = door.router.plan(id);
        let mut payload = Some(hibernate::payload_of(rec, tx, Instant::now()));
        let mut last_err = None;
        for &shard in &order {
            let Some(p) = payload.take() else { break };
            let handle = match self.shard(shard) {
                Ok(h) => h,
                Err(e) => {
                    payload = Some(p);
                    last_err = Some(e);
                    continue;
                }
            };
            match handle.import(id, p, ImportReason::Restore) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.bind(id, shard);
                    pool.commit_restore(id);
                    drop(door);
                    return Ok(Session::attach(id, rx, self.clone()));
                }
                Err((e, p, evicted)) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    payload = p;
                    last_err = Some(self.translate(e));
                }
            }
        }
        pool.abort_restore(id, None);
        Err(last_err.unwrap_or(EngineError::ShuttingDown))
    }

    /// Whether a stream is currently hibernated (no lane anywhere; its
    /// state lives in the store and wakes on push or resume).
    pub fn is_hibernated(&self, id: StreamId) -> bool {
        self.pool.as_ref().map_or(false, |p| p.contains(id))
    }

    /// Every currently hibernated stream id (ascending).
    pub fn hibernated_streams(&self) -> Vec<StreamId> {
        self.pool.as_ref().map(|p| p.ids()).unwrap_or_default()
    }

    /// Checkpoint every lane-resident stream to the state store and
    /// flush it: export each bound stream, persist its record, and put
    /// it straight back in its lane (counter-neutral — the stream never
    /// logically moved; its owner keeps pushing through the snapshot).
    /// Hibernated streams are already durable, so after a snapshot the
    /// store holds every registered stream and a crash loses nothing.
    ///
    /// Returns the number of streams checkpointed. A no-op `Ok(0)`
    /// without a configured pool.
    pub fn snapshot(&self) -> Result<usize, EngineError> {
        let Some(pool) = &self.pool else {
            return Ok(0);
        };
        let t0 = Instant::now();
        let mut door = write(&self.door);
        let bound: Vec<(StreamId, usize)> = (0..self.shards.len())
            .flat_map(|s| door.router.streams_on(s).into_iter().map(move |id| (id, s)))
            .collect();
        let mut n = 0usize;
        for (id, shard) in bound {
            // a dead shard's streams belong to the supervisor now; the
            // re-home path keys off their LAST checkpoint, so skipping
            // them here is correct, not lossy
            let Ok(handle) = self.shard(shard) else { continue };
            let payload = match handle.export(id, false) {
                Ok(p) => p,
                // the stream closed between the load snapshot and now
                Err(_) => continue,
            };
            let rec = hibernate::record_of(id, &payload);
            let (ckpt, retries) =
                store::with_retries(3, Duration::from_millis(10), || pool.checkpoint(&rec));
            door.store_retries += u64::from(retries);
            match handle.import(id, payload, ImportReason::Snapshot) {
                Ok(evicted) => {
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                }
                Err((_, payload, evicted)) => {
                    // an open racing its lock-free shard round-trip took
                    // the freed slot; park the stream as hibernated
                    // rather than lose it (its channel stays live)
                    if let Some(eid) = evicted {
                        door.router.unbind(eid);
                    }
                    door.router.unbind(id);
                    if let Some(p) = payload {
                        let port = p.port.clone();
                        let rec = hibernate::record_of(id, &p);
                        let _ = pool.spill(&rec, port);
                    }
                }
            }
            match ckpt {
                Ok(()) => n += 1,
                Err(e) => {
                    // degraded mode: a failing store must not abort the
                    // snapshot sweep, let alone the engine — journal it,
                    // meter it, keep serving
                    door.store_degraded += 1;
                    let aux = u64::from(retries);
                    self.obs.event(EventKind::StoreDegraded, id.0, shard as i64, aux);
                    eprintln!(
                        "deepcot: degraded store: checkpoint of stream {} failed after \
                         {retries} retries: {e} — serving continues",
                        id.0
                    );
                }
            }
        }
        let (synced, retries) = store::with_retries(3, Duration::from_millis(10), || pool.sync());
        door.store_retries += u64::from(retries);
        if let Err(e) = synced {
            door.store_degraded += 1;
            self.obs.event(EventKind::StoreDegraded, 0, -1, u64::from(retries));
            eprintln!(
                "deepcot: degraded store: snapshot sync failed after {retries} retries: {e} — \
                 durability is behind, serving continues"
            );
        }
        door.snapshots_taken += 1;
        let dt = t0.elapsed();
        door.snapshot_latency.record(dt);
        drop(door);
        self.obs.event(EventKind::Snapshot, 0, -1, n as u64);
        Ok(n)
    }

    /// Close a stream by id (sessions call this on drop). Hibernated
    /// streams are forgotten entirely — table row and stored blob.
    pub(crate) fn close_raw(&self, id: StreamId) {
        let shard = write(&self.door).router.unbind(id);
        if let Some(s) = shard {
            // a dead shard has nothing to close; the binding is gone
            // either way and the blob removal below still runs
            if let Ok(h) = self.shards[s].get() {
                h.close(id);
            }
        }
        if let Some(pool) = &self.pool {
            let _ = pool.remove(id);
        }
    }

    /// Number of shards behind this front door.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The cluster's observability handle (level, journal, exposition
    /// sequence / rate state) — shared by every shard and the net layer.
    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// The shard a stream currently serves on (observability; may be
    /// stale by the time the caller acts on it).
    pub fn shard_of(&self, id: StreamId) -> Option<usize> {
        read(&self.door).router.shard_of(id)
    }

    /// Snapshot of the front-door-tracked stream count per shard.
    pub fn shard_loads(&self) -> Vec<usize> {
        read(&self.door).router.load().to_vec()
    }

    /// Live-migrate a stream to another shard: quiesce it (no push can
    /// route while the write lock is held), export its portable
    /// [`StreamState`] snapshot — K/V rings, position clock, queued
    /// tokens, output port — from the source shard, import on the
    /// target, and rebind the front door. The stream's owner notices
    /// nothing: its `Session` keeps pushing and receiving, and outputs
    /// stay bitwise-identical to an unmigrated run.
    ///
    /// On failure the stream is left (or put back) on its source shard
    /// whenever possible; the attempt is counted in the migration
    /// metrics either way. A migrate to the stream's current shard is
    /// an uncounted no-op.
    ///
    /// [`StreamState`]: crate::coordinator::slot_stepper::StreamState
    pub fn migrate(&self, id: StreamId, to_shard: usize) -> Result<(), EngineError> {
        if to_shard >= self.shards.len() {
            return Err(EngineError::InvalidRequest(format!(
                "shard {to_shard} out of range ({} shards)",
                self.shards.len()
            )));
        }
        let t0 = Instant::now();
        let mut door = write(&self.door);
        let Some(from) = door.router.shard_of(id) else {
            door.migrations_attempted += 1;
            door.migrations_aborted += 1;
            self.obs.event(EventKind::MigrationAttempt, id.0, -1, to_shard as u64);
            self.obs.event(EventKind::MigrationAbort, id.0, -1, to_shard as u64);
            return Err(EngineError::StreamClosed(id));
        };
        if from == to_shard {
            // already home: an uncounted no-op, so degenerate requests
            // (e.g. a 1-shard round-robin hop) don't skew the counters
            // or drag the quiesce histogram toward zero
            return Ok(());
        }
        door.migrations_attempted += 1;
        self.obs.event(EventKind::MigrationAttempt, id.0, from as i64, to_shard as u64);
        // both endpoints must be alive before state starts moving; a
        // dead endpoint aborts with the stream untouched on its source
        let (src, dst) = match (self.shard(from), self.shard(to_shard)) {
            (Ok(s), Ok(d)) => (s, d),
            (Err(e), _) | (_, Err(e)) => {
                door.migrations_aborted += 1;
                self.obs.event(EventKind::MigrationAbort, id.0, from as i64, to_shard as u64);
                return Err(e);
            }
        };
        // export atomically detaches the stream from its source shard
        // (or fails with the stream still serving there, untouched)
        let payload = match src.export(id, true) {
            Ok(p) => p,
            Err(e) => {
                door.migrations_aborted += 1;
                self.obs.event(EventKind::MigrationAbort, id.0, from as i64, to_shard as u64);
                return Err(self.translate(e));
            }
        };
        door.router.unbind(id);
        match dst.import(id, payload, ImportReason::Migrate) {
            Ok(evicted) => {
                if let Some(eid) = evicted {
                    door.router.unbind(eid);
                }
                door.router.bind(id, to_shard);
                door.migrations_completed += 1;
                let quiesce = t0.elapsed();
                door.quiesce_latency.record(quiesce);
                self.obs.event(
                    EventKind::MigrationComplete,
                    id.0,
                    to_shard as i64,
                    quiesce.as_micros() as u64,
                );
                Ok(())
            }
            Err((e, mut payload, evicted)) => {
                if let Some(eid) = evicted {
                    // a failed import may still have evicted an idle
                    // victim during admission — its binding must go
                    door.router.unbind(eid);
                }
                door.migrations_aborted += 1;
                self.obs.event(EventKind::MigrationAbort, id.0, from as i64, to_shard as u64);
                // abort: put the stream back on its source shard. The
                // slot the export freed is USUALLY still free, but an
                // open racing its lock-free shard round-trip can have
                // taken it — so if the source rejects, rescue the
                // stream onto any other shard with room rather than
                // dropping a live stream; only when every shard is
                // full does the owner see a disconnected channel.
                // `rollback` (source only) un-counts the export so an
                // aborted migration leaves its counters untouched.
                let rescue: Vec<usize> = std::iter::once(from)
                    .chain((0..self.shards.len()).filter(|&s| s != from && s != to_shard))
                    .collect();
                for shard in rescue {
                    let Some(p) = payload.take() else { break };
                    let Ok(handle) = self.shards[shard].get() else {
                        payload = Some(p);
                        continue;
                    };
                    let reason = if shard == from {
                        ImportReason::MigrateRollback
                    } else {
                        ImportReason::Migrate
                    };
                    match handle.import(id, p, reason) {
                        Ok(evicted) => {
                            if let Some(eid) = evicted {
                                door.router.unbind(eid);
                            }
                            door.router.bind(id, shard);
                            break;
                        }
                        Err((_, p, evicted)) => {
                            if let Some(eid) = evicted {
                                door.router.unbind(eid);
                            }
                            payload = p;
                        }
                    }
                }
                Err(self.translate(e))
            }
        }
    }

    /// One placement sweep against load skew: plan migrations from the
    /// current load snapshot until no shard holds ≥2 more streams than
    /// the lightest one, then execute them via [`Self::migrate`]. Safe
    /// to call on a live cluster (long-lived sessions keep serving
    /// through their moves); a no-op on balanced clusters.
    pub fn rebalance(&self) -> Result<RebalanceReport, EngineError> {
        let moves: Vec<(StreamId, usize)> = {
            let door = read(&self.door);
            let n = door.router.n_shards();
            let mut load = door.router.load().to_vec();
            let mut movable: Vec<Vec<StreamId>> =
                (0..n).map(|s| door.router.streams_on(s)).collect();
            let mut moves = Vec::new();
            loop {
                let Some(max_s) = (0..n).max_by_key(|&s| load[s]) else {
                    break;
                };
                let Some(min_s) = (0..n).min_by_key(|&s| load[s]) else {
                    break;
                };
                if load[max_s] <= load[min_s] + 1 {
                    break;
                }
                let Some(id) = movable[max_s].pop() else {
                    break;
                };
                moves.push((id, min_s));
                load[max_s] -= 1;
                load[min_s] += 1;
            }
            moves
        };
        let mut report = RebalanceReport { planned: moves.len(), ..Default::default() };
        for (id, to) in moves {
            // a stream may have closed since planning; count that as a
            // failed move rather than erroring the whole sweep
            match self.migrate(id, to) {
                Ok(()) => report.moved += 1,
                Err(_) => report.failed += 1,
            }
        }
        Ok(report)
    }

    /// Cluster metrics: per-shard snapshots, their aggregate, and the
    /// front door's placement + migration counters.
    pub fn metrics(&self) -> Result<ClusterMetrics, EngineError> {
        // a dead shard must not blind the whole cluster's metrics
        // (supervision is exactly when operators need them); it
        // contributes an empty snapshot until its respawn reports in
        let per_shard: Vec<EngineMetrics> = self
            .shards
            .iter()
            .map(|cell| {
                cell.get()
                    .and_then(|h| h.metrics())
                    .unwrap_or_else(|_| EngineMetrics::new())
            })
            .collect();
        let mut m = ClusterMetrics::from_shards(per_shard);
        let door = read(&self.door);
        m.placed_primary = door.placed_primary;
        m.placed_fallback = door.placed_fallback;
        m.cluster_rejects = door.cluster_rejects;
        m.migrations_attempted = door.migrations_attempted;
        m.migrations_completed = door.migrations_completed;
        m.migrations_aborted = door.migrations_aborted;
        m.quiesce_latency = door.quiesce_latency.clone();
        m.streams_recovered = door.streams_recovered;
        m.snapshots_taken = door.snapshots_taken;
        m.snapshot_latency = door.snapshot_latency.clone();
        m.shard_failures = door.shard_failures;
        m.shards_respawned = door.shards_respawned;
        m.streams_rehomed = door.streams_rehomed;
        m.streams_lost = door.streams_lost;
        m.store_degraded = door.store_degraded;
        m.store_retries = door.store_retries;
        drop(door);
        m.shards_dead = self.shards.iter().filter(|c| c.is_dead()).count() as u64;
        if let Some(pool) = &self.pool {
            m.hibernated_resident = pool.resident() as u64;
        }
        m.uptime = self.obs.uptime();
        m.boot_unix_ms = self.obs.boot_unix_ms();
        if self.obs.spans_on() {
            // the quiesce + snapshot windows are front-door spans, not
            // shard ones; fold them into the stage family so exposition
            // sees one table
            m.stage_spans.merge_histo(Stage::MigQuiesce, &m.quiesce_latency);
            m.stage_spans.merge_histo(Stage::Snapshot, &m.snapshot_latency);
        }
        Ok(m)
    }
}

/// How many times the supervisor tries to respawn a crashed shard
/// worker (10 ms exponential backoff between attempts) before leaving
/// it dead — the rest of the cluster keeps serving either way.
const RESPAWN_ATTEMPTS: u32 = 8;

/// The crash supervisor: a dedicated thread that owns the failure
/// channel every shard worker reports into. On a worker panic it (1)
/// marks the shard dead so the front door fails fast with the
/// retryable [`EngineError::ShardFailed`], (2) re-homes the dead
/// shard's streams — checkpointed ones become portless hibernation
/// rows that a push/resume revives on a survivor from their last
/// checkpoint; un-checkpointed ones are counted lost so their owners
/// get a typed error instead of a hang — and (3) respawns the worker
/// and swaps its fresh handle into the shard's cell.
struct Supervisor {
    cfg: EngineConfig,
    handle: EngineHandle,
    workers: Arc<Mutex<Vec<ShardThread>>>,
    /// Respawned workers report failures into the same channel; the
    /// supervisor holding this clone means `recv` never disconnects
    /// while shards can still crash.
    fail_tx: Sender<ShardFailure>,
}

impl Supervisor {
    fn shutting_down(&self) -> bool {
        self.handle.shutting_down.load(Ordering::Acquire)
    }

    fn run(self, fail_rx: mpsc::Receiver<ShardFailure>) {
        // poll with a timeout rather than blocking forever: the
        // supervisor holds a fail_tx clone (for respawns), so the
        // Disconnected arm alone can never end this loop
        loop {
            match fail_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(f) => {
                    if self.shutting_down() {
                        return;
                    }
                    self.handle_failure(f);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.shutting_down() {
                        return;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    fn handle_failure(&self, f: ShardFailure) {
        let shard = f.shard;
        eprintln!("deepcot: shard {shard} worker died ({}); supervising", f.reason);
        // fail fast first: every request routed at this shard from now
        // on gets the retryable typed error instead of blocking
        self.handle.shards[shard].mark_dead();
        self.handle.obs.event(EventKind::ShardPanic, 0, shard as i64, 0);
        // re-home under the door write lock — the same quiesce a
        // migration uses, so no push can race the rebinding
        {
            let mut door = write(&self.handle.door);
            door.shard_failures += 1;
            let orphans = door.router.streams_on(shard);
            for id in orphans {
                door.router.unbind(id);
                let ticks =
                    self.handle.pool.as_ref().and_then(|p| p.checkpoint_ticks(id));
                match (&self.handle.pool, ticks) {
                    (Some(pool), Some(ticks)) => {
                        // last checkpoint exists: park the stream as a
                        // portless hibernation row — the owner's next
                        // push (or an OPEN-resume) restores it onto a
                        // survivor at exactly that checkpoint
                        pool.register_orphan(id);
                        door.streams_rehomed += 1;
                        self.handle.obs.event(EventKind::StreamRehomed, id.0, shard as i64, ticks);
                    }
                    _ => {
                        // no checkpoint: the state died with the worker.
                        // The unbind above makes the owner's next push
                        // return StreamClosed (typed, immediate) rather
                        // than hang on a dead channel
                        door.streams_lost += 1;
                        self.handle.obs.event(EventKind::StreamLost, id.0, shard as i64, 0);
                    }
                }
            }
        }
        // respawn with bounded exponential backoff; a persistent crash
        // (e.g. a deterministic fault plan that kills every respawn at
        // tick N) leaves the shard dead and the survivors serving
        let mut delay = Duration::from_millis(10);
        for attempt in 1..=RESPAWN_ATTEMPTS {
            if self.shutting_down() {
                return;
            }
            match self.respawn(shard) {
                Ok(()) => {
                    let total = {
                        let mut door = write(&self.handle.door);
                        door.shards_respawned += 1;
                        door.shards_respawned
                    };
                    self.handle.obs.event(EventKind::ShardRespawn, 0, shard as i64, total);
                    eprintln!("deepcot: shard {shard} respawned (attempt {attempt})");
                    return;
                }
                Err(e) => {
                    eprintln!(
                        "deepcot: shard {shard} respawn attempt {attempt}/{RESPAWN_ATTEMPTS} \
                         failed: {e}"
                    );
                    std::thread::sleep(delay);
                    delay = delay.saturating_mul(2);
                }
            }
        }
        eprintln!(
            "deepcot: shard {shard} left dead after {RESPAWN_ATTEMPTS} respawn attempts; \
             surviving shards keep serving"
        );
    }

    fn respawn(&self, shard: usize) -> Result<(), EngineError> {
        let mut t = ShardThread::start(
            shard,
            self.cfg.clone(),
            self.handle.obs.clone(),
            self.handle.pool.clone(),
            self.fail_tx.clone(),
            // the engine-wide injector: a respawned worker continues
            // the fault schedule, it does not restart it
            self.handle.fault.clone(),
        )?;
        t.wait_ready()?;
        let fresh = t.handle();
        // park the new worker where the corpse was; the old thread
        // already exited, so its Drop-join returns immediately
        let old = {
            let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::replace(&mut workers[shard], t)
        };
        drop(old);
        // only now — streams re-homed, worker ready — does the cell go
        // live again, so a retrying push can't land on the fresh shard
        // through a stale binding
        self.handle.shards[shard].replace(fresh.clone());
        if self.shutting_down() {
            // teardown raced the respawn: the fresh worker missed the
            // shutdown broadcast, so deliver it ourselves (shutdown's
            // second broadcast also covers this; signaling is idempotent)
            fresh.signal_shutdown();
        }
        Ok(())
    }
}

/// The sharded serving engine: N shard worker threads behind one
/// [`EngineHandle`] front door, plus a supervisor thread that re-homes
/// streams off crashed workers and respawns them. With
/// `cfg.shards == 1` this is exactly the old single-threaded
/// `EngineThread` — with a safety net.
pub struct ShardedEngine {
    /// Shared with the supervisor, which swaps respawned workers in.
    workers: Arc<Mutex<Vec<ShardThread>>>,
    handle: EngineHandle,
    supervisor: Option<std::thread::JoinHandle<()>>,
}

impl ShardedEngine {
    /// Spawn `cfg.effective_shards()` worker shards; blocks until every
    /// shard's model is loaded and ready (the first Push never pays
    /// compile latency). All shards are started before any is awaited,
    /// so their backends initialize in parallel.
    pub fn spawn(cfg: EngineConfig) -> Result<Self, EngineError> {
        let n = cfg.effective_shards().max(1);
        let obs = ObsHandle::new(cfg.obs);
        let fault = FaultInjector::from_plan(&cfg.fault);
        // with injection armed the state store is wrapped so its
        // put/get/sync sites can fail on schedule; disabled plans keep
        // the store untouched (zero overhead, identical code path)
        let wrap = |inner: Box<dyn StateStore>,
                    torn: Option<std::path::PathBuf>|
         -> Box<dyn StateStore> {
            if fault.enabled() {
                Box::new(FaultStore::new(inner, fault.clone(), torn))
            } else {
                inner
            }
        };
        let pool = match (&cfg.state_dir, cfg.hibernate) {
            (Some(dir), _) => {
                std::fs::create_dir_all(dir).map_err(EngineError::internal)?;
                let path = dir.join("streams.log");
                let store = DiskStore::open(&path).map_err(EngineError::internal)?;
                Some(HibernatePool::new(wrap(Box::new(store), Some(path))))
            }
            (None, true) => Some(HibernatePool::new(wrap(Box::new(MemStore::new()), None))),
            (None, false) => None,
        };
        // recover-on-boot: every stream a previous run persisted is
        // re-registered as hibernated (portless until resumed), and the
        // id counter moves past them so new opens never collide
        let mut next_id = 1u64;
        let mut recovered = 0u64;
        if let Some(pool) = &pool {
            for raw in pool.stored_ids().map_err(EngineError::internal)? {
                pool.register_recovered(StreamId(raw));
                next_id = next_id.max(raw + 1);
                recovered += 1;
            }
        }
        let (fail_tx, fail_rx) = mpsc::channel::<ShardFailure>();
        let mut workers = Vec::with_capacity(n);
        for s in 0..n {
            workers.push(ShardThread::start(
                s,
                cfg.clone(),
                obs.clone(),
                pool.clone(),
                fail_tx.clone(),
                fault.clone(),
            )?);
        }
        for t in workers.iter_mut() {
            t.wait_ready()?;
        }
        let cells: Arc<[ShardCell]> =
            workers.iter().map(|t| ShardCell::new(t.handle())).collect::<Vec<_>>().into();
        let door = FrontDoor {
            router: ShardRouter::new(n, cfg.placement),
            next_id,
            placed_primary: 0,
            placed_fallback: 0,
            cluster_rejects: 0,
            migrations_attempted: 0,
            migrations_completed: 0,
            migrations_aborted: 0,
            quiesce_latency: LatencyHisto::new(),
            streams_recovered: recovered,
            snapshots_taken: 0,
            snapshot_latency: LatencyHisto::new(),
            shard_failures: 0,
            shards_respawned: 0,
            streams_rehomed: 0,
            streams_lost: 0,
            store_degraded: 0,
            store_retries: 0,
        };
        let handle = EngineHandle {
            shards: cells,
            door: Arc::new(RwLock::new(door)),
            obs,
            pool,
            shutting_down: Arc::new(AtomicBool::new(false)),
            fault,
        };
        let workers = Arc::new(Mutex::new(workers));
        let sup = Supervisor {
            cfg,
            handle: handle.clone(),
            workers: Arc::clone(&workers),
            fail_tx,
        };
        let supervisor = std::thread::Builder::new()
            .name("deepcot-supervisor".to_string())
            .spawn(move || sup.run(fail_rx))
            .map_err(EngineError::internal)?;
        Ok(Self { workers, handle, supervisor: Some(supervisor) })
    }

    /// A cloneable front-door handle.
    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Number of worker shards.
    pub fn n_shards(&self) -> usize {
        self.handle.shards.len()
    }

    /// Live-migrate a stream to another shard (see
    /// [`EngineHandle::migrate`]).
    pub fn migrate(&self, id: StreamId, to_shard: usize) -> Result<(), EngineError> {
        self.handle.migrate(id, to_shard)
    }

    /// Run one load-skew rebalancing sweep (see
    /// [`EngineHandle::rebalance`]).
    pub fn rebalance(&self) -> Result<RebalanceReport, EngineError> {
        self.handle.rebalance()
    }

    /// Signal every shard, then join them all: each shard drains its
    /// queued requests with terminal errors before exiting, so no
    /// in-flight caller is left blocked. The supervisor is retired
    /// first (flag, then join) so a crash racing the teardown can't
    /// respawn a worker nobody will join.
    pub fn shutdown(mut self) -> Result<(), EngineError> {
        self.handle.shutting_down.store(true, Ordering::Release);
        {
            let workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
            for t in workers.iter() {
                t.signal_shutdown();
            }
        }
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
        // second broadcast + join under one lock: a worker the
        // supervisor respawned after the first broadcast missed it, and
        // signaling an already-draining shard is a harmless extra
        // Shutdown message
        let mut res = Ok(());
        let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
        for t in workers.iter() {
            t.signal_shutdown();
        }
        for t in workers.iter_mut() {
            if let Err(e) = t.join() {
                res = Err(e);
            }
        }
        res
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        // broadcast first so shards drain in parallel; dropping the
        // workers vec joins each one via ShardThread's own Drop
        self.handle.shutting_down.store(true, Ordering::Release);
        {
            let workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
            for t in workers.iter() {
                t.signal_shutdown();
            }
        }
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn hash_placement_is_deterministic_and_covers_all_shards() {
        let mut r = ShardRouter::new(4, PlacementPolicy::Hash);
        for raw in 1..40u64 {
            let id = StreamId(raw);
            let a = r.plan(id);
            let b = r.plan(id);
            assert_eq!(a, b, "same id must plan identically");
            assert_eq!(a.len(), 4);
            let mut seen = a.clone();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3], "plan must cover every shard once");
        }
        // sequential ids must not all clump onto one shard
        let primaries: std::collections::BTreeSet<usize> =
            (1..40u64).map(|raw| r.plan(StreamId(raw))[0]).collect();
        assert!(primaries.len() > 1, "hash collapsed all ids to one shard");
    }

    #[test]
    fn fallbacks_are_least_loaded_first() {
        let mut r = ShardRouter::new(3, PlacementPolicy::Hash);
        let id = StreamId(7);
        let primary = r.plan(id)[0];
        // load the shards unevenly (skip the primary to keep it first)
        let others: Vec<usize> = (0..3).filter(|&s| s != primary).collect();
        r.bind(StreamId(100), others[0]);
        r.bind(StreamId(101), others[0]);
        r.bind(StreamId(102), others[1]);
        let plan = r.plan(id);
        assert_eq!(plan[0], primary);
        assert_eq!(plan[1], others[1], "lighter shard first in the fallback chain");
        assert_eq!(plan[2], others[0]);
    }

    #[test]
    fn least_loaded_policy_picks_min() {
        let mut r = ShardRouter::new(3, PlacementPolicy::LeastLoaded);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 1);
        assert_eq!(r.plan(StreamId(3))[0], 2);
        r.bind(StreamId(3), 2);
        r.bind(StreamId(4), 2);
        assert_eq!(r.plan(StreamId(5))[0], 0, "ties break to the lower index");
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = ShardRouter::new(3, PlacementPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.plan(StreamId(i))[0]).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn bind_unbind_track_load() {
        let mut r = ShardRouter::new(2, PlacementPolicy::Hash);
        r.bind(StreamId(1), 0);
        r.bind(StreamId(2), 0);
        r.bind(StreamId(3), 1);
        assert_eq!(r.load(), &[2, 1]);
        assert_eq!(r.shard_of(StreamId(2)), Some(0));
        assert_eq!(r.streams_on(0), vec![StreamId(1), StreamId(2)]);
        assert_eq!(r.streams_on(1), vec![StreamId(3)]);
        assert_eq!(r.unbind(StreamId(2)), Some(0));
        assert_eq!(r.unbind(StreamId(2)), None, "double unbind is inert");
        assert_eq!(r.load(), &[1, 1]);
        assert_eq!(r.shard_of(StreamId(2)), None);
        assert_eq!(r.streams_on(0), vec![StreamId(1)]);
    }

    #[test]
    fn rebind_models_migration() {
        let mut r = ShardRouter::new(2, PlacementPolicy::Hash);
        r.bind(StreamId(1), 0);
        assert_eq!(r.unbind(StreamId(1)), Some(0));
        r.bind(StreamId(1), 1);
        assert_eq!(r.shard_of(StreamId(1)), Some(1));
        assert_eq!(r.load(), &[0, 1]);
    }

    /// Property: under random bind/unbind churn the tracked load always
    /// equals the number of assigned streams per shard, and every plan
    /// is a permutation of the shard set.
    #[test]
    fn prop_router_load_accounting() {
        prop::check("shard-router-load", 150, |rng| {
            let n = rng.range(1, 5);
            let policy = match rng.below(3) {
                0 => PlacementPolicy::Hash,
                1 => PlacementPolicy::LeastLoaded,
                _ => PlacementPolicy::RoundRobin,
            };
            let mut r = ShardRouter::new(n, policy);
            let mut live: Vec<StreamId> = Vec::new();
            let mut next = 1u64;
            for _ in 0..rng.range(1, 60) {
                if rng.chance(0.6) {
                    let id = StreamId(next);
                    next += 1;
                    let plan = r.plan(id);
                    let mut sorted = plan.clone();
                    sorted.sort_unstable();
                    if sorted != (0..n).collect::<Vec<_>>() {
                        return Err(format!("plan {plan:?} is not a permutation of 0..{n}"));
                    }
                    r.bind(id, plan[0]);
                    live.push(id);
                } else if let Some(&id) = live.first() {
                    r.unbind(id);
                    live.retain(|&x| x != id);
                }
                let mut want = vec![0usize; n];
                for &id in &live {
                    want[r.shard_of(id).ok_or("live stream lost its shard")?] += 1;
                }
                if r.load() != want.as_slice() {
                    return Err(format!("load {:?} != assigned {:?}", r.load(), want));
                }
                for s in 0..n {
                    if r.streams_on(s).len() != want[s] {
                        return Err(format!("streams_on({s}) disagrees with load"));
                    }
                }
            }
            Ok(())
        });
    }
}
