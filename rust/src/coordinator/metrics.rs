//! Serving metrics: lock-free-ish counters and a log-bucketed latency
//! histogram (hand-rolled; no external metrics crates offline).

use std::time::Duration;

/// Log2-bucketed latency histogram from 1µs to ~68s.
#[derive(Debug, Clone)]
pub struct LatencyHisto {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHisto {
    pub fn new() -> Self {
        Self { buckets: vec![0; 27], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket containing quantile q (conservative).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(self.max_us)
    }

    pub fn merge(&mut self, other: &LatencyHisto) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Per-shard serving counters, owned by one shard worker thread.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub ticks: u64,
    pub tokens_in: u64,
    pub outputs: u64,
    pub streams_opened: u64,
    pub streams_closed: u64,
    /// idle sessions reclaimed by admission (distinct from explicit closes)
    pub streams_evicted: u64,
    pub admission_rejects: u64,
    pub tick_latency: LatencyHisto,
    /// time a token waits in the batcher before its tick starts
    pub queue_latency: LatencyHisto,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self { tick_latency: LatencyHisto::new(), queue_latency: LatencyHisto::new(), ..Default::default() }
    }

    /// Fold another shard's counters into this one (histograms merge
    /// bucket-wise) — the cluster aggregate is a plain sum of shards.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.ticks += other.ticks;
        self.tokens_in += other.tokens_in;
        self.outputs += other.outputs;
        self.streams_opened += other.streams_opened;
        self.streams_closed += other.streams_closed;
        self.streams_evicted += other.streams_evicted;
        self.admission_rejects += other.admission_rejects;
        self.tick_latency.merge(&other.tick_latency);
        self.queue_latency.merge(&other.queue_latency);
    }

    pub fn report(&self) -> String {
        format!(
            "ticks={} tokens={} outputs={} streams={}/{} evicted={} rejects={} \
             tick(mean={:?} p50={:?} p95={:?} max={:?}) queue(p95={:?})",
            self.ticks,
            self.tokens_in,
            self.outputs,
            self.streams_opened,
            self.streams_closed,
            self.streams_evicted,
            self.admission_rejects,
            self.tick_latency.mean(),
            self.tick_latency.quantile(0.5),
            self.tick_latency.quantile(0.95),
            self.tick_latency.max(),
            self.queue_latency.quantile(0.95),
        )
    }
}

/// Cluster-wide serving metrics: the per-shard [`EngineMetrics`] plus
/// their sum and the front door's placement counters. The aggregate
/// fields mirror `EngineMetrics` name-for-name, so code written against
/// the single-engine metrics keeps reading the same fields and now sees
/// cluster totals.
#[derive(Debug, Clone, Default)]
pub struct ClusterMetrics {
    pub ticks: u64,
    pub tokens_in: u64,
    pub outputs: u64,
    pub streams_opened: u64,
    pub streams_closed: u64,
    pub streams_evicted: u64,
    pub admission_rejects: u64,
    pub tick_latency: LatencyHisto,
    pub queue_latency: LatencyHisto,
    /// Per-shard breakdown (index = shard id).
    pub per_shard: Vec<EngineMetrics>,
    /// Streams placed on their policy-preferred shard.
    pub placed_primary: u64,
    /// Streams placed on a fallback shard (primary was full).
    pub placed_fallback: u64,
    /// Opens rejected by every shard (cluster saturated).
    pub cluster_rejects: u64,
}

impl ClusterMetrics {
    /// Build the aggregate view from per-shard snapshots; the front
    /// door fills the placement counters afterwards.
    pub fn from_shards(per_shard: Vec<EngineMetrics>) -> Self {
        let mut agg = EngineMetrics::new();
        for m in &per_shard {
            agg.merge(m);
        }
        Self {
            ticks: agg.ticks,
            tokens_in: agg.tokens_in,
            outputs: agg.outputs,
            streams_opened: agg.streams_opened,
            streams_closed: agg.streams_closed,
            streams_evicted: agg.streams_evicted,
            admission_rejects: agg.admission_rejects,
            tick_latency: agg.tick_latency,
            queue_latency: agg.queue_latency,
            per_shard,
            placed_primary: 0,
            placed_fallback: 0,
            cluster_rejects: 0,
        }
    }

    /// The aggregate counters as one `EngineMetrics` view, built from
    /// the stored totals (the single source of truth after
    /// `from_shards`) — not re-derived from `per_shard`, so the two can
    /// never silently diverge.
    pub fn aggregate(&self) -> EngineMetrics {
        EngineMetrics {
            ticks: self.ticks,
            tokens_in: self.tokens_in,
            outputs: self.outputs,
            streams_opened: self.streams_opened,
            streams_closed: self.streams_closed,
            streams_evicted: self.streams_evicted,
            admission_rejects: self.admission_rejects,
            tick_latency: self.tick_latency.clone(),
            queue_latency: self.queue_latency.clone(),
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "cluster: shards={} placed(primary={} fallback={}) rejects={}\n  total: {}",
            self.per_shard.len(),
            self.placed_primary,
            self.placed_fallback,
            self.cluster_rejects,
            self.aggregate().report(),
        );
        if self.per_shard.len() > 1 {
            for (i, m) in self.per_shard.iter().enumerate() {
                s.push_str(&format!("\n  shard {i}: {}", m.report()));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_order() {
        let mut h = LatencyHisto::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() >= Duration::from_micros(20_000));
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHisto::new();
        let mut b = LatencyHisto::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHisto::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }

    #[test]
    fn cluster_metrics_sum_shards() {
        let mut a = EngineMetrics::new();
        a.ticks = 3;
        a.outputs = 5;
        a.streams_opened = 2;
        a.tick_latency.record(Duration::from_micros(100));
        let mut b = EngineMetrics::new();
        b.ticks = 4;
        b.outputs = 7;
        b.streams_evicted = 1;
        b.tick_latency.record(Duration::from_micros(400));
        let c = ClusterMetrics::from_shards(vec![a, b]);
        assert_eq!(c.ticks, 7);
        assert_eq!(c.outputs, 12);
        assert_eq!(c.streams_opened, 2);
        assert_eq!(c.streams_evicted, 1);
        assert_eq!(c.tick_latency.count(), 2);
        assert_eq!(c.per_shard.len(), 2);
        assert_eq!(c.aggregate().outputs, 12);
        assert!(c.report().contains("shard 1"));
    }
}
