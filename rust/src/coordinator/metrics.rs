//! Serving metrics: lock-free-ish counters and a log-bucketed latency
//! histogram (hand-rolled; no external metrics crates offline).

use std::time::Duration;

/// Log2-bucketed latency histogram from 1µs to ~68s.
#[derive(Debug, Clone)]
pub struct LatencyHisto {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHisto {
    pub fn new() -> Self {
        Self { buckets: vec![0; 27], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket containing quantile q (conservative).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(self.max_us)
    }

    pub fn merge(&mut self, other: &LatencyHisto) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Aggregate serving counters, owned by the engine thread.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub ticks: u64,
    pub tokens_in: u64,
    pub outputs: u64,
    pub streams_opened: u64,
    pub streams_closed: u64,
    pub admission_rejects: u64,
    pub tick_latency: LatencyHisto,
    /// time a token waits in the batcher before its tick starts
    pub queue_latency: LatencyHisto,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self { tick_latency: LatencyHisto::new(), queue_latency: LatencyHisto::new(), ..Default::default() }
    }

    pub fn report(&self) -> String {
        format!(
            "ticks={} tokens={} outputs={} streams={}/{} rejects={} \
             tick(mean={:?} p50={:?} p95={:?} max={:?}) queue(p95={:?})",
            self.ticks,
            self.tokens_in,
            self.outputs,
            self.streams_opened,
            self.streams_closed,
            self.admission_rejects,
            self.tick_latency.mean(),
            self.tick_latency.quantile(0.5),
            self.tick_latency.quantile(0.95),
            self.tick_latency.max(),
            self.queue_latency.quantile(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_order() {
        let mut h = LatencyHisto::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() >= Duration::from_micros(20_000));
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHisto::new();
        let mut b = LatencyHisto::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHisto::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }
}
