//! Serving metrics: lock-free-ish counters and a log-bucketed latency
//! histogram (hand-rolled; no external metrics crates offline).

use std::time::Duration;

use crate::obs::span::StageSpans;

/// Log2-bucketed latency histogram from 1µs to ~68s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHisto {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHisto {
    /// An empty histogram.
    pub fn new() -> Self {
        Self { buckets: vec![0; 27], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of the recorded samples (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    /// Largest recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> Duration {
        Duration::from_micros(self.sum_us)
    }

    /// Zero every counter in place (storage retained; no allocation).
    pub fn reset(&mut self) {
        for b in &mut self.buckets {
            *b = 0;
        }
        self.count = 0;
        self.sum_us = 0;
        self.max_us = 0;
    }

    /// Estimated value at quantile q: linear interpolation by rank
    /// inside the terminal bucket, clamped so the estimate never
    /// exceeds the true recorded maximum — `quantile(1.0) == max()`.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // rank within this bucket, in (0, 1]
                let rank = target - (seen - c);
                let frac = rank as f64 / c as f64;
                let lo = 1u64 << i;
                let hi = if i + 1 >= self.buckets.len() {
                    self.max_us
                } else {
                    (1u64 << (i + 1)).min(self.max_us)
                }
                .max(lo);
                return Duration::from_micros(lo + ((hi - lo) as f64 * frac) as u64);
            }
        }
        Duration::from_micros(self.max_us)
    }

    /// Fold another histogram's samples into this one (bucket-wise).
    pub fn merge(&mut self, other: &LatencyHisto) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Per-shard serving counters, owned by one shard worker thread.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Batched ticks executed.
    pub ticks: u64,
    /// Token vectors accepted by the batcher.
    pub tokens_in: u64,
    /// Tick results delivered to stream owners.
    pub outputs: u64,
    /// Streams admitted (fresh opens; migrations arrive separately).
    pub streams_opened: u64,
    /// Streams explicitly closed while bound here.
    pub streams_closed: u64,
    /// idle sessions reclaimed by admission (distinct from explicit closes)
    pub streams_evicted: u64,
    /// Admissions rejected at capacity (opens and migration imports).
    pub admission_rejects: u64,
    /// Streams that migrated onto this shard (aborted migrations that
    /// return home are rolled back, not counted).
    pub migrations_in: u64,
    /// Streams that migrated off this shard (net of aborted exports).
    pub migrations_out: u64,
    /// Streams this shard spilled to the state store to make room
    /// (hibernation: state is kept and resumable, unlike an eviction).
    pub streams_hibernated: u64,
    /// Hibernated streams restored into one of this shard's lanes.
    pub streams_restored: u64,
    /// Per-tick backend step latency.
    pub tick_latency: LatencyHisto,
    /// time a token waits in the batcher before its tick starts
    pub queue_latency: LatencyHisto,
    /// Per-stage pipeline latency breakdown (ingress → queue →
    /// batch-form → backend-step → deliver, plus migration legs).
    /// Empty unless the engine runs with `obs` at `spans` or above.
    pub stage_spans: StageSpans,
    /// Ticks whose end-to-end pipeline time exceeded the configured
    /// `slow_tick` threshold (counted at `obs=spans` and above).
    pub slow_ticks: u64,
    /// Kernel path the shard's backend resolved at startup ("scalar" /
    /// "avx2" / "neon"; "n/a" for backends without a dispatched kernel
    /// layer, empty before the shard reports). Dispatch never changes
    /// results (bitwise-pinned in `tests/simd_equiv.rs`) — this field
    /// exists so a latency number is never read without knowing which
    /// path produced it.
    pub kernel_dispatch: String,
}

impl EngineMetrics {
    /// Fresh all-zero counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold another shard's counters into this one (histograms merge
    /// bucket-wise) — the cluster aggregate is a plain sum of shards.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.ticks += other.ticks;
        self.tokens_in += other.tokens_in;
        self.outputs += other.outputs;
        self.streams_opened += other.streams_opened;
        self.streams_closed += other.streams_closed;
        self.streams_evicted += other.streams_evicted;
        self.admission_rejects += other.admission_rejects;
        self.migrations_in += other.migrations_in;
        self.migrations_out += other.migrations_out;
        self.streams_hibernated += other.streams_hibernated;
        self.streams_restored += other.streams_restored;
        self.tick_latency.merge(&other.tick_latency);
        self.queue_latency.merge(&other.queue_latency);
        self.stage_spans.merge(&other.stage_spans);
        self.slow_ticks += other.slow_ticks;
        // shards share one EngineConfig, so paths agree; first
        // non-empty wins (merging into fresh all-zero counters)
        if self.kernel_dispatch.is_empty() {
            self.kernel_dispatch = other.kernel_dispatch.clone();
        }
    }

    /// One-line operator summary of the counters.
    pub fn report(&self) -> String {
        let mut s = format!(
            "ticks={} tokens={} outputs={} streams={}/{} evicted={} rejects={} \
             migr={}in/{}out hib={}out/{}in tick(mean={:?} p50={:?} p95={:?} max={:?}) \
             queue(p95={:?})",
            self.ticks,
            self.tokens_in,
            self.outputs,
            self.streams_opened,
            self.streams_closed,
            self.streams_evicted,
            self.admission_rejects,
            self.migrations_in,
            self.migrations_out,
            self.streams_hibernated,
            self.streams_restored,
            self.tick_latency.mean(),
            self.tick_latency.quantile(0.5),
            self.tick_latency.quantile(0.95),
            self.tick_latency.max(),
            self.queue_latency.quantile(0.95),
        );
        if !self.kernel_dispatch.is_empty() {
            s.push_str(&format!(" dispatch={}", self.kernel_dispatch));
        }
        s
    }
}

/// Cluster-wide serving metrics: the per-shard [`EngineMetrics`] plus
/// their sum, the front door's placement counters, and the migration
/// counters (attempted/completed/aborted with quiesce-time quantiles)
/// that make rebalancing observable from the front door. The aggregate
/// fields mirror `EngineMetrics` name-for-name, so code written against
/// the single-engine metrics keeps reading the same fields and now sees
/// cluster totals.
#[derive(Debug, Clone, Default)]
pub struct ClusterMetrics {
    /// Batched ticks executed, cluster-wide.
    pub ticks: u64,
    /// Token vectors accepted, cluster-wide.
    pub tokens_in: u64,
    /// Tick results delivered, cluster-wide.
    pub outputs: u64,
    /// Streams admitted, cluster-wide.
    pub streams_opened: u64,
    /// Streams explicitly closed, cluster-wide.
    pub streams_closed: u64,
    /// Idle sessions reclaimed by admission, cluster-wide.
    pub streams_evicted: u64,
    /// Shard-level admission rejects, cluster-wide.
    pub admission_rejects: u64,
    /// Streams spilled to the state store, cluster-wide.
    pub streams_hibernated: u64,
    /// Hibernated streams restored into lanes, cluster-wide.
    pub streams_restored: u64,
    /// Per-tick backend step latency, merged across shards.
    pub tick_latency: LatencyHisto,
    /// Batcher queue-wait latency, merged across shards.
    pub queue_latency: LatencyHisto,
    /// Per-stage pipeline latency, merged across shards; the front
    /// door folds its quiesce histogram into the migration-quiesce
    /// stage when spans are enabled.
    pub stage_spans: StageSpans,
    /// Over-threshold ticks, cluster-wide.
    pub slow_ticks: u64,
    /// Per-shard breakdown (index = shard id).
    pub per_shard: Vec<EngineMetrics>,
    /// Streams placed on their policy-preferred shard.
    pub placed_primary: u64,
    /// Streams placed on a fallback shard (primary was full).
    pub placed_fallback: u64,
    /// Opens rejected by every shard (cluster saturated).
    pub cluster_rejects: u64,
    /// Live migrations requested (`migrate` / `rebalance`); a migrate
    /// to the stream's current shard is an uncounted no-op.
    pub migrations_attempted: u64,
    /// Live migrations that landed on their target shard.
    pub migrations_completed: u64,
    /// Live migrations that failed (stream left on — or returned to —
    /// its source shard when possible).
    pub migrations_aborted: u64,
    /// Stream-unavailability window per completed migration: export
    /// request to import acknowledgment (read p50/p99 off this).
    pub quiesce_latency: LatencyHisto,
    /// Streams currently hibernated (a gauge, not a counter: state in
    /// the store with no backend lane anywhere).
    pub hibernated_resident: u64,
    /// Streams re-registered as hibernated by recover-on-boot.
    pub streams_recovered: u64,
    /// Full-cluster snapshots taken (periodic or explicit).
    pub snapshots_taken: u64,
    /// Wall time per full-cluster snapshot (quiesce + export + store
    /// write for every bound stream).
    pub snapshot_latency: LatencyHisto,
    /// Shard worker deaths observed by the supervisor.
    pub shard_failures: u64,
    /// Dead shards respawned back into service by the supervisor.
    pub shards_respawned: u64,
    /// Shards currently dead (a gauge: marked failed, not yet — or
    /// never — respawned; requests routed at them fail fast with a
    /// retryable error).
    pub shards_dead: u64,
    /// Crashed-shard streams re-homed onto their last checkpoint
    /// (resumable on a surviving shard).
    pub streams_rehomed: u64,
    /// Crashed-shard streams lost for lack of a checkpoint (their
    /// owners get a typed error, never a hang).
    pub streams_lost: u64,
    /// Store operations that stayed failed past their retry budget —
    /// the engine served on in degraded mode instead of aborting.
    pub store_degraded: u64,
    /// Retries spent by degraded-store exponential backoff.
    pub store_retries: u64,
    /// Kernel path the shard backends resolved at startup (shards share
    /// one `EngineConfig`, so one value describes the cluster).
    pub kernel_dispatch: String,
    /// Time since the engine front door booted.
    pub uptime: Duration,
    /// Wall-clock boot instant, milliseconds since the Unix epoch.
    pub boot_unix_ms: u64,
}

impl ClusterMetrics {
    /// Build the aggregate view from per-shard snapshots; the front
    /// door fills the placement and migration counters afterwards.
    pub fn from_shards(per_shard: Vec<EngineMetrics>) -> Self {
        let mut agg = EngineMetrics::new();
        for m in &per_shard {
            agg.merge(m);
        }
        Self {
            ticks: agg.ticks,
            tokens_in: agg.tokens_in,
            outputs: agg.outputs,
            streams_opened: agg.streams_opened,
            streams_closed: agg.streams_closed,
            streams_evicted: agg.streams_evicted,
            admission_rejects: agg.admission_rejects,
            streams_hibernated: agg.streams_hibernated,
            streams_restored: agg.streams_restored,
            tick_latency: agg.tick_latency,
            queue_latency: agg.queue_latency,
            stage_spans: agg.stage_spans,
            slow_ticks: agg.slow_ticks,
            kernel_dispatch: agg.kernel_dispatch,
            per_shard,
            ..Self::default()
        }
    }

    /// The aggregate counters as one `EngineMetrics` view, built from
    /// the stored totals (the single source of truth after
    /// `from_shards`) — not re-derived from `per_shard`, so the two can
    /// never silently diverge.
    pub fn aggregate(&self) -> EngineMetrics {
        let (migrations_in, migrations_out) = self
            .per_shard
            .iter()
            .fold((0, 0), |(i, o), m| (i + m.migrations_in, o + m.migrations_out));
        EngineMetrics {
            ticks: self.ticks,
            tokens_in: self.tokens_in,
            outputs: self.outputs,
            streams_opened: self.streams_opened,
            streams_closed: self.streams_closed,
            streams_evicted: self.streams_evicted,
            admission_rejects: self.admission_rejects,
            migrations_in,
            migrations_out,
            streams_hibernated: self.streams_hibernated,
            streams_restored: self.streams_restored,
            tick_latency: self.tick_latency.clone(),
            queue_latency: self.queue_latency.clone(),
            stage_spans: self.stage_spans.clone(),
            slow_ticks: self.slow_ticks,
            kernel_dispatch: self.kernel_dispatch.clone(),
        }
    }

    /// Multi-line operator summary: placement + migration counters, the
    /// aggregate, and (on multi-shard clusters) per-shard breakdowns.
    pub fn report(&self) -> String {
        let mut s = format!(
            "cluster: shards={} placed(primary={} fallback={}) rejects={} \
             migrations(attempted={} completed={} aborted={} quiesce p50={:?} p99={:?})\n  \
             total: {}",
            self.per_shard.len(),
            self.placed_primary,
            self.placed_fallback,
            self.cluster_rejects,
            self.migrations_attempted,
            self.migrations_completed,
            self.migrations_aborted,
            self.quiesce_latency.quantile(0.5),
            self.quiesce_latency.quantile(0.99),
            self.aggregate().report(),
        );
        if self.hibernated_resident > 0 || self.streams_hibernated > 0 || self.snapshots_taken > 0
        {
            s.push_str(&format!(
                "\n  hibernation: resident={} recovered={} snapshots={} (p99={:?})",
                self.hibernated_resident,
                self.streams_recovered,
                self.snapshots_taken,
                self.snapshot_latency.quantile(0.99),
            ));
        }
        if self.shard_failures > 0 || self.store_degraded > 0 {
            s.push_str(&format!(
                "\n  faults: shard_failures={} respawned={} dead={} rehomed={} lost={} \
                 store_degraded={} store_retries={}",
                self.shard_failures,
                self.shards_respawned,
                self.shards_dead,
                self.streams_rehomed,
                self.streams_lost,
                self.store_degraded,
                self.store_retries,
            ));
        }
        if self.per_shard.len() > 1 {
            for (i, m) in self.per_shard.iter().enumerate() {
                s.push_str(&format!("\n  shard {i}: {}", m.report()));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_order() {
        let mut h = LatencyHisto::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() >= Duration::from_micros(20_000));
    }

    #[test]
    fn quantile_never_exceeds_max() {
        // regression: the old implementation returned the terminal
        // bucket's upper bound 2^(i+1), overstating p99 up to 2x; the
        // estimate must now clamp to the true recorded maximum
        let mut h = LatencyHisto::new();
        for us in [3u64, 130, 130, 131, 1050] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.quantile(1.0), h.max());
        assert_eq!(h.max(), Duration::from_micros(1050));
        for q in [0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert!(h.quantile(q) <= h.max(), "q={q} overshoots max");
        }
        // single sample: every quantile is that sample
        let mut one = LatencyHisto::new();
        one.record(Duration::from_micros(777));
        assert_eq!(one.quantile(0.5), Duration::from_micros(777));
        assert_eq!(one.quantile(1.0), one.max());
    }

    #[test]
    fn reset_zeroes_in_place() {
        let mut h = LatencyHisto::new();
        h.record(Duration::from_micros(42));
        h.reset();
        assert_eq!(h, LatencyHisto::new());
        assert_eq!(h.sum(), Duration::ZERO);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHisto::new();
        let mut b = LatencyHisto::new();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHisto::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }

    #[test]
    fn cluster_metrics_sum_shards() {
        let mut a = EngineMetrics::new();
        a.ticks = 3;
        a.outputs = 5;
        a.streams_opened = 2;
        a.migrations_out = 1;
        a.tick_latency.record(Duration::from_micros(100));
        a.kernel_dispatch = "scalar".to_string();
        let mut b = EngineMetrics::new();
        b.ticks = 4;
        b.outputs = 7;
        b.streams_evicted = 1;
        b.migrations_in = 1;
        b.tick_latency.record(Duration::from_micros(400));
        b.kernel_dispatch = "scalar".to_string();
        let c = ClusterMetrics::from_shards(vec![a, b]);
        assert_eq!(c.ticks, 7);
        assert_eq!(c.outputs, 12);
        assert_eq!(c.streams_opened, 2);
        assert_eq!(c.streams_evicted, 1);
        assert_eq!(c.tick_latency.count(), 2);
        assert_eq!(c.per_shard.len(), 2);
        assert_eq!(c.aggregate().outputs, 12);
        assert_eq!(c.aggregate().migrations_in, 1);
        assert_eq!(c.aggregate().migrations_out, 1);
        assert!(c.report().contains("shard 1"));
        assert!(c.report().contains("migrations(attempted=0"));
        // the resolved kernel path reaches the aggregate and the report
        assert_eq!(c.kernel_dispatch, "scalar");
        assert_eq!(c.aggregate().kernel_dispatch, "scalar");
        assert!(c.report().contains("dispatch=scalar"));
    }

    #[test]
    fn dispatch_absent_until_reported() {
        // fresh counters carry no path; the report omits the field
        // rather than printing an empty value
        let m = EngineMetrics::new();
        assert!(m.kernel_dispatch.is_empty());
        assert!(!m.report().contains("dispatch="));
    }
}
