//! Admission + placement: binds new streams to slots, evicts idle ones,
//! and answers the backpressure question at the front door.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::coordinator::slots::{SlotMap, StreamId};

#[derive(Debug, Clone)]
pub struct SessionInfo {
    pub slot: usize,
    pub opened: Instant,
    pub last_activity: Instant,
    pub ticks: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Accepted(usize),
    /// All slots busy and nothing evictable.
    Rejected,
}

#[derive(Debug)]
pub struct Router {
    slots: SlotMap,
    sessions: BTreeMap<StreamId, SessionInfo>,
    pub idle_timeout: Duration,
}

impl Router {
    pub fn new(capacity: usize, idle_timeout: Duration) -> Self {
        Self {
            slots: SlotMap::new(capacity),
            sessions: BTreeMap::new(),
            idle_timeout,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.capacity()
    }

    pub fn occupied(&self) -> usize {
        self.slots.occupied()
    }

    pub fn slot_of(&self, id: StreamId) -> Option<usize> {
        self.slots.slot_of(id)
    }

    pub fn session(&self, id: StreamId) -> Option<&SessionInfo> {
        self.sessions.get(&id)
    }

    /// Admit a stream under an externally assigned id (the cluster
    /// front door owns the id namespace): use a free slot, else evict
    /// the longest-idle session past the timeout, else reject. The
    /// evicted victim (if any) is reported so the caller can drop that
    /// stream's port and queued tokens — never swallow it.
    pub fn admit(&mut self, id: StreamId, now: Instant) -> (Admission, Option<StreamId>) {
        let mut evicted = None;
        if self.slots.is_full() {
            let evict = self
                .sessions
                .iter()
                .filter(|(_, s)| now.duration_since(s.last_activity) >= self.idle_timeout)
                .min_by_key(|(_, s)| s.last_activity)
                .map(|(&eid, _)| eid);
            match evict {
                Some(eid) => {
                    self.close(eid);
                    evicted = Some(eid);
                }
                None => return (Admission::Rejected, None),
            }
        }
        // a slot is free here by construction (either the map wasn't
        // full or the eviction above released one); stay panic-free on
        // that invariant and degrade to a reject if it ever breaks
        let Some(slot) = self.slots.bind(id) else {
            return (Admission::Rejected, evicted);
        };
        self.sessions.insert(
            id,
            SessionInfo { slot, opened: now, last_activity: now, ticks: 0 },
        );
        (Admission::Accepted(slot), evicted)
    }

    /// The hibernation spill candidate: when every slot is busy, the
    /// longest-idle session — *regardless* of the idle timeout, because
    /// hibernation spills state to the store instead of dropping it, so
    /// slot capacity bounds *active* streams, not registered ones.
    /// `None` while a free slot remains (nothing needs to move).
    pub fn spill_victim(&self) -> Option<StreamId> {
        if !self.slots.is_full() {
            return None;
        }
        self.sessions.iter().min_by_key(|(_, s)| s.last_activity).map(|(&id, _)| id)
    }

    /// Record a completed tick for a stream.
    pub fn touch(&mut self, id: StreamId, now: Instant) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.last_activity = now;
            s.ticks += 1;
        }
    }

    /// Close a stream; returns its freed slot (to be cleared).
    pub fn close(&mut self, id: StreamId) -> Option<usize> {
        self.sessions.remove(&id);
        self.slots.release(id)
    }

    pub fn active_streams(&self) -> Vec<StreamId> {
        self.sessions.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn admit_until_full_then_reject() {
        let now = Instant::now();
        let mut r = Router::new(2, Duration::from_secs(3600));
        let (a, _) = r.admit(StreamId(1), now);
        let (b, _) = r.admit(StreamId(2), now);
        assert!(matches!(a, Admission::Accepted(_)));
        assert!(matches!(b, Admission::Accepted(_)));
        let (c, _) = r.admit(StreamId(3), now);
        assert_eq!(c, Admission::Rejected);
    }

    #[test]
    fn eviction_frees_idle_sessions() {
        let now = Instant::now();
        let mut r = Router::new(1, Duration::from_millis(10));
        let id1 = StreamId(1);
        r.admit(id1, now);
        // id1 idle past timeout -> evicted on next admission
        let later = now + Duration::from_millis(20);
        let (adm, _) = r.admit(StreamId(2), later);
        assert!(matches!(adm, Admission::Accepted(_)));
        assert!(r.session(id1).is_none());
    }

    #[test]
    fn touch_prevents_eviction() {
        let now = Instant::now();
        let mut r = Router::new(1, Duration::from_millis(10));
        let id1 = StreamId(1);
        r.admit(id1, now);
        let later = now + Duration::from_millis(20);
        r.touch(id1, later);
        let (adm, ev) = r.admit(StreamId(2), later + Duration::from_millis(5));
        assert_eq!(adm, Admission::Rejected);
        assert_eq!(ev, None);
        assert!(r.session(id1).is_some());
    }

    #[test]
    fn spill_victim_is_lru_and_ignores_idle_timeout() {
        let now = Instant::now();
        let mut r = Router::new(2, Duration::from_secs(3600));
        assert_eq!(r.spill_victim(), None); // empty: nothing to spill
        r.admit(StreamId(1), now);
        assert_eq!(r.spill_victim(), None); // free slot remains
        r.admit(StreamId(2), now + Duration::from_millis(1));
        // Full: LRU wins even though neither is past the idle timeout.
        assert_eq!(r.spill_victim(), Some(StreamId(1)));
        r.touch(StreamId(1), now + Duration::from_millis(2));
        assert_eq!(r.spill_victim(), Some(StreamId(2)));
    }

    #[test]
    fn close_frees_slot() {
        let now = Instant::now();
        let mut r = Router::new(1, Duration::from_secs(1));
        let id = StreamId(1);
        r.admit(id, now);
        let slot = r.close(id);
        assert!(slot.is_some());
        assert_eq!(r.occupied(), 0);
        let (adm, _) = r.admit(StreamId(2), now);
        assert!(matches!(adm, Admission::Accepted(_)));
    }

    #[test]
    fn admit_reports_the_evicted_session() {
        let now = Instant::now();
        let mut r = Router::new(1, Duration::from_millis(10));
        let (adm, ev) = r.admit(StreamId(100), now);
        assert!(matches!(adm, Admission::Accepted(_)));
        assert_eq!(ev, None);
        // idle past the timeout: the next admit evicts and names the victim
        let later = now + Duration::from_millis(20);
        let (adm, ev) = r.admit(StreamId(101), later);
        assert!(matches!(adm, Admission::Accepted(_)));
        assert_eq!(ev, Some(StreamId(100)));
        assert!(r.session(StreamId(100)).is_none());
        // nothing evictable: reject, no victim
        let (adm, ev) = r.admit(StreamId(102), later);
        assert_eq!(adm, Admission::Rejected);
        assert_eq!(ev, None);
    }

    /// Property: occupied never exceeds capacity; every admitted stream
    /// has a consistent slot; evictions are always reported.
    #[test]
    fn prop_router_invariants() {
        prop::check("router-invariants", 150, |rng| {
            let cap = rng.range(1, 5);
            let mut r = Router::new(cap, Duration::from_millis(rng.range(1, 30) as u64));
            let mut t = Instant::now();
            let mut next_id = 1u64;
            let mut live: Vec<StreamId> = Vec::new();
            for _ in 0..rng.range(1, 60) {
                t += Duration::from_millis(rng.range(0, 20) as u64);
                match rng.below(3) {
                    0 => {
                        let id = StreamId(next_id);
                        next_id += 1;
                        let (adm, evicted) = r.admit(id, t);
                        if let Some(eid) = evicted {
                            if r.session(eid).is_some() {
                                return Err(format!("evicted id {} still live", eid.0));
                            }
                        }
                        if let Admission::Accepted(slot) = adm {
                            if slot >= cap {
                                return Err("slot out of range".into());
                            }
                            live.push(id);
                        }
                    }
                    1 => {
                        if let Some(&id) = live.first() {
                            r.close(id);
                            live.retain(|&x| x != id);
                        }
                    }
                    _ => {
                        if let Some(&id) = live.last() {
                            r.touch(id, t);
                        }
                    }
                }
                live.retain(|&id| r.session(id).is_some()); // evictions
                if r.occupied() > cap {
                    return Err("over capacity".into());
                }
                for &id in &live {
                    let s = r.session(id).unwrap();
                    if r.slot_of(id) != Some(s.slot) {
                        return Err("slot bookkeeping diverged".into());
                    }
                }
            }
            Ok(())
        });
    }
}
