//! One engine shard: a worker thread owning a complete serving cell —
//! its own execution backend ([`SlotStepper`]: PJRT handles are
//! `Rc`-based and the scalar backend is plain host memory, so
//! everything state-touching lives on this thread), its own [`Router`]
//! (admission, idle eviction), [`Batcher`] (deadline / all-slots tick
//! policy) and [`EngineMetrics`]. The cluster front door
//! (`coordinator::cluster`) spawns N of these and pins each stream to
//! one shard; a 1-shard cluster is exactly the old single-threaded
//! engine.
//!
//! Data flow per tick (within one shard):
//!   front door → Open/Push ─┐
//!                           ├→ Batcher (deadline / all-slots policy)
//!   Router (slots) ─────────┘        │
//!                                    ▼
//!                  SlotStepper.tick_lanes (one batched step, all live lanes)
//!                                    │
//!          per-stream output channels ← scatter lanes + metrics
//!
//! Stream ids are assigned by the front door (a cluster-global
//! namespace), so a stream keeps its id no matter which shard it lands
//! on; the shard's router only binds ids to batch lanes.
//!
//! **Live migration** rides on two extra requests. `Export` quiesces a
//! stream in one atomic step of the shard loop: snapshot its lane
//! ([`StreamState`]), pull its queued tokens out of the batcher, detach
//! its output port, release the slot — and hand the whole
//! [`ExportedStream`] to the front door. `Import` is the mirror image
//! on the target shard: admit into a free slot, restore the lane,
//! reattach the port (the client's receiver never notices), requeue the
//! tokens. Because both run between ticks of their single-threaded
//! shard loops, a snapshot can never be torn or go stale.
//!
//! Shutdown discipline: on [`ShardRequest::Shutdown`] the worker drains
//! every request still queued in its channel and answers each with a
//! terminal [`EngineError::ShuttingDown`] (final metrics are still
//! served) — a caller blocked on a reply is never left hanging, and
//! queued pushes fail loudly instead of silently dropping their ticks.
//!
//! **Crash isolation**: the serve loop runs under `catch_unwind`, so a
//! panicking backend (or an injected `FaultSite::ShardStep` fault)
//! kills only this shard, not the process. The worker reports the
//! failure over a [`ShardFailure`] channel to the cluster's supervisor,
//! which marks the shard dead, re-homes its checkpointed streams onto
//! survivors, and respawns the worker. While a shard is down, its
//! callers see the retryable [`EngineError::ShardFailed`] — never a
//! poisoned [`EngineError::ShuttingDown`].
//!
//! [`EngineError::ShuttingDown`]: crate::coordinator::session::EngineError::ShuttingDown
//! [`EngineError::ShardFailed`]: crate::coordinator::session::EngineError::ShardFailed

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

use crate::config::{EngineBackend, EngineConfig};
use crate::coordinator::batcher::{Batcher, Pending};
use crate::coordinator::hibernate::{self, HibernatePool};
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::router::{Admission, Router};
use crate::coordinator::session::EngineError;
use crate::coordinator::slot_stepper::{SlotStepper, StreamState};
use crate::coordinator::slots::StreamId;
use crate::fault::{FaultInjector, FaultSite};
use crate::manifest::Manifest;
use crate::nn::params::ModelParams;
use crate::obs::journal::EventKind;
use crate::obs::span::Stage;
use crate::obs::ObsHandle;
use crate::runtime::Runtime;

/// One tick's result delivered to a stream's owner.
#[derive(Debug, Clone)]
pub struct TickResult {
    /// Classifier logits for the stream's newest token.
    pub logits: Vec<f32>,
    /// Final-layer activations for the stream's new tokens.
    pub out: Vec<f32>,
    /// Per-stream tick ordinal (1-based; counts only this stream's
    /// ticks, and survives a live migration).
    pub tick: u64,
}

/// A successful admission: the stream's output channel, plus the idle
/// session this shard evicted to make room (the front door must drop
/// the victim's binding too — its owner may never close it).
pub(crate) type Admitted = (Receiver<TickResult>, Option<StreamId>);

/// Everything that travels with a stream when it migrates between
/// shards: its lane snapshot, its output port (the client keeps the
/// receiving end), its tick ordinal, and its still-queued tokens.
pub(crate) struct ExportedStream {
    pub(crate) state: StreamState,
    pub(crate) port: Sender<TickResult>,
    pub(crate) ticks: u64,
    pub(crate) queued: Vec<Pending>,
}

/// A push failure, with the token vector handed back when the shard
/// never accepted it (so the front door can retry after a migration
/// rebind without cloning every push).
pub(crate) type PushRejected = (EngineError, Option<Vec<f32>>);

/// An import failure: the payload handed back when possible (so the
/// front door can abort the migration by re-importing on the source),
/// plus any idle victim admission evicted before the failure — the
/// front door must still unbind the victim or its binding leaks.
pub(crate) type ImportRejected = (EngineError, Option<Box<ExportedStream>>, Option<StreamId>);

/// Why a stream is being imported into a lane — drives which counters
/// and spans the landing shard records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ImportReason {
    /// Live migration landing on its target shard.
    Migrate,
    /// Migration abort: this import undoes this shard's own failed
    /// export, so the export's `migrations_out` is un-counted instead
    /// of `migrations_in` incremented.
    MigrateRollback,
    /// A hibernated stream waking back into a lane.
    Restore,
    /// A stream returning to its own slot right after a snapshot
    /// export (counter-neutral: the stream never logically moved).
    Snapshot,
}

pub(crate) enum ShardRequest {
    Open { id: StreamId, reply: Sender<Result<Admitted, EngineError>> },
    Push { id: StreamId, tokens: Vec<f32>, reply: Sender<Result<(), PushRejected>> },
    Close { id: StreamId },
    Export {
        id: StreamId,
        /// Migration exports count `migrations_out`; snapshot exports
        /// are counter-neutral (the stream comes right back).
        for_migration: bool,
        reply: Sender<Result<Box<ExportedStream>, EngineError>>,
    },
    Import {
        id: StreamId,
        payload: Box<ExportedStream>,
        reason: ImportReason,
        reply: Sender<Result<Option<StreamId>, ImportRejected>>,
    },
    Metrics { reply: Sender<EngineMetrics> },
    Shutdown,
}

/// Cloneable, `Send` handle to one shard's worker thread. Every
/// channel failure (worker gone, reply dropped) surfaces as the
/// retryable [`EngineError::ShardFailed`] — a dead or panicked shard
/// never panics its clients, and the front door translates the error
/// to [`EngineError::ShuttingDown`] when the whole engine is actually
/// going down (so supervision never masquerades as shutdown or vice
/// versa).
#[derive(Clone)]
pub(crate) struct ShardHandle {
    shard: usize,
    tx: SyncSender<ShardRequest>,
}

/// A dead shard's channel error: the supervisor will re-home and
/// respawn, so the caller should retry.
fn shard_gone() -> EngineError {
    EngineError::ShardFailed { retryable: true }
}

impl ShardHandle {
    /// Bind a front-door-assigned stream id; returns its output channel
    /// and the idle stream evicted to make room, if any.
    pub(crate) fn open(&self, id: StreamId) -> Result<Admitted, EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(ShardRequest::Open { id, reply }).map_err(|_| shard_gone())?;
        rx.recv().map_err(|_| shard_gone())?
    }

    /// Submit the next token(s) for a stream bound to this shard.
    pub(crate) fn push(&self, id: StreamId, tokens: Vec<f32>) -> Result<(), PushRejected> {
        let (reply, rx) = mpsc::channel();
        if let Err(mpsc::SendError(req)) = self.tx.send(ShardRequest::Push { id, tokens, reply }) {
            let tokens = match req {
                ShardRequest::Push { tokens, .. } => Some(tokens),
                _ => None,
            };
            return Err((shard_gone(), tokens));
        }
        rx.recv().map_err(|_| (shard_gone(), None))?
    }

    pub(crate) fn close(&self, id: StreamId) {
        let _ = self.tx.send(ShardRequest::Close { id });
    }

    /// Quiesce + snapshot a stream (removes it from this shard on
    /// success). `for_migration` governs counters only — snapshot
    /// exports re-import immediately and must stay counter-neutral.
    pub(crate) fn export(
        &self,
        id: StreamId,
        for_migration: bool,
    ) -> Result<Box<ExportedStream>, EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ShardRequest::Export { id, for_migration, reply })
            .map_err(|_| shard_gone())?;
        rx.recv().map_err(|_| shard_gone())?
    }

    /// Land an exported stream on this shard ([`ImportReason`] says
    /// whether this is a migration, its abort path, a hibernation
    /// restore, or a snapshot return). On failure the payload is
    /// returned (when recoverable) so the caller can re-import it on
    /// the source shard or re-hibernate it.
    pub(crate) fn import(
        &self,
        id: StreamId,
        payload: Box<ExportedStream>,
        reason: ImportReason,
    ) -> Result<Option<StreamId>, ImportRejected> {
        let (reply, rx) = mpsc::channel();
        if let Err(mpsc::SendError(req)) =
            self.tx.send(ShardRequest::Import { id, payload, reason, reply })
        {
            let payload = match req {
                ShardRequest::Import { payload, .. } => Some(payload),
                _ => None,
            };
            return Err((shard_gone(), payload, None));
        }
        match rx.recv() {
            Ok(res) => res,
            Err(_) => Err((shard_gone(), None, None)),
        }
    }

    pub(crate) fn metrics(&self) -> Result<EngineMetrics, EngineError> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(ShardRequest::Metrics { reply }).map_err(|_| shard_gone())?;
        rx.recv().map_err(|_| shard_gone())
    }

    pub(crate) fn signal_shutdown(&self) {
        let _ = self.tx.send(ShardRequest::Shutdown);
    }
}

/// What the worker thread reports to the supervisor when it dies
/// abnormally (panic or a backend tick error). A clean shutdown sends
/// nothing.
pub(crate) struct ShardFailure {
    /// Which shard died.
    pub(crate) shard: usize,
    /// The terminal error (a caught panic surfaces as the retryable
    /// [`EngineError::ShardFailed`]).
    pub(crate) reason: EngineError,
}

pub(crate) struct ShardThread {
    handle: ShardHandle,
    /// Startup signal, consumed by [`Self::wait_ready`].
    ready: Option<Receiver<Result<(), EngineError>>>,
    join: Option<std::thread::JoinHandle<Result<(), EngineError>>>,
}

impl ShardThread {
    /// Start one shard worker WITHOUT waiting for its backend: the
    /// cluster starts every shard first and then waits on all of them,
    /// so N shards load their models in parallel instead of serially.
    /// `fail_tx` is the supervisor's failure feed: the worker announces
    /// its own abnormal death there (nothing on clean shutdown).
    pub(crate) fn start(
        shard: usize,
        cfg: EngineConfig,
        obs: ObsHandle,
        pool: Option<HibernatePool>,
        fail_tx: Sender<ShardFailure>,
        inj: FaultInjector,
    ) -> Result<Self, EngineError> {
        let (tx, rx) = mpsc::sync_channel::<ShardRequest>(cfg.request_queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), EngineError>>();
        let join = std::thread::Builder::new()
            .name(format!("deepcot-shard-{shard}"))
            .spawn(move || {
                let res = shard_main(shard, cfg, obs, pool, rx, ready_tx, inj);
                if let Err(e) = &res {
                    // receiver gone = no supervisor (startup failure or
                    // engine teardown); nothing to notify
                    let _ = fail_tx.send(ShardFailure { shard, reason: e.clone() });
                }
                res
            })
            .map_err(EngineError::internal)?;
        Ok(Self {
            handle: ShardHandle { shard, tx },
            ready: Some(ready_rx),
            join: Some(join),
        })
    }

    /// Block until the shard's model is loaded and the backend is up
    /// (so the first Push never pays compile latency). Idempotent.
    pub(crate) fn wait_ready(&mut self) -> Result<(), EngineError> {
        match self.ready.take() {
            Some(rx) => rx.recv().map_err(|_| EngineError::ShuttingDown)?,
            None => Ok(()),
        }
    }

    pub(crate) fn handle(&self) -> ShardHandle {
        self.handle.clone()
    }

    pub(crate) fn signal_shutdown(&self) {
        self.handle.signal_shutdown();
    }

    pub(crate) fn join(&mut self) -> Result<(), EngineError> {
        match self.join.take() {
            None => Ok(()),
            Some(j) => match j.join() {
                Ok(res) => res,
                Err(_) => Err(EngineError::Internal(format!(
                    "shard {} panicked",
                    self.handle.shard
                ))),
            },
        }
    }
}

impl Drop for ShardThread {
    fn drop(&mut self) {
        self.signal_shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Backend selection: PJRT when the XLA runtime is available, the
/// pure-Rust batched scalar engine otherwise (or on request) — same
/// manifest, same weights, same lane semantics. The scalar backend
/// honors `cfg.slots_per_shard`; PJRT capacity is AOT-compiled, so an
/// override there is an error (under `auto` it simply falls through to
/// the scalar backend).
fn init_stepper(cfg: &EngineConfig) -> Result<(Option<Runtime>, SlotStepper), EngineError> {
    let pjrt = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper), EngineError> {
        if cfg.slots_per_shard != 0 {
            return Err(EngineError::InvalidRequest(
                "per-shard slot capacity override requires the scalar backend \
                 (PJRT batch is AOT-compiled)"
                    .to_string(),
            ));
        }
        let rt = Runtime::new(&cfg.artifacts_dir).map_err(EngineError::internal)?;
        let variant = rt.load(&cfg.variant).map_err(EngineError::internal)?;
        let stepper = SlotStepper::new(variant)?;
        Ok((Some(rt), stepper))
    };
    let scalar = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper), EngineError> {
        let (manifest, dir) = Manifest::load(&cfg.artifacts_dir).map_err(EngineError::internal)?;
        let entry = manifest.variant(&cfg.variant).map_err(EngineError::internal)?;
        let params = ModelParams::load(&dir, entry).map_err(EngineError::internal)?;
        let capacity = if cfg.slots_per_shard != 0 {
            cfg.slots_per_shard
        } else {
            entry.config.batch
        };
        Ok((
            None,
            SlotStepper::new_scalar_with_dispatch(entry, params, capacity, cfg.kernel_dispatch)?,
        ))
    };
    match cfg.backend {
        EngineBackend::Pjrt => pjrt(cfg),
        EngineBackend::Scalar => scalar(cfg),
        EngineBackend::Auto => pjrt(cfg).or_else(|pe| {
            scalar(cfg).map_err(|se| {
                EngineError::Internal(format!("pjrt backend: {pe}; scalar fallback: {se}"))
            })
        }),
    }
}

struct StreamPort {
    out: Sender<TickResult>,
    ticks: u64,
}

/// When hibernation is on and every slot is busy, spill the
/// longest-idle resident stream to the state store so the admission
/// that follows lands in a free lane. Returns the spilled victim — the
/// caller reports it to the front door exactly like an eviction victim
/// (the door unbinds it; the pool's table keeps it resumable). On any
/// failure (backend can't snapshot, store write failed) the victim
/// stays live, its tokens go back in the batcher, and admission falls
/// through to the legacy evict-or-reject path.
#[allow(clippy::too_many_arguments)]
fn make_room(
    now: Instant,
    shard: usize,
    obs: &ObsHandle,
    pool: &Option<HibernatePool>,
    stepper: &mut SlotStepper,
    router: &mut Router,
    batcher: &mut Batcher,
    ports: &mut BTreeMap<StreamId, StreamPort>,
    metrics: &mut EngineMetrics,
    spans_on: bool,
) -> Option<StreamId> {
    let pool = pool.as_ref()?;
    let vid = router.spill_victim()?;
    let slot = router.slot_of(vid)?;
    let port = ports.get(&vid)?;
    let mut state = StreamState::default();
    if stepper.export_lane(slot, &mut state).is_err() {
        // backend can't snapshot lanes (e.g. PJRT): hard-drop semantics
        return None;
    }
    let queued = batcher.extract(vid);
    let rec = hibernate::record_from_parts(vid, port.ticks, &state, &queued);
    match pool.spill(&rec, port.out.clone()) {
        Ok(()) => {
            ports.remove(&vid);
            router.close(vid);
            stepper.clear_lane(slot);
            metrics.streams_hibernated += 1;
            obs.event(EventKind::StreamHibernate, vid.0, shard as i64, 0);
            if spans_on {
                metrics.stage_spans.record(Stage::HibernateSpill, now.elapsed());
            }
            Some(vid)
        }
        Err(e) => {
            // store write failed: the stream never left — requeue its
            // tokens and let admission take the legacy path. Degraded,
            // not fatal: journal + warn so operators see the store
            // misbehaving long before durability is actually needed
            batcher.restore(vid, queued);
            obs.event(EventKind::StoreDegraded, vid.0, shard as i64, 0);
            eprintln!(
                "deepcot: degraded store: spill of stream {} failed: {e} — stream stays in its lane",
                vid.0
            );
            None
        }
    }
}

/// The `Import` request body: validate → admit → restore lane → attach
/// port → requeue tokens. Validation runs before admission so a bad
/// snapshot cannot strand a half-admitted stream; on any failure the
/// payload is handed back for the caller's abort path.
#[allow(clippy::too_many_arguments)]
fn import_stream(
    id: StreamId,
    payload: Box<ExportedStream>,
    reason: ImportReason,
    now: Instant,
    shard: usize,
    obs: &ObsHandle,
    pool: &Option<HibernatePool>,
    stepper: &mut SlotStepper,
    router: &mut Router,
    batcher: &mut Batcher,
    ports: &mut BTreeMap<StreamId, StreamPort>,
    metrics: &mut EngineMetrics,
    spans_on: bool,
) -> Result<Option<StreamId>, ImportRejected> {
    if let Err(e) = stepper.validate_state(&payload.state) {
        return Err((e, Some(payload), None));
    }
    let spilled =
        make_room(now, shard, obs, pool, stepper, router, batcher, ports, metrics, spans_on);
    let (adm, evicted) = router.admit(id, now);
    if let Some(eid) = evicted {
        // same teardown as an admission eviction on Open
        batcher.forget(eid);
        ports.remove(&eid);
        metrics.streams_evicted += 1;
        obs.event(EventKind::StreamEvict, eid.0, shard as i64, 0);
    }
    // at most one of the two is set: a successful spill guarantees the
    // admission below finds a free slot and evicts nobody
    let evicted = spilled.or(evicted);
    let slot = match adm {
        Admission::Accepted(slot) => slot,
        Admission::Rejected => {
            metrics.admission_rejects += 1;
            obs.event(EventKind::AdmissionReject, id.0, shard as i64, 0);
            return Err((
                EngineError::Saturated { capacity: router.capacity() },
                Some(payload),
                evicted,
            ));
        }
    };
    if let Err(e) = stepper.import_lane(slot, &payload.state) {
        // validate_state keeps this path rare (third-party backends or
        // geometry-total collisions); release the slot and let the
        // caller abort, still reporting the victim admission evicted
        router.close(id);
        stepper.clear_lane(slot);
        return Err((e, Some(payload), evicted));
    }
    let ExportedStream { port, ticks, queued, .. } = *payload;
    ports.insert(id, StreamPort { out: port, ticks });
    batcher.restore(id, queued);
    match reason {
        ImportReason::Migrate => metrics.migrations_in += 1,
        ImportReason::MigrateRollback => {
            // the stream never left: un-count the aborted export so
            // failed migrations don't inflate this shard's counters
            metrics.migrations_out = metrics.migrations_out.saturating_sub(1);
        }
        ImportReason::Restore => {
            metrics.streams_restored += 1;
            obs.event(EventKind::StreamRestore, id.0, shard as i64, 0);
        }
        ImportReason::Snapshot => {}
    }
    Ok(evicted)
}

fn shard_main(
    shard: usize,
    cfg: EngineConfig,
    obs: ObsHandle,
    pool: Option<HibernatePool>,
    rx: Receiver<ShardRequest>,
    ready: Sender<Result<(), EngineError>>,
    inj: FaultInjector,
) -> Result<(), EngineError> {
    let (_rt, stepper) = match init_stepper(&cfg) {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e.clone()));
            return Err(e);
        }
    };
    // auto-fallback silently changes the latency class — always say
    // which backend (and kernel path) actually came up
    eprintln!(
        "deepcot engine: shard {shard} serving {} on the {} backend (slots={}, dispatch={})",
        cfg.variant,
        stepper.backend_name(),
        stepper.capacity(),
        stepper.kernel_dispatch()
    );
    obs.event(
        EventKind::DispatchResolved,
        0,
        shard as i64,
        EventKind::dispatch_aux(stepper.kernel_dispatch()),
    );
    // Crash isolation: a panic anywhere in the serve loop (backend bug,
    // injected fault) must kill only this shard. The mutable serving
    // state is confined to the closure, so nothing observable outlives
    // the unwind — AssertUnwindSafe is sound here.
    let mut stepper = stepper;
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_loop(shard, &cfg, &obs, &pool, &rx, &mut stepper, &inj)
    }));
    match caught {
        Ok(res) => res,
        Err(_) => Err(EngineError::ShardFailed { retryable: true }),
    }
}

/// The shard worker's request/tick loop — everything after backend
/// init. Runs under `catch_unwind` in [`shard_main`]; returning `Err`
/// (backend tick failure) and panicking are both reported to the
/// supervisor as a [`ShardFailure`].
fn serve_loop(
    shard: usize,
    cfg: &EngineConfig,
    obs: &ObsHandle,
    pool: &Option<HibernatePool>,
    rx: &Receiver<ShardRequest>,
    stepper: &mut SlotStepper,
    // the engine-wide injector, shared across worker incarnations so a
    // one-shot `@N` schedule stays one-shot through a respawn
    inj: &FaultInjector,
) -> Result<(), EngineError> {
    let spans_on = obs.spans_on();
    let lane_elems = {
        let c = stepper.config();
        c.m_tokens * c.d_in
    };
    let mut router = Router::new(stepper.capacity(), cfg.idle_timeout);
    let mut batcher = Batcher::new(cfg.batch_deadline, cfg.max_queue_per_stream);
    let mut ports: BTreeMap<StreamId, StreamPort> = Default::default();
    let mut metrics = EngineMetrics::new();
    metrics.kernel_dispatch = stepper.kernel_dispatch().to_string();

    loop {
        // 1. drain / wait for requests up to the batching deadline
        let wait = if batcher.pending_len() > 0 {
            cfg.batch_deadline / 4
        } else {
            Duration::from_millis(50)
        };
        match rx.recv_timeout(wait) {
            Ok(req) => {
                let now = Instant::now();
                match req {
                    ShardRequest::Open { id, reply } => {
                        // with hibernation on, a full shard spills its
                        // coldest stream to the store instead of dropping
                        // an idle one
                        let spilled = make_room(
                            now,
                            shard,
                            obs,
                            pool,
                            stepper,
                            &mut router,
                            &mut batcher,
                            &mut ports,
                            &mut metrics,
                            spans_on,
                        );
                        let (adm, evicted) = router.admit(id, now);
                        if let Some(eid) = evicted {
                            // the victim's port and queued tokens go with
                            // it: its owner sees a disconnected channel
                            batcher.forget(eid);
                            ports.remove(&eid);
                            metrics.streams_evicted += 1;
                            obs.event(EventKind::StreamEvict, eid.0, shard as i64, 0);
                        }
                        let evicted = spilled.or(evicted);
                        let res = match adm {
                            Admission::Accepted(slot) => {
                                stepper.clear_lane(slot);
                                let (out_tx, out_rx) = mpsc::channel();
                                ports.insert(id, StreamPort { out: out_tx, ticks: 0 });
                                metrics.streams_opened += 1;
                                obs.event(EventKind::StreamOpen, id.0, shard as i64, 0);
                                Ok((out_rx, evicted))
                            }
                            Admission::Rejected => {
                                metrics.admission_rejects += 1;
                                obs.event(EventKind::AdmissionReject, id.0, shard as i64, 0);
                                Err(EngineError::Saturated { capacity: router.capacity() })
                            }
                        };
                        let _ = reply.send(res);
                    }
                    ShardRequest::Push { id, tokens, reply } => {
                        let res = if router.slot_of(id).is_none() {
                            // hand the tokens back: the stream may have
                            // migrated and the front door will re-route
                            Err((EngineError::StreamClosed(id), Some(tokens)))
                        } else if tokens.len() != lane_elems {
                            Err((
                                EngineError::InvalidRequest(format!(
                                    "expected {lane_elems} f32 tokens, got {}",
                                    tokens.len()
                                )),
                                None,
                            ))
                        } else if batcher.push(id, tokens, now) {
                            metrics.tokens_in += 1;
                            if spans_on {
                                metrics.stage_spans.record(Stage::Ingress, now.elapsed());
                            }
                            Ok(())
                        } else {
                            Err((EngineError::Backpressure(id), None))
                        };
                        let _ = reply.send(res);
                    }
                    ShardRequest::Close { id } => {
                        // count only streams that were actually bound: a
                        // late close of an already-evicted stream must
                        // not double-count as both evicted and closed
                        if let Some(slot) = router.close(id) {
                            stepper.clear_lane(slot);
                            metrics.streams_closed += 1;
                            obs.event(EventKind::StreamClose, id.0, shard as i64, 0);
                        }
                        batcher.forget(id);
                        ports.remove(&id);
                    }
                    ShardRequest::Export { id, for_migration, reply } => {
                        let res = match router.slot_of(id) {
                            None => Err(EngineError::StreamClosed(id)),
                            Some(slot) => {
                                let mut state = StreamState::default();
                                match (stepper.export_lane(slot, &mut state), ports.remove(&id)) {
                                    (Ok(()), Some(port)) => {
                                        router.close(id);
                                        stepper.clear_lane(slot);
                                        let queued = batcher.extract(id);
                                        if for_migration {
                                            metrics.migrations_out += 1;
                                        }
                                        Ok(Box::new(ExportedStream {
                                            state,
                                            port: port.out,
                                            ticks: port.ticks,
                                            queued,
                                        }))
                                    }
                                    (Ok(()), None) => Err(EngineError::Internal(format!(
                                        "stream {} bound without an output port",
                                        id.0
                                    ))),
                                    (Err(e), port) => {
                                        // e.g. PJRT: stream stays serving
                                        if let Some(p) = port {
                                            ports.insert(id, p);
                                        }
                                        Err(e)
                                    }
                                }
                            }
                        };
                        if spans_on && res.is_ok() && for_migration {
                            metrics.stage_spans.record(Stage::MigExport, now.elapsed());
                        }
                        let _ = reply.send(res);
                    }
                    ShardRequest::Import { id, payload, reason, reply } => {
                        let res = import_stream(
                            id,
                            payload,
                            reason,
                            now,
                            shard,
                            obs,
                            pool,
                            stepper,
                            &mut router,
                            &mut batcher,
                            &mut ports,
                            &mut metrics,
                            spans_on,
                        );
                        if spans_on && res.is_ok() {
                            match reason {
                                ImportReason::Migrate | ImportReason::MigrateRollback => {
                                    metrics.stage_spans.record(Stage::MigImport, now.elapsed());
                                }
                                ImportReason::Restore => {
                                    metrics
                                        .stage_spans
                                        .record(Stage::HibernateRestore, now.elapsed());
                                }
                                // snapshot round-trips are measured whole
                                // at the front door (Stage::Snapshot)
                                ImportReason::Snapshot => {}
                            }
                        }
                        let _ = reply.send(res);
                    }
                    ShardRequest::Metrics { reply } => {
                        let _ = reply.send(metrics.clone());
                    }
                    ShardRequest::Shutdown => return drain(shard, rx, &metrics),
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return Ok(()),
        }

        // 2. tick when the policy says so
        let now = Instant::now();
        if batcher.ready(router.occupied(), now) {
            let plan = batcher.take_tick(|id| router.slot_of(id));
            if plan.lanes.is_empty() {
                continue;
            }
            let mut oldest = now;
            for (_, _, _, enq) in &plan.lanes {
                metrics.queue_latency.record(now.duration_since(*enq));
                if *enq < oldest {
                    oldest = *enq;
                }
            }
            let t0 = Instant::now();
            // deterministic chaos: the injector counts only this
            // shard's ticks when it is the plan's target, so `@N`
            // fires on the N-th tick of a known shard, every run
            if inj.fire_on_shard(FaultSite::ShardStep, shard as u64) {
                panic!("injected fault: shard-step (shard {shard})");
            }
            let lanes = stepper.tick_lanes(&plan)?;
            let stepped = Instant::now();
            metrics.tick_latency.record(stepped.duration_since(t0));
            metrics.ticks += 1;
            let done = Instant::now();
            for lane in lanes {
                router.touch(lane.stream, done);
                if let Some(port) = ports.get_mut(&lane.stream) {
                    port.ticks += 1;
                    metrics.outputs += 1;
                    let _ = port.out.send(TickResult {
                        logits: lane.logits,
                        out: lane.out,
                        tick: port.ticks,
                    });
                }
            }
            if spans_on {
                // contiguous segments over [oldest-enqueue, delivered]:
                // queue + batch-form + backend-step + deliver sum (within
                // timer truncation) to pipeline-total — pinned by a test
                let delivered = Instant::now();
                metrics.stage_spans.record(Stage::Queue, now.duration_since(oldest));
                metrics.stage_spans.record(Stage::BatchForm, t0.duration_since(now));
                metrics.stage_spans.record(Stage::BackendStep, stepped.duration_since(t0));
                metrics.stage_spans.record(Stage::Deliver, delivered.duration_since(stepped));
                let total = delivered.duration_since(oldest);
                metrics.stage_spans.record(Stage::PipelineTotal, total);
                if total > cfg.slow_tick {
                    metrics.slow_ticks += 1;
                    obs.event(EventKind::SlowTick, 0, shard as i64, total.as_micros() as u64);
                }
            }
        }
    }
}

/// Post-shutdown drain: answer every request still queued with a
/// terminal [`EngineError::ShuttingDown`] so no caller is left blocked
/// on a reply channel (metrics requests are still served the final
/// snapshot). Requests arriving after the drain observes an empty
/// queue get the generic disconnected-channel error when the receiver
/// drops.
fn drain(
    _shard: usize,
    rx: &Receiver<ShardRequest>,
    metrics: &EngineMetrics,
) -> Result<(), EngineError> {
    loop {
        match rx.try_recv() {
            Ok(ShardRequest::Open { reply, .. }) => {
                let _ = reply.send(Err(EngineError::ShuttingDown));
            }
            Ok(ShardRequest::Push { reply, .. }) => {
                let _ = reply.send(Err((EngineError::ShuttingDown, None)));
            }
            Ok(ShardRequest::Export { reply, .. }) => {
                let _ = reply.send(Err(EngineError::ShuttingDown));
            }
            Ok(ShardRequest::Import { payload, reply, .. }) => {
                let _ = reply.send(Err((EngineError::ShuttingDown, Some(payload), None)));
            }
            Ok(ShardRequest::Metrics { reply }) => {
                let _ = reply.send(metrics.clone());
            }
            Ok(ShardRequest::Close { .. }) | Ok(ShardRequest::Shutdown) => {}
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return Ok(()),
        }
    }
}
