//! One engine shard: a worker thread owning a complete serving cell —
//! its own execution backend ([`SlotStepper`]: PJRT handles are
//! `Rc`-based and the scalar backend is plain host memory, so
//! everything state-touching lives on this thread), its own [`Router`]
//! (admission, idle eviction), [`Batcher`] (deadline / all-slots tick
//! policy) and [`EngineMetrics`]. The cluster front door
//! (`coordinator::cluster`) spawns N of these and pins each stream to
//! one shard; a 1-shard cluster is exactly the old single-threaded
//! engine.
//!
//! Data flow per tick (within one shard):
//!   front door → Open/Push ─┐
//!                           ├→ Batcher (deadline / all-slots policy)
//!   Router (slots) ─────────┘        │
//!                                    ▼
//!                  SlotStepper.tick (one batched step, all live lanes)
//!                                    │
//!          per-stream output channels ← scatter lanes + metrics
//!
//! Stream ids are assigned by the front door (a cluster-global
//! namespace), so a stream keeps its id no matter which shard it lands
//! on; the shard's router only binds ids to batch lanes.
//!
//! Shutdown discipline: on [`ShardRequest::Shutdown`] the worker drains
//! every request still queued in its channel and answers each with a
//! terminal error (final metrics are still served) — a caller blocked
//! on a reply is never left hanging, and queued pushes fail loudly
//! instead of silently dropping their ticks.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{EngineBackend, EngineConfig};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::EngineMetrics;
use crate::coordinator::router::{Admission, Router};
use crate::coordinator::slot_stepper::SlotStepper;
use crate::coordinator::slots::StreamId;
use crate::manifest::Manifest;
use crate::nn::params::ModelParams;
use crate::runtime::Runtime;

/// One tick's result delivered to a stream's owner.
#[derive(Debug, Clone)]
pub struct TickResult {
    pub logits: Vec<f32>,
    pub out: Vec<f32>,
    /// Per-stream tick ordinal (1-based; counts only this stream's ticks).
    pub tick: u64,
}

/// A successful admission: the stream's output channel, plus the idle
/// session this shard evicted to make room (the front door must drop
/// the victim's binding too — its owner may never close it).
pub(crate) type Admitted = (Receiver<TickResult>, Option<StreamId>);

pub(crate) enum ShardRequest {
    Open { id: StreamId, reply: Sender<Result<Admitted>> },
    Push { id: StreamId, tokens: Vec<f32>, reply: Sender<Result<()>> },
    Close { id: StreamId },
    Metrics { reply: Sender<EngineMetrics> },
    Shutdown,
}

/// Cloneable, `Send` handle to one shard's worker thread.
#[derive(Clone)]
pub(crate) struct ShardHandle {
    shard: usize,
    tx: SyncSender<ShardRequest>,
}

impl ShardHandle {
    /// Bind a front-door-assigned stream id; returns its output channel
    /// and the idle stream evicted to make room, if any.
    pub(crate) fn open(&self, id: StreamId) -> Result<Admitted> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ShardRequest::Open { id, reply })
            .map_err(|_| anyhow!("shard {} is gone", self.shard))?;
        rx.recv().map_err(|_| anyhow!("shard {} dropped reply", self.shard))?
    }

    /// Submit the next token(s) for a stream bound to this shard.
    pub(crate) fn push(&self, id: StreamId, tokens: Vec<f32>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ShardRequest::Push { id, tokens, reply })
            .map_err(|_| anyhow!("shard {} is gone", self.shard))?;
        rx.recv().map_err(|_| anyhow!("shard {} dropped reply", self.shard))?
    }

    pub(crate) fn close(&self, id: StreamId) {
        let _ = self.tx.send(ShardRequest::Close { id });
    }

    pub(crate) fn metrics(&self) -> Result<EngineMetrics> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ShardRequest::Metrics { reply })
            .map_err(|_| anyhow!("shard {} is gone", self.shard))?;
        rx.recv().map_err(|_| anyhow!("shard {} dropped reply", self.shard))
    }

    pub(crate) fn signal_shutdown(&self) {
        let _ = self.tx.send(ShardRequest::Shutdown);
    }
}

pub(crate) struct ShardThread {
    handle: ShardHandle,
    /// Startup signal, consumed by [`Self::wait_ready`].
    ready: Option<Receiver<Result<()>>>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ShardThread {
    /// Start one shard worker WITHOUT waiting for its backend: the
    /// cluster starts every shard first and then waits on all of them,
    /// so N shards load their models in parallel instead of serially.
    pub(crate) fn start(shard: usize, cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<ShardRequest>(cfg.request_queue);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("deepcot-shard-{shard}"))
            .spawn(move || shard_main(shard, cfg, rx, ready_tx))?;
        Ok(Self {
            handle: ShardHandle { shard, tx },
            ready: Some(ready_rx),
            join: Some(join),
        })
    }

    /// Block until the shard's model is loaded and the backend is up
    /// (so the first Push never pays compile latency). Idempotent.
    pub(crate) fn wait_ready(&mut self) -> Result<()> {
        match self.ready.take() {
            Some(rx) => rx
                .recv()
                .map_err(|_| anyhow!("shard {} died during startup", self.handle.shard))?,
            None => Ok(()),
        }
    }

    pub(crate) fn handle(&self) -> ShardHandle {
        self.handle.clone()
    }

    pub(crate) fn signal_shutdown(&self) {
        self.handle.signal_shutdown();
    }

    pub(crate) fn join(&mut self) -> Result<()> {
        if let Some(j) = self.join.take() {
            j.join()
                .map_err(|_| anyhow!("shard {} panicked", self.handle.shard))??;
        }
        Ok(())
    }
}

impl Drop for ShardThread {
    fn drop(&mut self) {
        self.signal_shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Backend selection: PJRT when the XLA runtime is available, the
/// pure-Rust batched scalar engine otherwise (or on request) — same
/// manifest, same weights, same lane semantics. The scalar backend
/// honors `cfg.slots_per_shard`; PJRT capacity is AOT-compiled, so an
/// override there is an error (under `auto` it simply falls through to
/// the scalar backend).
fn init_stepper(cfg: &EngineConfig) -> Result<(Option<Runtime>, SlotStepper)> {
    let pjrt = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper)> {
        if cfg.slots_per_shard != 0 {
            bail!(
                "per-shard slot capacity override requires the scalar backend \
                 (PJRT batch is AOT-compiled)"
            );
        }
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        let variant = rt.load(&cfg.variant)?;
        let stepper = SlotStepper::new(variant)?;
        Ok((Some(rt), stepper))
    };
    let scalar = |cfg: &EngineConfig| -> Result<(Option<Runtime>, SlotStepper)> {
        let (manifest, dir) = Manifest::load(&cfg.artifacts_dir)?;
        let entry = manifest.variant(&cfg.variant)?;
        let params = ModelParams::load(&dir, entry)?;
        let capacity = if cfg.slots_per_shard != 0 {
            cfg.slots_per_shard
        } else {
            entry.config.batch
        };
        Ok((None, SlotStepper::new_scalar_with_capacity(entry, params, capacity)?))
    };
    match cfg.backend {
        EngineBackend::Pjrt => pjrt(cfg),
        EngineBackend::Scalar => scalar(cfg),
        EngineBackend::Auto => pjrt(cfg).or_else(|pe| {
            scalar(cfg).map_err(|se| anyhow!("pjrt backend: {pe}; scalar fallback: {se}"))
        }),
    }
}

struct StreamPort {
    out: Sender<TickResult>,
    ticks: u64,
}

fn shard_main(
    shard: usize,
    cfg: EngineConfig,
    rx: Receiver<ShardRequest>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let (_rt, mut stepper) = match init_stepper(&cfg) {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("{e}")));
            bail!("shard {shard} init failed");
        }
    };
    // auto-fallback silently changes the latency class — always say
    // which backend actually came up
    eprintln!(
        "deepcot engine: shard {shard} serving {} on the {} backend (slots={})",
        cfg.variant,
        stepper.backend_name(),
        stepper.capacity()
    );
    let lane_elems = {
        let c = stepper.config();
        c.m_tokens * c.d_in
    };
    let mut router = Router::new(stepper.capacity(), cfg.idle_timeout);
    let mut batcher = Batcher::new(cfg.batch_deadline, cfg.max_queue_per_stream);
    let mut ports: std::collections::BTreeMap<StreamId, StreamPort> = Default::default();
    let mut metrics = EngineMetrics::new();

    loop {
        // 1. drain / wait for requests up to the batching deadline
        let wait = if batcher.pending_len() > 0 {
            cfg.batch_deadline / 4
        } else {
            Duration::from_millis(50)
        };
        match rx.recv_timeout(wait) {
            Ok(req) => {
                let now = Instant::now();
                match req {
                    ShardRequest::Open { id, reply } => {
                        let (adm, evicted) = router.admit(id, now);
                        if let Some(eid) = evicted {
                            // the victim's port and queued tokens go with
                            // it: its owner sees a disconnected channel
                            batcher.forget(eid);
                            ports.remove(&eid);
                            metrics.streams_evicted += 1;
                        }
                        let res = match adm {
                            Admission::Accepted(slot) => {
                                stepper.clear_lane(slot);
                                let (out_tx, out_rx) = mpsc::channel();
                                ports.insert(id, StreamPort { out: out_tx, ticks: 0 });
                                metrics.streams_opened += 1;
                                Ok((out_rx, evicted))
                            }
                            Admission::Rejected => {
                                metrics.admission_rejects += 1;
                                Err(anyhow!(
                                    "shard {shard}: no free slots (capacity {})",
                                    router.capacity()
                                ))
                            }
                        };
                        let _ = reply.send(res);
                    }
                    ShardRequest::Push { id, tokens, reply } => {
                        let res = if router.slot_of(id).is_none() {
                            Err(anyhow!("unknown stream {id:?}"))
                        } else if tokens.len() != lane_elems {
                            Err(anyhow!(
                                "expected {lane_elems} f32 tokens, got {}",
                                tokens.len()
                            ))
                        } else if batcher.push(id, tokens, now) {
                            metrics.tokens_in += 1;
                            Ok(())
                        } else {
                            Err(anyhow!("stream {id:?} queue full (backpressure)"))
                        };
                        let _ = reply.send(res);
                    }
                    ShardRequest::Close { id } => {
                        // count only streams that were actually bound: a
                        // late close of an already-evicted stream must
                        // not double-count as both evicted and closed
                        if let Some(slot) = router.close(id) {
                            stepper.clear_lane(slot);
                            metrics.streams_closed += 1;
                        }
                        batcher.forget(id);
                        ports.remove(&id);
                    }
                    ShardRequest::Metrics { reply } => {
                        let _ = reply.send(metrics.clone());
                    }
                    ShardRequest::Shutdown => return drain(shard, &rx, &metrics),
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return Ok(()),
        }

        // 2. tick when the policy says so
        let now = Instant::now();
        if batcher.ready(router.occupied(), now) {
            let plan = batcher.take_tick(|id| router.slot_of(id));
            if plan.lanes.is_empty() {
                continue;
            }
            for (_, _, _, enq) in &plan.lanes {
                metrics.queue_latency.record(now.duration_since(*enq));
            }
            let t0 = Instant::now();
            let lanes = stepper.tick(&plan)?;
            metrics.tick_latency.record(t0.elapsed());
            metrics.ticks += 1;
            let done = Instant::now();
            for lane in lanes {
                router.touch(lane.stream, done);
                if let Some(port) = ports.get_mut(&lane.stream) {
                    port.ticks += 1;
                    metrics.outputs += 1;
                    let _ = port.out.send(TickResult {
                        logits: lane.logits,
                        out: lane.out,
                        tick: port.ticks,
                    });
                }
            }
        }
    }
}

/// Post-shutdown drain: answer every request still queued with a
/// terminal error so no caller is left blocked on a reply channel
/// (metrics requests are still served the final snapshot). Requests
/// arriving after the drain observes an empty queue get the generic
/// disconnected-channel error when the receiver drops.
fn drain(shard: usize, rx: &Receiver<ShardRequest>, metrics: &EngineMetrics) -> Result<()> {
    loop {
        match rx.try_recv() {
            Ok(ShardRequest::Open { reply, .. }) => {
                let _ = reply.send(Err(anyhow!("shard {shard} is shutting down")));
            }
            Ok(ShardRequest::Push { reply, .. }) => {
                let _ = reply.send(Err(anyhow!(
                    "shard {shard} shut down before this push was served"
                )));
            }
            Ok(ShardRequest::Metrics { reply }) => {
                let _ = reply.send(metrics.clone());
            }
            Ok(ShardRequest::Close { .. }) | Ok(ShardRequest::Shutdown) => {}
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return Ok(()),
        }
    }
}
