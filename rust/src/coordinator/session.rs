//! The client layer: typed [`EngineError`]s and the RAII [`Session`]
//! stream handle — the only public client path into the serving
//! cluster.
//!
//! `EngineHandle::open` hands back a [`Session`] that owns the stream
//! for its lifetime: `push` submits tokens, `recv`/`try_recv` read
//! [`TickResult`]s, and dropping the session closes the stream at the
//! front door (no leaked slots when a client unwinds). Every fallible
//! operation returns an [`EngineError`] variant instead of a stringly
//! error, so callers can branch on backpressure vs saturation vs
//! shutdown without parsing messages. [`Session::split_receiver`]
//! detaches the receiving half as a [`TickReceiver`] for callers whose
//! push and receive sides live on different threads (the net server).

use std::fmt;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Duration;

use crate::coordinator::cluster::EngineHandle;
use crate::coordinator::shard::TickResult;
use crate::coordinator::slots::StreamId;

/// Typed serving-path errors. Clients branch on the variant; `Display`
/// renders an operator-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// Admission failed everywhere it was tried: every candidate shard
    /// is at capacity with nothing evictable.
    Saturated {
        /// Slot capacity of the scope that rejected the request.
        capacity: usize,
    },
    /// The stream is closed, evicted, or was never opened.
    StreamClosed(StreamId),
    /// The stream's pending-token queue is full; retry after consuming
    /// results (the backpressure signal).
    Backpressure(StreamId),
    /// The engine (or the owning shard) is shutting down or gone —
    /// also how a poisoned/panicked shard surfaces to clients.
    ShuttingDown,
    /// No tick result arrived within the caller's deadline.
    Timeout,
    /// The request was malformed (e.g. a wrong token-vector length).
    InvalidRequest(String),
    /// The stream is hibernated (its state lives in the state store)
    /// and has no live owner to restore it through — re-open it with a
    /// resume request to wake it.
    Hibernated(StreamId),
    /// The stream's shard worker crashed. When `retryable` the
    /// supervisor is re-homing the shard's streams onto survivors —
    /// retry the request (a re-homed stream resumes from its last
    /// checkpoint via an OPEN-resume); when not, the failure is
    /// permanent for this stream (no checkpoint existed).
    ShardFailed {
        /// Whether the caller should retry after the supervisor
        /// finishes re-homing (`true` for checkpointed streams).
        retryable: bool,
    },
    /// The active backend cannot perform the operation (e.g. stream
    /// snapshot export on the PJRT backend).
    Unsupported(String),
    /// An internal engine failure (model/backend/runtime error).
    Internal(String),
}

impl EngineError {
    /// Wrap any displayable internal failure as [`EngineError::Internal`].
    pub fn internal<E: fmt::Display>(e: E) -> Self {
        EngineError::Internal(e.to_string())
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Saturated { capacity } => {
                write!(f, "no free slots (capacity {capacity})")
            }
            EngineError::StreamClosed(id) => write!(f, "stream {} is closed or unknown", id.0),
            EngineError::Backpressure(id) => {
                write!(f, "stream {} queue full (backpressure)", id.0)
            }
            EngineError::ShuttingDown => write!(f, "engine is shutting down"),
            EngineError::Timeout => write!(f, "timed out waiting for a tick result"),
            EngineError::InvalidRequest(m) => write!(f, "invalid request: {m}"),
            EngineError::Hibernated(id) => {
                write!(f, "stream {} is hibernated; resume it to push", id.0)
            }
            EngineError::ShardFailed { retryable: true } => {
                write!(f, "shard worker failed; streams are being re-homed — retry")
            }
            EngineError::ShardFailed { retryable: false } => {
                write!(f, "shard worker failed; stream state was lost (no checkpoint)")
            }
            EngineError::Unsupported(m) => write!(f, "unsupported: {m}"),
            EngineError::Internal(m) => write!(f, "engine internal error: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// RAII handle to one open stream: push tokens, receive tick results,
/// and close on drop. Obtained from `EngineHandle::open`; the session
/// owns the stream's front-door binding for its whole lifetime, so a
/// client that unwinds (panic, early return) cannot leak its slot.
pub struct Session {
    id: StreamId,
    rx: Option<Receiver<TickResult>>,
    handle: EngineHandle,
    closed: bool,
}

/// The receiving half of a split [`Session`] (see
/// [`Session::split_receiver`]): same `recv` / `recv_timeout` /
/// `try_recv` semantics, movable to another thread while the session
/// itself keeps pushing (an mpsc receiver is `Send` but not `Sync`, so
/// the two halves cannot share one handle across threads). Dropping the
/// receiver does NOT close the stream — the session half owns the RAII
/// close.
pub struct TickReceiver {
    id: StreamId,
    rx: Receiver<TickResult>,
}

impl TickReceiver {
    /// The stream this receiver belongs to.
    pub fn id(&self) -> StreamId {
        self.id
    }

    /// Block for the next tick result (see [`Session::recv`]).
    pub fn recv(&self) -> Result<TickResult, EngineError> {
        self.rx.recv().map_err(|_| EngineError::StreamClosed(self.id))
    }

    /// Block for the next tick result up to `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<TickResult, EngineError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => Err(EngineError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(EngineError::StreamClosed(self.id)),
        }
    }

    /// Non-blocking poll: `Ok(None)` when no result is ready yet.
    pub fn try_recv(&self) -> Result<Option<TickResult>, EngineError> {
        match self.rx.try_recv() {
            Ok(r) => Ok(Some(r)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(EngineError::StreamClosed(self.id)),
        }
    }
}

impl fmt::Debug for TickReceiver {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TickReceiver({})", self.id.0)
    }
}

impl Session {
    pub(crate) fn attach(id: StreamId, rx: Receiver<TickResult>, handle: EngineHandle) -> Self {
        Self { id, rx: Some(rx), handle, closed: false }
    }

    /// Detach the receiving half so pushes and receives can run on
    /// different threads (the net server's workers push while its poll
    /// loop drains the receiver half into the connection's write
    /// queue). Returns `None` if the receiver was already taken. After the
    /// split the session's own `recv`/`try_recv` report
    /// [`EngineError::StreamClosed`]; `push`, `close`, and the RAII
    /// close-on-drop are unaffected.
    pub fn split_receiver(&mut self) -> Option<TickReceiver> {
        self.rx.take().map(|rx| TickReceiver { id: self.id, rx })
    }

    /// The cluster-unique stream id (for logs, metrics correlation, and
    /// migration requests).
    pub fn id(&self) -> StreamId {
        self.id
    }

    /// Submit the next token vector (`m_tokens * d_in` f32s). Routed to
    /// the stream's current shard — transparently follows a live
    /// migration.
    pub fn push(&self, tokens: Vec<f32>) -> Result<(), EngineError> {
        self.handle.push_raw(self.id, tokens)
    }

    /// Block for the next tick result. Errors with
    /// [`EngineError::StreamClosed`] once the stream is torn down
    /// (evicted, the engine shut down, or the receiver was split off).
    pub fn recv(&self) -> Result<TickResult, EngineError> {
        match &self.rx {
            Some(rx) => rx.recv().map_err(|_| EngineError::StreamClosed(self.id)),
            None => Err(EngineError::StreamClosed(self.id)),
        }
    }

    /// Block for the next tick result up to `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<TickResult, EngineError> {
        let Some(rx) = &self.rx else {
            return Err(EngineError::StreamClosed(self.id));
        };
        match rx.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => Err(EngineError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(EngineError::StreamClosed(self.id)),
        }
    }

    /// Non-blocking poll: `Ok(None)` when no result is ready yet.
    pub fn try_recv(&self) -> Result<Option<TickResult>, EngineError> {
        let Some(rx) = &self.rx else {
            return Err(EngineError::StreamClosed(self.id));
        };
        match rx.try_recv() {
            Ok(r) => Ok(Some(r)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(EngineError::StreamClosed(self.id)),
        }
    }

    /// Close the stream now (equivalent to dropping the session, but
    /// explicit at call sites that care about ordering).
    pub fn close(mut self) {
        self.closed = true;
        self.handle.close_raw(self.id);
    }

    /// Disarm the RAII close WITHOUT touching the engine. For zombie
    /// session objects only: after a shard crash re-homes a stream and
    /// a resume mints it a new owner, the old session refers to a
    /// stream it no longer owns — closing through the corpse would
    /// tear down (and un-persist) the resumed stream.
    pub(crate) fn forget(mut self) {
        self.closed = true;
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if !self.closed {
            self.handle.close_raw(self.id);
        }
    }
}

impl fmt::Debug for Session {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Session({})", self.id.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_render_operator_messages() {
        assert_eq!(
            EngineError::Saturated { capacity: 4 }.to_string(),
            "no free slots (capacity 4)"
        );
        assert_eq!(
            EngineError::StreamClosed(StreamId(7)).to_string(),
            "stream 7 is closed or unknown"
        );
        assert_eq!(
            EngineError::Backpressure(StreamId(3)).to_string(),
            "stream 3 queue full (backpressure)"
        );
        assert_eq!(EngineError::ShuttingDown.to_string(), "engine is shutting down");
        assert_eq!(
            EngineError::ShardFailed { retryable: true }.to_string(),
            "shard worker failed; streams are being re-homed — retry"
        );
        assert_eq!(
            EngineError::ShardFailed { retryable: false }.to_string(),
            "shard worker failed; stream state was lost (no checkpoint)"
        );
        assert_eq!(
            EngineError::Hibernated(StreamId(9)).to_string(),
            "stream 9 is hibernated; resume it to push"
        );
        assert!(EngineError::internal("boom").to_string().contains("boom"));
    }

    #[test]
    fn errors_convert_into_anyhow() {
        fn fallible() -> anyhow::Result<u32> {
            Err(EngineError::ShuttingDown)?
        }
        assert!(fallible().unwrap_err().to_string().contains("shutting down"));
    }
}
