//! Tick assembly: decides *when* to run a batched step and gathers the
//! pending token of each bound stream into its slot lane.
//!
//! Policy (vLLM-router-flavoured, adapted to fixed slots): flush when
//! every occupied slot has a pending token, or when the oldest pending
//! token has waited past the deadline. Slots without a pending token at
//! flush time are masked (zero tokens; outputs dropped) — a stream
//! skipping a tick does not advance its position.
//!
//! Pure logic with an injected clock: fully unit/property-testable
//! without the engine thread.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::coordinator::slots::StreamId;

#[derive(Debug, Clone)]
pub struct Pending {
    pub tokens: Vec<f32>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct Batcher {
    pending: BTreeMap<StreamId, Pending>,
    pub deadline: Duration,
    /// max tokens a stream may queue ahead (backpressure bound)
    pub max_queue_per_stream: usize,
    queued: BTreeMap<StreamId, Vec<Pending>>,
}

/// One assembled tick: lane-indexed tokens + which lanes are live.
#[derive(Debug, Clone)]
pub struct TickPlan {
    /// per live lane: (slot, stream, tokens, enqueue time)
    pub lanes: Vec<(usize, StreamId, Vec<f32>, Instant)>,
}

impl Batcher {
    pub fn new(deadline: Duration, max_queue_per_stream: usize) -> Self {
        Self {
            pending: BTreeMap::new(),
            deadline,
            max_queue_per_stream: max_queue_per_stream.max(1),
            queued: BTreeMap::new(),
        }
    }

    /// Enqueue a token vector for a stream. Returns false (rejected)
    /// when the stream's queue is full — the backpressure signal.
    pub fn push(&mut self, id: StreamId, tokens: Vec<f32>, now: Instant) -> bool {
        let p = Pending { tokens, enqueued: now };
        if self.pending.contains_key(&id) {
            let q = self.queued.entry(id).or_default();
            if q.len() >= self.max_queue_per_stream {
                return false;
            }
            q.push(p);
        } else {
            self.pending.insert(id, p);
        }
        true
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn queued_len(&self, id: StreamId) -> usize {
        self.pending.contains_key(&id) as usize
            + self.queued.get(&id).map(|q| q.len()).unwrap_or(0)
    }

    /// Drop all state for a closed stream.
    pub fn forget(&mut self, id: StreamId) {
        self.pending.remove(&id);
        self.queued.remove(&id);
    }

    /// Remove and return everything a stream has queued, FIFO order
    /// (pending head first) — the quiesce step of a live migration:
    /// the tokens travel with the stream to its new shard.
    pub fn extract(&mut self, id: StreamId) -> Vec<Pending> {
        let mut v = Vec::new();
        if let Some(p) = self.pending.remove(&id) {
            v.push(p);
        }
        if let Some(q) = self.queued.remove(&id) {
            v.extend(q);
        }
        v
    }

    /// Reinstate an [`Self::extract`]ed queue on this batcher (the
    /// import step of a live migration), preserving FIFO order and the
    /// original enqueue timestamps. The stream must have no pending
    /// state here yet (it was just admitted).
    pub fn restore(&mut self, id: StreamId, mut items: Vec<Pending>) {
        debug_assert!(!self.pending.contains_key(&id), "restore over live pending state");
        if items.is_empty() {
            return;
        }
        let rest = items.split_off(1);
        if let Some(first) = items.pop() {
            self.pending.insert(id, first);
        }
        if !rest.is_empty() {
            self.queued.insert(id, rest);
        }
    }

    /// Should we flush now, given the set of occupied streams?
    pub fn ready(&self, occupied: usize, now: Instant) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        if self.pending.len() >= occupied.max(1) {
            return true; // every bound stream has a token
        }
        self.pending
            .values()
            .any(|p| now.duration_since(p.enqueued) >= self.deadline)
    }

    /// Assemble the tick for the given slot binding and refill pending
    /// slots from per-stream queues.
    pub fn take_tick<F: Fn(StreamId) -> Option<usize>>(&mut self, slot_of: F) -> TickPlan {
        let ids: Vec<StreamId> = self.pending.keys().copied().collect();
        let mut lanes = Vec::with_capacity(ids.len());
        for id in ids {
            let Some(slot) = slot_of(id) else { continue };
            let Some(p) = self.pending.remove(&id) else {
                continue;
            };
            lanes.push((slot, id, p.tokens, p.enqueued));
            if let Some(q) = self.queued.get_mut(&id) {
                if !q.is_empty() {
                    let next = q.remove(0);
                    self.pending.insert(id, next);
                }
            }
        }
        TickPlan { lanes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn flush_when_all_streams_pending() {
        let mut b = Batcher::new(Duration::from_millis(5), 4);
        let now = t0();
        assert!(!b.ready(2, now));
        b.push(StreamId(1), vec![1.0], now);
        assert!(!b.ready(2, now));
        b.push(StreamId(2), vec![2.0], now);
        assert!(b.ready(2, now));
        let plan = b.take_tick(|id| Some(id.0 as usize - 1));
        assert_eq!(plan.lanes.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn deadline_forces_partial_tick() {
        let mut b = Batcher::new(Duration::from_millis(1), 4);
        let now = t0();
        b.push(StreamId(1), vec![1.0], now);
        assert!(!b.ready(2, now));
        assert!(b.ready(2, now + Duration::from_millis(2)));
    }

    #[test]
    fn backpressure_bounds_queue() {
        let mut b = Batcher::new(Duration::from_millis(1), 2);
        let now = t0();
        assert!(b.push(StreamId(1), vec![0.0], now)); // pending
        assert!(b.push(StreamId(1), vec![1.0], now)); // queue 1
        assert!(b.push(StreamId(1), vec![2.0], now)); // queue 2
        assert!(!b.push(StreamId(1), vec![3.0], now)); // rejected
        assert_eq!(b.queued_len(StreamId(1)), 3);
    }

    #[test]
    fn queue_refills_pending_in_order() {
        let mut b = Batcher::new(Duration::from_millis(1), 4);
        let now = t0();
        b.push(StreamId(1), vec![1.0], now);
        b.push(StreamId(1), vec![2.0], now);
        let p1 = b.take_tick(|_| Some(0));
        assert_eq!(p1.lanes[0].2, vec![1.0]);
        let p2 = b.take_tick(|_| Some(0));
        assert_eq!(p2.lanes[0].2, vec![2.0]);
    }

    #[test]
    fn extract_restore_preserves_fifo() {
        let mut a = Batcher::new(Duration::from_millis(1), 8);
        let now = t0();
        for v in 0..4 {
            a.push(StreamId(1), vec![v as f32], now);
        }
        a.push(StreamId(2), vec![9.0], now);
        let moved = a.extract(StreamId(1));
        assert_eq!(moved.len(), 4);
        assert_eq!(a.queued_len(StreamId(1)), 0, "extract must clear the source");
        assert_eq!(a.queued_len(StreamId(2)), 1, "other streams untouched");
        // restore on a different batcher (the target shard's)
        let mut b = Batcher::new(Duration::from_millis(1), 8);
        b.restore(StreamId(1), moved);
        assert_eq!(b.queued_len(StreamId(1)), 4);
        for want in 0..4 {
            let plan = b.take_tick(|_| Some(0));
            assert_eq!(plan.lanes[0].2, vec![want as f32]);
        }
        assert!(b.take_tick(|_| Some(0)).lanes.is_empty());
        // restoring an empty queue is inert
        b.restore(StreamId(3), Vec::new());
        assert_eq!(b.queued_len(StreamId(3)), 0);
    }

    #[test]
    fn unbound_streams_are_skipped() {
        let mut b = Batcher::new(Duration::from_millis(1), 4);
        b.push(StreamId(7), vec![1.0], t0());
        let plan = b.take_tick(|_| None);
        assert!(plan.lanes.is_empty());
    }

    /// Property: tokens per stream are delivered in FIFO order and
    /// nothing is lost or duplicated while queues stay within bounds.
    #[test]
    fn prop_fifo_no_loss() {
        prop::check("batcher-fifo", 150, |rng| {
            let mut b = Batcher::new(Duration::from_millis(1), 8);
            let now = t0();
            let n_streams = rng.range(1, 4);
            let mut sent: Vec<Vec<f32>> = vec![Vec::new(); n_streams];
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); n_streams];
            let mut counter = 0.0f32;
            for _ in 0..rng.range(1, 40) {
                if rng.chance(0.6) {
                    let s = rng.below(n_streams);
                    if b.push(StreamId(s as u64), vec![counter], now) {
                        sent[s].push(counter);
                    }
                    counter += 1.0;
                } else {
                    let plan = b.take_tick(|id| Some(id.0 as usize));
                    for (_, id, toks, _) in plan.lanes {
                        got[id.0 as usize].push(toks[0]);
                    }
                }
            }
            loop {
                let plan = b.take_tick(|id| Some(id.0 as usize));
                if plan.lanes.is_empty() {
                    break;
                }
                for (_, id, toks, _) in plan.lanes {
                    got[id.0 as usize].push(toks[0]);
                }
            }
            for s in 0..n_streams {
                if got[s] != sent[s] {
                    return Err(format!("stream {s}: sent {:?} got {:?}", sent[s], got[s]));
                }
            }
            Ok(())
        });
    }
}
