//! The backend layer: the [`StreamBackend`] trait — batched continual
//! stepping with per-lane stream state — and the two built-in
//! implementations behind the [`SlotStepper`] front:
//!
//! * **PJRT** — the batched AOT executable; state is mirrored host-side
//!   (the CPU PJRT feedback path round-trips through the host anyway),
//!   which buys masked lanes and lane recycling for free.
//! * **Scalar** — [`BatchedScalarDeepCoT`]: the pure-Rust multi-lane
//!   engine stepping all slots through single stacked shared-weight
//!   matmuls over ring-buffer K/V memories, running on the
//!   `nn::kernels` SIMD-friendly suite (packed fused matmul+bias,
//!   two-segment ring attention, memoized RoPE tables — all with a
//!   fixed summation order independent of lane count, which is what
//!   keeps a stream's outputs bitwise-identical across shard layouts
//!   and slot budgets). Used when the XLA shared library is
//!   unavailable (engine backend `auto`/`scalar`), so the whole
//!   coordinator — admission, batching, masking, churn — serves real
//!   traffic with no device runtime at all.
//!
//! Third-party backends implement [`StreamBackend`] and plug in via
//! [`SlotStepper::from_backend`] — the shard loop and the cluster never
//! name a concrete backend.
//!
//! Lane semantics are identical across backends:
//!   * masked lanes — a stream that skipped this tick keeps its previous
//!     K/V memory (the rolled output / ring push for that lane is
//!     discarded or skipped);
//!   * lane recycling — releasing a slot zeroes its lane, giving the
//!     next stream a cold memory.
//!
//! **Stream state is a value.** A lane's entire serving identity — its
//! K/V ring contents, ring write heads, and position clock — exports
//! into a portable [`StreamState`] snapshot and imports into any free
//! lane of any backend instance with the same geometry, producing
//! bitwise-identical subsequent ticks. On the scalar backend this is a
//! memcpy of the ring storage; it is what live stream migration between
//! shards is built on. The PJRT backend reports
//! [`EngineError::Unsupported`] until the AOT step variants accept
//! per-lane position inputs (see ROADMAP).
//!
//! Positions: the scalar backend keeps a per-lane position clock — a
//! stream's clock starts at 0 when its slot is bound and advances only
//! on the ticks it participates in, so its RoPE phases depend on
//! nothing but its own history (the property the cluster's cross-shard
//! bitwise-equivalence and migration tests pin down). The PJRT backend
//! still runs on the shared engine clock (RoPE's relative-offset
//! property makes attention invariant to the common shift).
//!
//! Capacity: the scalar backend's lane count is a constructor argument
//! (`new_scalar_with_capacity`), letting a shard size its slot budget
//! independently of the manifest's compiled batch; PJRT capacity is
//! baked into the executable's batch dimension.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::batcher::TickPlan;
use crate::coordinator::session::EngineError;
use crate::coordinator::slots::StreamId;
use crate::manifest::{ModelConfig, VariantEntry};
use crate::nn::batched::BatchedScalarDeepCoT;
use crate::nn::params::ModelParams;
use crate::nn::simd::{DispatchChoice, KernelOps};
use crate::nn::tensor::Mat;
use crate::runtime::{HostTensor, LoadedVariant};

/// Per-lane tick results.
pub struct LaneOut {
    /// Batch lane the stream ticked on.
    pub slot: usize,
    /// The stream that owns the lane this tick.
    pub stream: StreamId,
    /// Classifier logits for the lane's newest token.
    pub logits: Vec<f32>,
    /// Final-layer activations for the lane's new tokens.
    pub out: Vec<f32>,
}

/// A portable snapshot of one stream's serving state — the stream's
/// whole identity as a value. Exporting a lane and importing the
/// snapshot into any same-geometry lane (same or different backend
/// instance, same or different shard) resumes the stream with
/// bitwise-identical outputs.
///
/// Buffers are reused across exports: `export_lane` clears and refills
/// them, so a caller that keeps one `StreamState` scratch performs no
/// steady-state heap allocation after the first export.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamState {
    /// Raw K/V ring storage: all K rings in `(layer, head)` order, then
    /// all V rings, each `mem_len * d_head` f32s of physical (not
    /// logically rotated) storage.
    pub kv_rings: Vec<f32>,
    /// Per-ring physical write-head index, aligned with `kv_rings`.
    pub write_heads: Vec<usize>,
    /// The stream's position clock (RoPE phase of its next token).
    pub pos: i32,
}

/// A pluggable execution backend: steps all lanes of one batched
/// continual model and exposes per-lane state as portable snapshots.
/// Implementations live on the shard worker thread that created them
/// (no `Send` bound — the PJRT backend holds `Rc` runtime handles).
pub trait StreamBackend {
    /// Short backend name for logs ("scalar", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The served model geometry.
    fn config(&self) -> &ModelConfig;

    /// Number of batch lanes (the shard's slot budget).
    fn capacity(&self) -> usize;

    /// Zero a lane's state (stream released / new stream admitted).
    fn clear_lane(&mut self, lane: usize);

    /// Run one batched tick for the planned lanes.
    fn tick_lanes(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>, EngineError>;

    /// Check that a snapshot matches this backend's geometry without
    /// touching any lane (run before admission on the import path, so
    /// a bad snapshot cannot strand a half-admitted stream).
    fn validate_state(&self, state: &StreamState) -> Result<(), EngineError>;

    /// Snapshot a lane's full stream state into `into` (buffers are
    /// cleared and refilled — reuse one scratch `StreamState` to keep
    /// the path allocation-free).
    fn export_lane(&self, lane: usize, into: &mut StreamState) -> Result<(), EngineError>;

    /// Restore a lane from a snapshot; the lane then ticks
    /// bitwise-identically to the exported stream.
    fn import_lane(&mut self, lane: usize, state: &StreamState) -> Result<(), EngineError>;

    /// The kernel path this backend's tick runs on ("scalar" / "avx2"
    /// / "neon"), for metrics and logs. Backends without a dispatched
    /// kernel layer report "n/a".
    fn kernel_dispatch(&self) -> &'static str {
        "n/a"
    }
}

/// Backend-dispatching batched stepper: a thin owner of a boxed
/// [`StreamBackend`] with constructors for the two built-in backends.
pub struct SlotStepper {
    backend: Box<dyn StreamBackend>,
}

impl SlotStepper {
    /// Batched PJRT backend over a loaded step variant.
    pub fn new(variant: Rc<LoadedVariant>) -> Result<Self, EngineError> {
        let b = PjrtSlotStepper::new(variant).map_err(EngineError::internal)?;
        Ok(Self { backend: Box::new(b) })
    }

    /// Pure-Rust scalar backend from a manifest entry + host weights
    /// (no PJRT client, no XLA shared library), at the variant's
    /// compiled batch size.
    pub fn new_scalar(entry: &VariantEntry, params: ModelParams) -> Result<Self, EngineError> {
        Self::new_scalar_with_capacity(entry, params, entry.config.batch)
    }

    /// Scalar backend with an explicit slot capacity (shard-sized lane
    /// count, independent of the manifest's compiled batch), kernel
    /// path resolved under `DispatchChoice::Auto`.
    pub fn new_scalar_with_capacity(
        entry: &VariantEntry,
        params: ModelParams,
        capacity: usize,
    ) -> Result<Self, EngineError> {
        Self::new_scalar_with_dispatch(entry, params, capacity, DispatchChoice::Auto)
    }

    /// Scalar backend with an explicit slot capacity and kernel
    /// dispatch choice (`EngineConfig::kernel_dispatch`). Resolution
    /// happens here, once — a forced-but-unsupported path is rejected
    /// before any lane state exists.
    pub fn new_scalar_with_dispatch(
        entry: &VariantEntry,
        params: ModelParams,
        capacity: usize,
        dispatch: DispatchChoice,
    ) -> Result<Self, EngineError> {
        let ops = KernelOps::resolve(dispatch)
            .map_err(|e| EngineError::InvalidRequest(e.to_string()))?;
        let b =
            ScalarSlotStepper::new(entry, params, capacity, ops).map_err(EngineError::internal)?;
        Ok(Self { backend: Box::new(b) })
    }

    /// Wrap a custom [`StreamBackend`] implementation — the extension
    /// point for third-party backends; the coordinator needs nothing
    /// else from them.
    pub fn from_backend(backend: Box<dyn StreamBackend>) -> Self {
        Self { backend }
    }

    /// Short backend name for logs.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's resolved kernel path ("n/a" for backends without
    /// a dispatched kernel layer).
    pub fn kernel_dispatch(&self) -> &'static str {
        self.backend.kernel_dispatch()
    }

    /// The served model geometry.
    pub fn config(&self) -> &ModelConfig {
        self.backend.config()
    }

    /// Number of batch lanes (the shard's slot budget).
    pub fn capacity(&self) -> usize {
        self.backend.capacity()
    }

    /// Zero a lane's state (stream released / new stream admitted).
    pub fn clear_lane(&mut self, lane: usize) {
        self.backend.clear_lane(lane);
    }

    /// Run one batched tick for the planned lanes.
    pub fn tick_lanes(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>, EngineError> {
        self.backend.tick_lanes(plan)
    }

    /// Check a snapshot against this backend's geometry.
    pub fn validate_state(&self, state: &StreamState) -> Result<(), EngineError> {
        self.backend.validate_state(state)
    }

    /// Snapshot a lane's stream state into `into` (buffer-reusing).
    pub fn export_lane(&self, lane: usize, into: &mut StreamState) -> Result<(), EngineError> {
        self.backend.export_lane(lane, into)
    }

    /// Restore a lane from a snapshot.
    pub fn import_lane(&mut self, lane: usize, state: &StreamState) -> Result<(), EngineError> {
        self.backend.import_lane(lane, state)
    }
}

// ---------------------------------------------------------------------
// Scalar backend

struct ScalarSlotStepper {
    cfg: ModelConfig,
    model: BatchedScalarDeepCoT,
    /// Lane count (shard slot budget; independent of `cfg.batch`).
    capacity: usize,
    /// Reused per-tick staging (stacked lane tokens + live mask).
    tokens: Mat,
    live: Vec<bool>,
    /// Per-lane stream position clocks: rewound when a slot is cleared,
    /// advanced by m_tokens for every tick the lane participates in,
    /// overwritten by an imported snapshot's clock.
    lane_pos: Vec<i32>,
}

impl ScalarSlotStepper {
    fn new(
        entry: &VariantEntry,
        params: ModelParams,
        capacity: usize,
        ops: &'static KernelOps,
    ) -> Result<Self> {
        if entry.family != "deepcot" {
            bail!(
                "scalar slot backend implements the deepcot family only (got {})",
                entry.family
            );
        }
        // same contract as the PJRT backend: only continual-step
        // variants have per-lane state to slot
        if !entry.is_step() {
            bail!("scalar slot backend needs a continual step variant (entry has no state wiring)");
        }
        let cfg = entry.config.clone();
        anyhow::ensure!(capacity >= 1, "scalar slot backend needs capacity >= 1");
        let model = BatchedScalarDeepCoT::with_lanes_ops(cfg.clone(), params, capacity, ops);
        let tokens = Mat::zeros(capacity * cfg.m_tokens, cfg.d_in);
        Ok(Self {
            cfg,
            model,
            capacity,
            tokens,
            live: vec![false; capacity],
            lane_pos: vec![0; capacity],
        })
    }

    fn tick_impl(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>> {
        let (b, m, d_in) = (self.capacity, self.cfg.m_tokens, self.cfg.d_in);
        let lane_elems = m * d_in;
        self.live.iter_mut().for_each(|v| *v = false);
        self.tokens.fill(0.0);
        for (slot, _, toks, _) in &plan.lanes {
            anyhow::ensure!(*slot < b, "slot {slot} out of range (B={b})");
            anyhow::ensure!(
                toks.len() == lane_elems,
                "lane tokens {} != m*d_in {}",
                toks.len(),
                lane_elems
            );
            self.tokens.data[slot * lane_elems..(slot + 1) * lane_elems].copy_from_slice(toks);
            self.live[*slot] = true;
        }
        let step = self.model.tick_lanes(&self.tokens, &self.live, &self.lane_pos)?;
        let mut res = Vec::with_capacity(plan.lanes.len());
        for (slot, stream, _, _) in &plan.lanes {
            res.push(LaneOut {
                slot: *slot,
                stream: *stream,
                logits: step.logits.row(*slot).to_vec(),
                out: step.out.rows_view(slot * m, m).as_slice().to_vec(),
            });
        }
        // advance the clocks of exactly the lanes that ticked
        for (slot, _, _, _) in &plan.lanes {
            self.lane_pos[*slot] += m as i32;
        }
        Ok(res)
    }
}

impl StreamBackend for ScalarSlotStepper {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear_lane(&mut self, lane: usize) {
        self.model.reset_lane(lane);
        self.lane_pos[lane] = 0;
    }

    fn tick_lanes(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>, EngineError> {
        self.tick_impl(plan).map_err(EngineError::internal)
    }

    fn validate_state(&self, state: &StreamState) -> Result<(), EngineError> {
        if state.write_heads.len() != self.model.rings_per_lane()
            || state.kv_rings.len() != self.model.floats_per_lane()
        {
            return Err(EngineError::InvalidRequest(format!(
                "snapshot geometry mismatch: {} rings / {} floats, backend expects {} / {}",
                state.write_heads.len(),
                state.kv_rings.len(),
                self.model.rings_per_lane(),
                self.model.floats_per_lane()
            )));
        }
        Ok(())
    }

    fn export_lane(&self, lane: usize, into: &mut StreamState) -> Result<(), EngineError> {
        if lane >= self.capacity {
            return Err(EngineError::InvalidRequest(format!(
                "lane {lane} out of range (capacity {})",
                self.capacity
            )));
        }
        self.model.export_lane(lane, &mut into.kv_rings, &mut into.write_heads);
        into.pos = self.lane_pos[lane];
        Ok(())
    }

    fn import_lane(&mut self, lane: usize, state: &StreamState) -> Result<(), EngineError> {
        if lane >= self.capacity {
            return Err(EngineError::InvalidRequest(format!(
                "lane {lane} out of range (capacity {})",
                self.capacity
            )));
        }
        self.model
            .import_lane(lane, &state.kv_rings, &state.write_heads)
            .map_err(|e| EngineError::InvalidRequest(e.to_string()))?;
        self.lane_pos[lane] = state.pos;
        Ok(())
    }

    fn kernel_dispatch(&self) -> &'static str {
        self.model.dispatch().as_str()
    }
}

// ---------------------------------------------------------------------
// PJRT backend

struct PjrtSlotStepper {
    variant: Rc<LoadedVariant>,
    /// host mirror of each state input (index-aligned with wiring order)
    state: Vec<HostTensor>,
    wiring: Vec<(usize, usize)>,
    /// batch axis of each state tensor (family-dependent)
    batch_axis: usize,
    pos: i32,
}

impl PjrtSlotStepper {
    fn new(variant: Rc<LoadedVariant>) -> Result<Self> {
        if !variant.entry.is_step() {
            bail!("{} is not a step variant", variant.name);
        }
        let wiring = variant.entry.state_wiring();
        let batch_axis = match variant.entry.family.as_str() {
            "deepcot" | "xl" => 1, // (L, B, H, M, dh)
            _ => 0,                // (B, H, n-1, dh)
        };
        let state = wiring
            .iter()
            .map(|&(_, inp)| HostTensor::zeros(variant.entry.inputs[inp].shape.clone()))
            .collect();
        Ok(Self { variant, state, wiring, batch_axis, pos: 0 })
    }

    /// Element range(s) of one lane within a state tensor of `shape`.
    fn lane_ranges(&self, shape: &[usize], lane: usize) -> Vec<std::ops::Range<usize>> {
        let b = shape[self.batch_axis];
        debug_assert!(lane < b);
        let inner: usize = shape[self.batch_axis + 1..].iter().product();
        let outer: usize = shape[..self.batch_axis].iter().product();
        (0..outer)
            .map(|o| {
                let start = (o * b + lane) * inner;
                start..start + inner
            })
            .collect()
    }

    fn tick_impl(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>> {
        let variant = self.variant.clone(); // Rc bump
        let entry = &variant.entry;
        let cfg = &entry.config;
        let (b, m, d_in) = (cfg.batch, cfg.m_tokens, cfg.d_in);
        let lane_elems = m * d_in;
        let mut tokens = HostTensor::zeros(vec![b, m, d_in]);
        let mut live = vec![false; b];
        for (slot, _, toks, _) in &plan.lanes {
            anyhow::ensure!(*slot < b, "slot {slot} out of range (B={b})");
            anyhow::ensure!(
                toks.len() == lane_elems,
                "lane tokens {} != m*d_in {}",
                toks.len(),
                lane_elems
            );
            tokens.data[slot * lane_elems..(slot + 1) * lane_elems].copy_from_slice(toks);
            live[*slot] = true;
        }
        // upload inputs in manifest order — by reference, no clones
        // (§Perf iteration 3: the old clone-per-state-tensor path copied
        // the full batched K/V memory twice per tick)
        let mut bufs = Vec::with_capacity(entry.inputs.len());
        let mut state_iter = self.state.iter();
        // non-token f32 inputs are exactly the state tensors, in wiring
        // order (kmem then vmem ...) — the manifest contract
        for spec in &entry.inputs {
            bufs.push(match spec.dtype.as_str() {
                "i32" => variant.upload_pos(self.pos)?,
                _ => {
                    if spec.name == "tokens" {
                        variant.upload_f32_ref(&tokens)?
                    } else {
                        let st = match state_iter.next() {
                            Some(st) => st,
                            None => bail!("manifest state inputs exceed the wiring order"),
                        };
                        variant.upload_f32_ref(st)?
                    }
                }
            });
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let parts = variant.execute_raw_literals(&refs)?;
        drop(refs);
        drop(bufs);
        // state feedback with masked-lane restore: copy the literal into
        // the existing host mirror, then restore dead lanes from a lane
        // backup taken beforehand (small: only dead lanes are saved)
        for (si, &(out_idx, _)) in self.wiring.iter().enumerate() {
            // save dead-lane slices before overwriting
            let mut saved: Vec<(std::ops::Range<usize>, Vec<f32>)> = Vec::new();
            let shape = self.state[si].shape.clone();
            for lane in 0..b {
                if !live[lane] {
                    for r in self.lane_ranges(&shape, lane) {
                        saved.push((r.clone(), self.state[si].data[r].to_vec()));
                    }
                }
            }
            parts[out_idx]
                .copy_raw_to::<f32>(&mut self.state[si].data)
                .map_err(|e| anyhow::anyhow!("state fetch: {e}"))?;
            for (r, vals) in saved {
                self.state[si].data[r].copy_from_slice(&vals);
            }
        }
        self.pos += m as i32;
        // scatter outputs back to lanes
        let logits = variant.literal_to_host(0, &parts[0])?;
        let out = variant.literal_to_host(1, &parts[1])?;
        let logits = &logits;
        let out = &out;
        let c = match logits.shape.last() {
            Some(&c) => c,
            None => bail!("logits output has no shape"),
        };
        let od: usize = out.shape[1..].iter().product();
        let mut res = Vec::with_capacity(plan.lanes.len());
        for (slot, stream, _, _) in &plan.lanes {
            res.push(LaneOut {
                slot: *slot,
                stream: *stream,
                logits: logits.data[slot * c..(slot + 1) * c].to_vec(),
                out: out.data[slot * od..(slot + 1) * od].to_vec(),
            });
        }
        Ok(res)
    }
}

/// Snapshot export/import needs per-lane position clocks, which the
/// PJRT AOT step variants don't take yet (shared scalar `pos` input) —
/// a lane moved between engines with different shared clocks would
/// replay wrong RoPE phases. Surfaced as a typed error so migration
/// aborts cleanly with the stream intact on its source shard.
const PJRT_SNAPSHOT_UNSUPPORTED: &str =
    "PJRT backend cannot snapshot streams until AOT step variants take per-lane positions";

impl StreamBackend for PjrtSlotStepper {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self) -> &ModelConfig {
        &self.variant.entry.config
    }

    fn capacity(&self) -> usize {
        self.variant.entry.config.batch
    }

    fn clear_lane(&mut self, lane: usize) {
        for si in 0..self.state.len() {
            let shape = self.state[si].shape.clone();
            for r in self.lane_ranges(&shape, lane) {
                self.state[si].data[r].iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }

    fn tick_lanes(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>, EngineError> {
        self.tick_impl(plan).map_err(EngineError::internal)
    }

    fn validate_state(&self, _state: &StreamState) -> Result<(), EngineError> {
        Err(EngineError::Unsupported(PJRT_SNAPSHOT_UNSUPPORTED.to_string()))
    }

    fn export_lane(&self, _lane: usize, _into: &mut StreamState) -> Result<(), EngineError> {
        Err(EngineError::Unsupported(PJRT_SNAPSHOT_UNSUPPORTED.to_string()))
    }

    fn import_lane(&mut self, _lane: usize, _state: &StreamState) -> Result<(), EngineError> {
        Err(EngineError::Unsupported(PJRT_SNAPSHOT_UNSUPPORTED.to_string()))
    }
}
