//! Batched continual stepper with per-lane stream state, over either of
//! two backends behind one [`SlotStepper`] front:
//!
//! * **PJRT** — the batched AOT executable; state is mirrored host-side
//!   (the CPU PJRT feedback path round-trips through the host anyway),
//!   which buys masked lanes and lane recycling for free.
//! * **Scalar** — [`BatchedScalarDeepCoT`]: the pure-Rust multi-lane
//!   engine stepping all slots through single stacked shared-weight
//!   matmuls over ring-buffer K/V memories. Used when the XLA shared
//!   library is unavailable (engine backend `auto`/`scalar`), so the
//!   whole coordinator — admission, batching, masking, churn — serves
//!   real traffic with no device runtime at all.
//!
//! Lane semantics are identical across backends:
//!   * masked lanes — a stream that skipped this tick keeps its previous
//!     K/V memory (the rolled output / ring push for that lane is
//!     discarded or skipped);
//!   * lane recycling — releasing a slot zeroes its lane, giving the
//!     next stream a cold memory.
//!
//! Positions: the scalar backend keeps a per-lane position clock — a
//! stream's clock starts at 0 when its slot is bound and advances only
//! on the ticks it participates in, so its RoPE phases depend on
//! nothing but its own history (the property the cluster's cross-shard
//! bitwise-equivalence tests pin down). The PJRT backend still runs on
//! the shared engine clock (RoPE's relative-offset property makes
//! attention invariant to the common shift) until the AOT step variants
//! accept a vector `pos` input — see ROADMAP.
//!
//! Capacity: the scalar backend's lane count is a constructor argument
//! (`new_scalar_with_capacity`), letting a shard size its slot budget
//! independently of the manifest's compiled batch; PJRT capacity is
//! baked into the executable's batch dimension.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::batcher::TickPlan;
use crate::coordinator::slots::StreamId;
use crate::manifest::{ModelConfig, VariantEntry};
use crate::nn::batched::BatchedScalarDeepCoT;
use crate::nn::params::ModelParams;
use crate::nn::tensor::Mat;
use crate::runtime::{HostTensor, LoadedVariant};

/// Per-lane tick results.
pub struct LaneOut {
    pub slot: usize,
    pub stream: StreamId,
    pub logits: Vec<f32>,
    pub out: Vec<f32>,
}

/// Backend-dispatching batched stepper.
pub struct SlotStepper {
    backend: Backend,
}

enum Backend {
    Pjrt(PjrtSlotStepper),
    Scalar(ScalarSlotStepper),
}

impl SlotStepper {
    /// Batched PJRT backend over a loaded step variant.
    pub fn new(variant: Rc<LoadedVariant>) -> Result<Self> {
        Ok(Self { backend: Backend::Pjrt(PjrtSlotStepper::new(variant)?) })
    }

    /// Pure-Rust scalar backend from a manifest entry + host weights
    /// (no PJRT client, no XLA shared library), at the variant's
    /// compiled batch size.
    pub fn new_scalar(entry: &VariantEntry, params: ModelParams) -> Result<Self> {
        Self::new_scalar_with_capacity(entry, params, entry.config.batch)
    }

    /// Scalar backend with an explicit slot capacity (shard-sized lane
    /// count, independent of the manifest's compiled batch).
    pub fn new_scalar_with_capacity(
        entry: &VariantEntry,
        params: ModelParams,
        capacity: usize,
    ) -> Result<Self> {
        Ok(Self { backend: Backend::Scalar(ScalarSlotStepper::new(entry, params, capacity)?) })
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Pjrt(_) => "pjrt",
            Backend::Scalar(_) => "scalar",
        }
    }

    pub fn config(&self) -> &ModelConfig {
        match &self.backend {
            Backend::Pjrt(s) => &s.variant.entry.config,
            Backend::Scalar(s) => &s.cfg,
        }
    }

    pub fn capacity(&self) -> usize {
        match &self.backend {
            Backend::Pjrt(s) => s.variant.entry.config.batch,
            Backend::Scalar(s) => s.capacity,
        }
    }

    /// Zero a lane's state (stream released / new stream admitted).
    pub fn clear_lane(&mut self, lane: usize) {
        match &mut self.backend {
            Backend::Pjrt(s) => s.clear_lane(lane),
            Backend::Scalar(s) => s.clear_lane(lane),
        }
    }

    /// Run one batched tick for the planned lanes.
    pub fn tick(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>> {
        match &mut self.backend {
            Backend::Pjrt(s) => s.tick(plan),
            Backend::Scalar(s) => s.tick(plan),
        }
    }
}

// ---------------------------------------------------------------------
// Scalar backend

struct ScalarSlotStepper {
    cfg: ModelConfig,
    model: BatchedScalarDeepCoT,
    /// Lane count (shard slot budget; independent of `cfg.batch`).
    capacity: usize,
    /// Reused per-tick staging (stacked lane tokens + live mask).
    tokens: Mat,
    live: Vec<bool>,
    /// Per-lane stream position clocks: rewound when a slot is cleared,
    /// advanced by m_tokens for every tick the lane participates in.
    lane_pos: Vec<i32>,
}

impl ScalarSlotStepper {
    fn new(entry: &VariantEntry, params: ModelParams, capacity: usize) -> Result<Self> {
        if entry.family != "deepcot" {
            bail!(
                "scalar slot backend implements the deepcot family only (got {})",
                entry.family
            );
        }
        // same contract as the PJRT backend: only continual-step
        // variants have per-lane state to slot
        if !entry.is_step() {
            bail!("scalar slot backend needs a continual step variant (entry has no state wiring)");
        }
        let cfg = entry.config.clone();
        anyhow::ensure!(capacity >= 1, "scalar slot backend needs capacity >= 1");
        let model = BatchedScalarDeepCoT::with_lanes(cfg.clone(), params, capacity);
        let tokens = Mat::zeros(capacity * cfg.m_tokens, cfg.d_in);
        Ok(Self {
            cfg,
            model,
            capacity,
            tokens,
            live: vec![false; capacity],
            lane_pos: vec![0; capacity],
        })
    }

    fn clear_lane(&mut self, lane: usize) {
        self.model.reset_lane(lane);
        self.lane_pos[lane] = 0;
    }

    fn tick(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>> {
        let (b, m, d_in) = (self.capacity, self.cfg.m_tokens, self.cfg.d_in);
        let lane_elems = m * d_in;
        self.live.iter_mut().for_each(|v| *v = false);
        self.tokens.fill(0.0);
        for (slot, _, toks, _) in &plan.lanes {
            anyhow::ensure!(*slot < b, "slot {slot} out of range (B={b})");
            anyhow::ensure!(
                toks.len() == lane_elems,
                "lane tokens {} != m*d_in {}",
                toks.len(),
                lane_elems
            );
            self.tokens.data[slot * lane_elems..(slot + 1) * lane_elems].copy_from_slice(toks);
            self.live[*slot] = true;
        }
        let step = self.model.tick_lanes(&self.tokens, &self.live, &self.lane_pos)?;
        let mut res = Vec::with_capacity(plan.lanes.len());
        for (slot, stream, _, _) in &plan.lanes {
            res.push(LaneOut {
                slot: *slot,
                stream: *stream,
                logits: step.logits.row(*slot).to_vec(),
                out: step.out.rows_view(slot * m, m).as_slice().to_vec(),
            });
        }
        // advance the clocks of exactly the lanes that ticked
        for (slot, _, _, _) in &plan.lanes {
            self.lane_pos[*slot] += m as i32;
        }
        Ok(res)
    }
}

// ---------------------------------------------------------------------
// PJRT backend

struct PjrtSlotStepper {
    variant: Rc<LoadedVariant>,
    /// host mirror of each state input (index-aligned with wiring order)
    state: Vec<HostTensor>,
    wiring: Vec<(usize, usize)>,
    /// batch axis of each state tensor (family-dependent)
    batch_axis: usize,
    pos: i32,
}

impl PjrtSlotStepper {
    fn new(variant: Rc<LoadedVariant>) -> Result<Self> {
        if !variant.entry.is_step() {
            bail!("{} is not a step variant", variant.name);
        }
        let wiring = variant.entry.state_wiring();
        let batch_axis = match variant.entry.family.as_str() {
            "deepcot" | "xl" => 1, // (L, B, H, M, dh)
            _ => 0,                // (B, H, n-1, dh)
        };
        let state = wiring
            .iter()
            .map(|&(_, inp)| HostTensor::zeros(variant.entry.inputs[inp].shape.clone()))
            .collect();
        Ok(Self { variant, state, wiring, batch_axis, pos: 0 })
    }

    /// Element range(s) of one lane within a state tensor of `shape`.
    fn lane_ranges(&self, shape: &[usize], lane: usize) -> Vec<std::ops::Range<usize>> {
        let b = shape[self.batch_axis];
        debug_assert!(lane < b);
        let inner: usize = shape[self.batch_axis + 1..].iter().product();
        let outer: usize = shape[..self.batch_axis].iter().product();
        (0..outer)
            .map(|o| {
                let start = (o * b + lane) * inner;
                start..start + inner
            })
            .collect()
    }

    fn clear_lane(&mut self, lane: usize) {
        for si in 0..self.state.len() {
            let shape = self.state[si].shape.clone();
            for r in self.lane_ranges(&shape, lane) {
                self.state[si].data[r].iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }

    fn tick(&mut self, plan: &TickPlan) -> Result<Vec<LaneOut>> {
        let variant = self.variant.clone(); // Rc bump
        let entry = &variant.entry;
        let cfg = &entry.config;
        let (b, m, d_in) = (cfg.batch, cfg.m_tokens, cfg.d_in);
        let lane_elems = m * d_in;
        let mut tokens = HostTensor::zeros(vec![b, m, d_in]);
        let mut live = vec![false; b];
        for (slot, _, toks, _) in &plan.lanes {
            anyhow::ensure!(*slot < b, "slot {slot} out of range (B={b})");
            anyhow::ensure!(
                toks.len() == lane_elems,
                "lane tokens {} != m*d_in {}",
                toks.len(),
                lane_elems
            );
            tokens.data[slot * lane_elems..(slot + 1) * lane_elems].copy_from_slice(toks);
            live[*slot] = true;
        }
        // upload inputs in manifest order — by reference, no clones
        // (§Perf iteration 3: the old clone-per-state-tensor path copied
        // the full batched K/V memory twice per tick)
        let mut bufs = Vec::with_capacity(entry.inputs.len());
        let mut state_iter = self.state.iter();
        // non-token f32 inputs are exactly the state tensors, in wiring
        // order (kmem then vmem ...) — the manifest contract
        for spec in &entry.inputs {
            bufs.push(match spec.dtype.as_str() {
                "i32" => variant.upload_pos(self.pos)?,
                _ => {
                    if spec.name == "tokens" {
                        variant.upload_f32_ref(&tokens)?
                    } else {
                        let st = state_iter.next().expect("state tensor order");
                        variant.upload_f32_ref(st)?
                    }
                }
            });
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let parts = variant.execute_raw_literals(&refs)?;
        drop(refs);
        drop(bufs);
        // state feedback with masked-lane restore: copy the literal into
        // the existing host mirror, then restore dead lanes from a lane
        // backup taken beforehand (small: only dead lanes are saved)
        for (si, &(out_idx, _)) in self.wiring.iter().enumerate() {
            // save dead-lane slices before overwriting
            let mut saved: Vec<(std::ops::Range<usize>, Vec<f32>)> = Vec::new();
            let shape = self.state[si].shape.clone();
            for lane in 0..b {
                if !live[lane] {
                    for r in self.lane_ranges(&shape, lane) {
                        saved.push((r.clone(), self.state[si].data[r].to_vec()));
                    }
                }
            }
            parts[out_idx]
                .copy_raw_to::<f32>(&mut self.state[si].data)
                .map_err(|e| anyhow::anyhow!("state fetch: {e}"))?;
            for (r, vals) in saved {
                self.state[si].data[r].copy_from_slice(&vals);
            }
        }
        self.pos += m as i32;
        // scatter outputs back to lanes
        let logits = variant.literal_to_host(0, &parts[0])?;
        let out = variant.literal_to_host(1, &parts[1])?;
        let logits = &logits;
        let out = &out;
        let c = *logits.shape.last().unwrap();
        let od: usize = out.shape[1..].iter().product();
        let mut res = Vec::with_capacity(plan.lanes.len());
        for (slot, stream, _, _) in &plan.lanes {
            res.push(LaneOut {
                slot: *slot,
                stream: *stream,
                logits: logits.data[slot * c..(slot + 1) * c].to_vec(),
                out: out.data[slot * od..(slot + 1) * od].to_vec(),
            });
        }
        Ok(res)
    }
}
