//! Hibernation policy: the cluster-wide table of spilled streams and
//! the glue between coordinator types and the `store` subsystem.
//!
//! A hibernated stream has no backend lane anywhere — its whole
//! identity lives as a [`StreamRecord`] blob in a [`StateStore`], plus
//! one row in this pool's table remembering whether a live client still
//! holds the stream's output channel. Spilling happens on the shard
//! worker (the victim's lane is exported right before the slot is
//! reused); restoring happens at the front door (a PUSH or resume to a
//! hibernated id imports the record into a free lane, possibly after a
//! colder stream is spilled to make room). The pool serializes store
//! access behind one mutex; callers must never hold that lock across a
//! shard round-trip, so every method here does its store work and
//! returns.
//!
//! The blob is *kept* in the store after a restore: it doubles as the
//! crash-recovery checkpoint (refreshed by the next spill or periodic
//! snapshot) and is only deleted when the stream is explicitly closed.

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::coordinator::batcher::Pending;
use crate::coordinator::shard::{ExportedStream, TickResult};
use crate::coordinator::slot_stepper::StreamState;
use crate::coordinator::slots::StreamId;
use crate::store::codec::StreamRecord;
use crate::store::{StateStore, StoreError};

/// Counters for the hibernation subsystem, snapshotted into
/// `ClusterMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HibernateStats {
    /// Streams spilled out of a lane into the store (lifetime total).
    pub spills: u64,
    /// Streams restored from the store into a lane (lifetime total).
    pub restores: u64,
    /// Streams re-registered as hibernated by recover-on-boot.
    pub recovered: u64,
}

struct PoolInner {
    store: Box<dyn StateStore>,
    /// Hibernated streams → the output channel their client still
    /// holds (`None` for streams recovered from disk after a restart:
    /// those wait for an explicit resume to mint a new channel).
    table: BTreeMap<StreamId, Option<Sender<TickResult>>>,
    stats: HibernateStats,
    /// Reused encode buffer so steady snapshotting stays allocation-lean.
    buf: Vec<u8>,
}

/// Cloneable, thread-safe handle to the hibernation table + store.
#[derive(Clone)]
pub(crate) struct HibernatePool {
    inner: Arc<Mutex<PoolInner>>,
}

impl HibernatePool {
    pub(crate) fn new(store: Box<dyn StateStore>) -> HibernatePool {
        HibernatePool {
            inner: Arc::new(Mutex::new(PoolInner {
                store,
                table: BTreeMap::new(),
                stats: HibernateStats::default(),
                buf: Vec::new(),
            })),
        }
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        // a poisoned pool lock means a panic mid-store-call; the table
        // and store are still structurally valid, so keep serving
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Spill a live stream: persist its record and remember its output
    /// channel. On store failure nothing is recorded and the caller
    /// keeps the stream in its lane.
    pub(crate) fn spill(
        &self,
        rec: &StreamRecord,
        port: Sender<TickResult>,
    ) -> Result<(), StoreError> {
        let mut g = self.lock();
        let PoolInner { store, buf, .. } = &mut *g;
        rec.encode_into(buf);
        store.put(rec.stream, buf)?;
        g.table.insert(StreamId(rec.stream), Some(port));
        g.stats.spills += 1;
        Ok(())
    }

    /// Refresh the durable checkpoint of a stream that stays resident
    /// in its lane (the periodic-snapshot path): store write only, no
    /// table entry.
    pub(crate) fn checkpoint(&self, rec: &StreamRecord) -> Result<(), StoreError> {
        let mut g = self.lock();
        let PoolInner { store, buf, .. } = &mut *g;
        rec.encode_into(buf);
        store.put(rec.stream, buf)
    }

    /// Whether `id` is currently hibernated.
    pub(crate) fn contains(&self, id: StreamId) -> bool {
        self.lock().table.contains_key(&id)
    }

    /// `None` if not hibernated; otherwise whether a live client still
    /// holds the stream's output channel.
    pub(crate) fn has_port(&self, id: StreamId) -> Option<bool> {
        self.lock().table.get(&id).map(|p| p.is_some())
    }

    /// Start restoring `id`: load + decode its record and take its
    /// table row. The caller must either land the stream in a lane and
    /// call [`Self::commit_restore`], or put the row back with
    /// [`Self::abort_restore`]. The blob stays in the store either way
    /// (it is the crash-recovery checkpoint until the stream closes).
    #[allow(clippy::type_complexity)]
    pub(crate) fn begin_restore(
        &self,
        id: StreamId,
    ) -> Result<Option<(StreamRecord, Option<Sender<TickResult>>)>, StoreError> {
        let mut g = self.lock();
        if !g.table.contains_key(&id) {
            return Ok(None);
        }
        let Some(blob) = g.store.get(id.0)? else {
            // table/store diverged (e.g. a store error during spill
            // cleanup): drop the orphan row rather than wedge the id
            g.table.remove(&id);
            return Ok(None);
        };
        let rec = StreamRecord::decode(&blob)?;
        let port = g.table.remove(&id).flatten();
        Ok(Some((rec, port)))
    }

    /// The restore landed in a lane.
    pub(crate) fn commit_restore(&self, _id: StreamId) {
        self.lock().stats.restores += 1;
    }

    /// The restore failed everywhere: put the table row back so the
    /// stream stays resumable.
    pub(crate) fn abort_restore(&self, id: StreamId, port: Option<Sender<TickResult>>) {
        self.lock().table.insert(id, port);
    }

    /// Recover-on-boot: re-register a stream found in the store as
    /// hibernated with no owner (a resume request mints its channel).
    pub(crate) fn register_recovered(&self, id: StreamId) {
        let mut g = self.lock();
        g.table.insert(id, None);
        g.stats.recovered += 1;
    }

    /// Whether the store holds a checkpoint blob for `id` — the
    /// supervisor's re-home test after a shard crash. A read error
    /// counts as "no checkpoint": claiming one we cannot load would
    /// wedge the stream in an unresumable state.
    pub(crate) fn has_checkpoint(&self, id: StreamId) -> bool {
        self.checkpoint_ticks(id).is_some()
    }

    /// The tick ordinal a re-home would resume `id` from: decoded from
    /// its checkpoint blob, `None` when there is no loadable
    /// checkpoint. Read-only (the table row is untouched).
    pub(crate) fn checkpoint_ticks(&self, id: StreamId) -> Option<u64> {
        let blob = self.lock().store.get(id.0).ok().flatten()?;
        StreamRecord::decode(&blob).ok().map(|rec| rec.ticks)
    }

    /// Re-home a crashed shard's stream: register it as hibernated
    /// with no owner, exactly like recover-on-boot but without
    /// counting toward `recovered` (the crash path has its own
    /// counters). The stream's last checkpoint blob becomes its
    /// state; a resume request (or OPEN-resume over the wire) wakes
    /// it on a surviving shard.
    pub(crate) fn register_orphan(&self, id: StreamId) {
        self.lock().table.insert(id, None);
    }

    /// Forget `id` entirely (stream closed): table row and stored blob.
    pub(crate) fn remove(&self, id: StreamId) -> Result<bool, StoreError> {
        let mut g = self.lock();
        let had_row = g.table.remove(&id).is_some();
        let had_blob = g.store.delete(id.0)?;
        Ok(had_row || had_blob)
    }

    /// Stream ids currently hibernated (ascending).
    pub(crate) fn ids(&self) -> Vec<StreamId> {
        self.lock().table.keys().copied().collect()
    }

    /// Stream ids present in the backing store (ascending) — on a fresh
    /// boot over an existing state dir these are the streams to recover.
    pub(crate) fn stored_ids(&self) -> Result<Vec<u64>, StoreError> {
        self.lock().store.list()
    }

    /// Number of currently hibernated streams.
    pub(crate) fn resident(&self) -> usize {
        self.lock().table.len()
    }

    pub(crate) fn stats(&self) -> HibernateStats {
        self.lock().stats
    }

    /// Flush the backing store to durable media.
    pub(crate) fn sync(&self) -> Result<(), StoreError> {
        self.lock().store.sync()
    }
}

/// Snapshot an exported stream as a storable record. `f32`s are moved
/// bit-for-bit; only the batcher timestamps are dropped (they are
/// re-stamped on restore).
pub(crate) fn record_of(id: StreamId, payload: &ExportedStream) -> StreamRecord {
    record_from_parts(id, payload.ticks, &payload.state, &payload.queued)
}

/// [`record_of`] over the pieces a shard holds mid-spill, before any
/// `ExportedStream` exists.
pub(crate) fn record_from_parts(
    id: StreamId,
    ticks: u64,
    state: &StreamState,
    queued: &[Pending],
) -> StreamRecord {
    StreamRecord {
        stream: id.0,
        ticks,
        pos: state.pos,
        write_heads: state.write_heads.clone(),
        kv_rings: state.kv_rings.clone(),
        queued: queued.iter().map(|p| p.tokens.clone()).collect(),
    }
}

/// Rebuild an importable stream from a stored record plus the output
/// channel it should deliver ticks on. Queued tokens are re-stamped
/// `now` (their original enqueue instants died with the spill; queue
/// latency restarts at restore, which is the honest reading).
pub(crate) fn payload_of(
    rec: StreamRecord,
    port: Sender<TickResult>,
    now: Instant,
) -> Box<ExportedStream> {
    let StreamRecord { ticks, pos, write_heads, kv_rings, queued, .. } = rec;
    Box::new(ExportedStream {
        state: StreamState { kv_rings, write_heads, pos },
        port,
        ticks,
        queued: queued
            .into_iter()
            .map(|tokens| Pending { tokens, enqueued: now })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;
    use std::sync::mpsc;

    fn rec(id: u64) -> StreamRecord {
        StreamRecord {
            stream: id,
            ticks: 3,
            pos: 5,
            write_heads: vec![1, 2],
            kv_rings: vec![0.5, -1.5],
            queued: vec![vec![9.0]],
        }
    }

    #[test]
    fn spill_restore_cycle_keeps_blob_until_removed() {
        let pool = HibernatePool::new(Box::new(MemStore::new()));
        let (tx, _rx) = mpsc::channel();
        pool.spill(&rec(7), tx).unwrap();
        assert!(pool.contains(StreamId(7)));
        assert_eq!(pool.has_port(StreamId(7)), Some(true));
        let (got, port) = pool.begin_restore(StreamId(7)).unwrap().unwrap();
        assert_eq!(got, rec(7));
        assert!(port.is_some());
        assert!(!pool.contains(StreamId(7)));
        pool.commit_restore(StreamId(7));
        // blob survives the restore as the crash checkpoint…
        assert_eq!(pool.stored_ids().unwrap(), vec![7]);
        // …until the stream is closed for real
        assert!(pool.remove(StreamId(7)).unwrap());
        assert_eq!(pool.stored_ids().unwrap(), Vec::<u64>::new());
        let s = pool.stats();
        assert_eq!((s.spills, s.restores, s.recovered), (1, 1, 0));
    }

    #[test]
    fn abort_restore_reinstates_the_row() {
        let pool = HibernatePool::new(Box::new(MemStore::new()));
        let (tx, _rx) = mpsc::channel();
        pool.spill(&rec(4), tx).unwrap();
        let (_rec, port) = pool.begin_restore(StreamId(4)).unwrap().unwrap();
        pool.abort_restore(StreamId(4), port);
        assert_eq!(pool.has_port(StreamId(4)), Some(true));
    }

    #[test]
    fn orphan_registration_mirrors_recovery_without_counting() {
        let mut store = MemStore::new();
        store.put(5, &rec(5).encode()).unwrap();
        let pool = HibernatePool::new(Box::new(store));
        assert!(pool.has_checkpoint(StreamId(5)));
        assert!(!pool.has_checkpoint(StreamId(6)));
        pool.register_orphan(StreamId(5));
        assert_eq!(pool.has_port(StreamId(5)), Some(false));
        assert_eq!(pool.stats().recovered, 0, "crash re-home is not boot recovery");
        let (got, port) = pool.begin_restore(StreamId(5)).unwrap().unwrap();
        assert_eq!(got.stream, 5);
        assert!(port.is_none());
    }

    #[test]
    fn recovered_streams_are_portless() {
        let mut store = MemStore::new();
        store.put(11, &rec(11).encode()).unwrap();
        let pool = HibernatePool::new(Box::new(store));
        for id in pool.stored_ids().unwrap() {
            pool.register_recovered(StreamId(id));
        }
        assert_eq!(pool.has_port(StreamId(11)), Some(false));
        assert_eq!(pool.stats().recovered, 1);
        let (got, port) = pool.begin_restore(StreamId(11)).unwrap().unwrap();
        assert_eq!(got.stream, 11);
        assert!(port.is_none());
    }
}
