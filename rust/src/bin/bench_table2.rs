//! Regenerates Table II (audio classification on synthetic GTZAN) — exp T2.
use anyhow::Result;
use deepcot::bench_harness::tables::{run_table2, BenchOpts};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_table2: audio table (paper Table II)")
        .opt("seed", "0", "workload seed")
        .opt("scale", "1.0", "corpus-size multiplier")
        .flag("quick", "reduced corpus + time budget")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    if !args.has("quick") {
        opts.scale = args.get_f64("scale")?;
    }
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    run_table2(&rt, &opts)?;
    Ok(())
}
