//! Regenerates Fig. 1 + supp. Figs. 2-3 (latency/throughput vs window).
use anyhow::Result;
use deepcot::bench_harness::tables::{run_fig1, BenchOpts};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_fig1: runtime sweep (paper Fig. 1, supp. Figs. 2-3)")
        .opt("seed", "0", "workload seed")
        .opt("windows", "16,32,64,128,256,512", "window sizes to sweep")
        .flag("quick", "reduced time budget")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    let windows: Vec<usize> =
        args.get("windows").split(',').filter_map(|s| s.trim().parse().ok()).collect();
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    run_fig1(&rt, &opts, &windows)?;
    Ok(())
}
