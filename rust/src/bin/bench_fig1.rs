//! Regenerates Fig. 1 + supp. Figs. 2-3 (latency/throughput vs window).
//!
//! Two sweeps: the scalar CPU engines (always available — synthetic
//! weights, no PJRT), then the PJRT variants when the XLA runtime and
//! `make artifacts` output are present.
use anyhow::Result;
use deepcot::bench_harness::tables::{run_fig1, run_fig1_scalar, BenchOpts};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_fig1: runtime sweep (paper Fig. 1, supp. Figs. 2-3)")
        .opt("seed", "0", "workload seed")
        .opt("windows", "16,32,64,128,256,512", "window sizes to sweep")
        .opt("depth", "4", "encoder depth for the scalar-engine sweep")
        .flag("quick", "reduced time budget")
        .flag("no-scalar", "skip the scalar-engine sweep")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    let windows: Vec<usize> =
        args.get("windows").split(',').filter_map(|s| s.trim().parse().ok()).collect();
    if !args.has("no-scalar") {
        run_fig1_scalar(&opts, &windows, args.get_usize("depth")?)?;
    }
    match Runtime::new(&deepcot::artifacts_dir()) {
        Ok(rt) => {
            run_fig1(&rt, &opts, &windows)?;
        }
        Err(e) => {
            eprintln!("skipping PJRT sweep: {e}");
        }
    }
    Ok(())
}
