//! `deepcot_serve` — the TCP serving front door as a binary: spawn the
//! shard cluster and expose it over the `net::proto` wire protocol.
//!
//! Serve real artifacts (default) or a hermetic synthetic model:
//!
//!     cargo run --release --bin deepcot_serve -- --listen 127.0.0.1:7433
//!     cargo run --release --bin deepcot_serve -- --synthetic --shards 2
//!
//! All engine options (`--variant`, `--backend`, `--shards`,
//! `--placement`, …) come from `EngineConfig::cli`, as do the front
//! door's executor knobs: `--net-workers` (decode/engine worker pool;
//! the server runs O(workers) threads however many connections are
//! open), `--net-max-conns`, `--net-max-streams` (per-connection open
//! quota), and `--net-auth-token` (shared-secret OPEN auth). `--listen
//! 127.0.0.1:0` picks an ephemeral port (printed on startup). The
//! server runs until a client sends a SHUTDOWN frame, then drains:
//! every live stream gets a terminal typed error, the engine shuts
//! down cleanly, and the process exits 0.
//!
//! `--smoke N` is the CI loopback self-test: after startup an
//! in-process client connects over TCP, opens a stream, pushes N
//! tokens (checking every tick reply), prints the server's metrics
//! report, scrapes the HTTP metrics endpoint when one is up, and
//! requests a clean shutdown.
//!
//! `--metrics-listen ADDR` binds the HTTP observability endpoint
//! (`/metrics` Prometheus text, `/metrics.json`, `/journal`); on
//! shutdown any undrained journal events are dumped to stdout as
//! one-line JSON.
//!
//! Session persistence (see `store::disk`): with `--state-dir DIR` the
//! engine journals every hibernated stream to `DIR/streams.log`, takes
//! a full-cluster snapshot every `--snapshot-every-ms` (and a final one
//! on clean shutdown), and recovers every registered stream as
//! hibernated on the next boot. The kill-and-recover CI smoke drives
//! exactly this: `--smoke N --smoke-hold` pushes traffic and then keeps
//! serving (no close, no shutdown) so a SIGKILL lands on live state;
//! the restarted process runs `--resume-smoke` to reattach each
//! recovered stream over loopback TCP and prove its tick ordinals
//! continue where the killed run left off.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::EngineThread;
use deepcot::coordinator::session::EngineError;
use deepcot::manifest::Manifest;
use deepcot::net::client::{ClientError, NetClient};
use deepcot::net::server::{NetConfig, NetServer};
use deepcot::obs::expo;
use deepcot::obs::server::{MetricsFormat, MetricsServer};
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;

fn main() -> Result<()> {
    let cli = EngineConfig::cli(Cli::new(
        "deepcot_serve: TCP wire-protocol front door for the DeepCoT serving cluster",
    ))
    .opt("listen", "127.0.0.1:7433", "address to listen on (port 0 = ephemeral)")
    .opt("metrics-listen", "", "HTTP metrics endpoint address (empty = off, port 0 = ephemeral)")
    .opt("smoke", "0", "loopback self-test: push N tokens, then clean shutdown (0 = off)")
    .flag("smoke-hold", "after --smoke, keep serving instead of shutting down (crash-test aid)")
    .flag("resume-smoke", "resume every recovered stream over loopback TCP, then shut down")
    .flag(
        "expect-respawn",
        "chaos smoke: drive traffic through an injected shard crash (set --fault), assert the \
         supervisor re-homes + respawns, then shut down",
    )
    .flag("synthetic", "serve a hermetic synthetic model (no `make artifacts` needed)");
    let args = cli.parse()?;
    let mut cfg = EngineConfig::from_args(&args)?;
    if args.has("synthetic") {
        cfg.artifacts_dir = SyntheticServeSpec::default().write()?;
        cfg.variant = SyntheticServeSpec::variant_name(1);
        cfg.backend = EngineBackend::Scalar;
        if cfg.slots_per_shard == 0 {
            cfg.slots_per_shard = 4;
        }
    }
    // lane width for the smoke client, straight off the served manifest
    let (manifest, _) = Manifest::load(&cfg.artifacts_dir)?;
    let mc = &manifest.variant(&cfg.variant)?.config;
    let d_lane = mc.m_tokens * mc.d_in;

    let snapshot_every = cfg.snapshot_every;
    let persistent = cfg.state_dir.is_some();
    // front-door knobs (--net-workers, --net-max-conns, --net-max-streams,
    // --net-auth-token) ride on EngineConfig; lift them before the move
    let net_cfg = NetConfig::from_engine(&cfg);
    let auth_token = cfg.net_auth_token.clone();
    let engine = EngineThread::spawn(cfg).context("spawning the serving cluster")?;
    if persistent {
        let recovered = engine.handle().hibernated_streams().len();
        println!("deepcot_serve: recovered {recovered} hibernated stream(s) from the state dir");
    }
    let authed = net_cfg.auth_token.is_some();
    let server = NetServer::start_with(args.get("listen"), engine.handle(), net_cfg)
        .context("binding the front door")?;
    println!(
        "deepcot_serve: listening on {}{}",
        server.local_addr(),
        if authed { " (OPEN auth required)" } else { "" }
    );

    let obs = engine.handle().obs().clone();
    let metrics_srv = if args.get("metrics-listen").is_empty() {
        None
    } else {
        let eng = engine.handle();
        let net = server.metrics_handle();
        let srv = MetricsServer::start(args.get("metrics-listen"), move |fmt| {
            let obs = eng.obs();
            match fmt {
                MetricsFormat::JournalDrain => expo::render_journal(obs),
                _ => match eng.metrics() {
                    Ok(m) => {
                        let n = net.snapshot();
                        match fmt {
                            MetricsFormat::Prometheus => {
                                expo::render_prometheus(obs, &m, Some(&n))
                            }
                            _ => expo::render_json(obs, &m, Some(&n)),
                        }
                    }
                    Err(e) => format!("# metrics unavailable: {e}\n"),
                },
            }
        })
        .context("binding the metrics endpoint")?;
        println!("deepcot_serve: metrics endpoint on http://{}/metrics", srv.local_addr());
        Some(srv)
    };

    let smoke = args.get_usize("smoke")?;
    // a held smoke client must outlive the wait loop: dropping it would
    // close the connection and with it the server-side stream
    let mut _held_client = None;
    if smoke > 0 {
        let scrape = metrics_srv.as_ref().map(|s| s.local_addr());
        _held_client = run_smoke(
            &server,
            smoke,
            d_lane,
            scrape,
            obs.spans_on(),
            args.has("smoke-hold"),
            &auth_token,
        )?;
    }
    if args.has("resume-smoke") {
        run_resume_smoke(&server, &engine, d_lane, &auth_token)?;
    }
    if args.has("expect-respawn") {
        run_chaos_smoke(
            &server,
            &engine,
            d_lane,
            metrics_srv.as_ref().map(|s| s.local_addr()),
            &auth_token,
        )?;
    }

    // serve until some client requests shutdown (the smoke client
    // does), taking a full-cluster snapshot each period when one is
    // configured
    let period = if snapshot_every > Duration::ZERO { snapshot_every } else { Duration::from_secs(3600) };
    while !server.wait_shutdown_requested(period) {
        if snapshot_every > Duration::ZERO {
            // a failing snapshot degrades durability, not availability:
            // warn and keep serving (store-level failures are already
            // absorbed + metered inside snapshot itself)
            match engine.handle().snapshot() {
                Ok(n) if n > 0 => {
                    println!("deepcot_serve: snapshot checkpointed {n} live stream(s)");
                }
                Ok(_) => {}
                Err(e) => {
                    eprintln!("deepcot_serve: periodic snapshot failed: {e} — serving continues");
                }
            }
        }
    }
    println!("deepcot_serve: shutdown requested; draining");
    if persistent {
        // one final checkpoint so a clean shutdown loses nothing
        match engine.handle().snapshot() {
            Ok(n) => println!("deepcot_serve: final snapshot checkpointed {n} live stream(s)"),
            Err(e) => {
                eprintln!("deepcot_serve: final snapshot failed: {e} — shutting down anyway");
            }
        }
    }
    let net = server.metrics();
    drop(metrics_srv); // stop scraping before the engine goes away
    server.shutdown();
    engine.shutdown().context("engine shutdown")?;
    // dump whatever the journal still holds, one JSON line per event
    for ev in obs.journal().drain() {
        println!("deepcot_serve: journal {}", expo::event_json(&ev));
    }
    println!("deepcot_serve: drained ({})", net.report());
    Ok(())
}

/// `GET path` against the metrics endpoint; returns the response body.
fn scrape(addr: SocketAddr, path: &str) -> Result<String> {
    let mut sock = TcpStream::connect(addr).context("connecting to the metrics endpoint")?;
    sock.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(sock, "GET {path} HTTP/1.0\r\n\r\n")?;
    let mut resp = String::new();
    sock.read_to_string(&mut resp).context("reading the scrape response")?;
    anyhow::ensure!(resp.starts_with("HTTP/1.0 200"), "scrape of {path} failed: {resp}");
    match resp.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => anyhow::bail!("scrape of {path} returned no body"),
    }
}

/// Loopback self-test: a real TCP client against our own front door,
/// plus one scrape of the HTTP metrics endpoint when one is bound.
///
/// With `hold` set the client neither closes its stream nor requests
/// shutdown, and is returned to the caller so the connection (and with
/// it the server-side stream) stays alive until the process dies —
/// the setup half of the kill-and-recover smoke.
fn run_smoke(
    server: &NetServer,
    ticks: usize,
    d_lane: usize,
    metrics_addr: Option<SocketAddr>,
    spans_on: bool,
    hold: bool,
    auth_token: &str,
) -> Result<Option<NetClient>> {
    let mut client =
        NetClient::connect(server.local_addr()).context("smoke client connecting")?;
    client.set_auth_token(auth_token);
    client.set_read_timeout(Some(Duration::from_secs(30)))?;
    let stream = client.open().context("smoke open")?;
    let mut rng = Rng::new(0x5E21E);
    for t in 0..ticks {
        client
            .push(stream, &rng.normal_vec(d_lane, 1.0))
            .with_context(|| format!("smoke push {t}"))?;
        let tick = client.recv_tick(stream).with_context(|| format!("smoke tick {t}"))?;
        anyhow::ensure!(tick.tick == t as u64 + 1, "tick ordinal {} != {}", tick.tick, t + 1);
        anyhow::ensure!(
            tick.logits.iter().all(|v| v.is_finite()),
            "non-finite logits at tick {t}"
        );
    }
    println!("{}", client.metrics().context("smoke metrics")?);
    if let Some(addr) = metrics_addr {
        let body = scrape(addr, "/metrics")?;
        anyhow::ensure!(
            body.contains("deepcot_ticks_total"),
            "scrape missing deepcot_ticks_total:\n{body}"
        );
        if spans_on {
            let key = "deepcot_stage_latency_us_count{stage=\"backend_step\"}";
            let count = body
                .lines()
                .find_map(|l| l.strip_prefix(key))
                .and_then(|v| v.trim().parse::<f64>().ok())
                .unwrap_or(0.0);
            anyhow::ensure!(count > 0.0, "no backend_step stage spans in scrape:\n{body}");
        }
        println!("deepcot_serve: smoke scrape ok ({} bytes of /metrics)", body.len());
    }
    if hold {
        println!("deepcot_serve: smoke ok ({ticks} ticks over loopback); holding stream {stream}");
        return Ok(Some(client));
    }
    client.close(stream).context("smoke close")?;
    client.shutdown_server().context("smoke shutdown")?;
    println!("deepcot_serve: smoke ok ({ticks} ticks over loopback)");
    Ok(None)
}

/// The recovery half of the kill-and-recover smoke: reattach every
/// stream the engine recovered from its state dir over loopback TCP,
/// push one token each, and require the tick ordinal to *continue*
/// past 1 — proof the pre-kill state survived — then shut down.
fn run_resume_smoke(
    server: &NetServer,
    engine: &EngineThread,
    d_lane: usize,
    auth_token: &str,
) -> Result<()> {
    let ids = engine.handle().hibernated_streams();
    anyhow::ensure!(!ids.is_empty(), "resume-smoke found no recovered streams to resume");
    let mut client =
        NetClient::connect(server.local_addr()).context("resume-smoke client connecting")?;
    client.set_auth_token(auth_token);
    client.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut rng = Rng::new(0x2E5);
    for id in &ids {
        let stream = client
            .open_resume(id.0)
            .with_context(|| format!("resume-smoke reattaching stream {}", id.0))?;
        anyhow::ensure!(stream == id.0, "resume returned stream {stream}, asked for {}", id.0);
        client.push(stream, &rng.normal_vec(d_lane, 1.0)).context("resume-smoke push")?;
        let tick = client.recv_tick(stream).context("resume-smoke tick")?;
        anyhow::ensure!(
            tick.tick > 1,
            "stream {} restarted from tick {} instead of continuing",
            id.0,
            tick.tick
        );
        anyhow::ensure!(
            tick.logits.iter().all(|v| v.is_finite()),
            "non-finite logits after resuming stream {}",
            id.0
        );
    }
    client.shutdown_server().context("resume-smoke shutdown")?;
    println!("deepcot_serve: resume smoke ok ({} stream(s) continued past their kill point)", ids.len());
    Ok(())
}

/// Classify a chaos-smoke wire error: `Some(true)` — the stream lost
/// its owner (re-homed to a checkpoint, or its tick pump announced the
/// teardown) and wants an OPEN-resume; `Some(false)` — transient, just
/// retry after a beat; `None` — not part of the planned failure, the
/// smoke must fail loudly. `ShuttingDown` lands in `None` on purpose:
/// supervision must never masquerade as shutdown.
fn chaos_recoverable(e: &ClientError) -> Option<bool> {
    match e {
        ClientError::Engine(EngineError::Hibernated(_))
        | ClientError::Engine(EngineError::StreamClosed(_)) => Some(true),
        ClientError::Engine(EngineError::ShardFailed { retryable: true })
        | ClientError::Engine(EngineError::Timeout)
        | ClientError::Engine(EngineError::Backpressure(_)) => Some(false),
        _ => None,
    }
}

/// The supervision chaos smoke (`--expect-respawn`, paired with a
/// `--fault … shard_step=@N` plan): drive several streams over
/// loopback TCP into an injected shard-worker panic, recover each one
/// through the typed-error protocol (retry / OPEN-resume), and require
/// the metrics to report the crash, the re-home, and the respawn. The
/// client must finish — a hang or an untyped failure fails the smoke.
fn run_chaos_smoke(
    server: &NetServer,
    engine: &EngineThread,
    d_lane: usize,
    metrics_addr: Option<SocketAddr>,
    auth_token: &str,
) -> Result<()> {
    const STREAMS: usize = 4;
    const WARMUP: usize = 8;
    const CHAOS: usize = 40;
    let mut client =
        NetClient::connect(server.local_addr()).context("chaos client connecting")?;
    client.set_auth_token(auth_token);
    client.set_read_timeout(Some(Duration::from_secs(10)))?;
    let ids: Vec<u64> =
        (0..STREAMS).map(|_| client.open().context("chaos open")).collect::<Result<_>>()?;
    let mut rng = Rng::new(0xC4A05);
    // warm-up, then checkpoint: the injected crash must land AFTER a
    // snapshot so every stream has a checkpoint to re-home onto
    for _ in 0..WARMUP {
        for &id in &ids {
            client.push(id, &rng.normal_vec(d_lane, 1.0)).context("chaos warm-up push")?;
            client.recv_tick(id).context("chaos warm-up tick")?;
        }
    }
    let n = engine.handle().snapshot().context("chaos checkpoint")?;
    anyhow::ensure!(
        n >= STREAMS,
        "chaos smoke checkpointed only {n}/{STREAMS} streams — pass --state-dir (or --hibernate) \
         so every stream survives the injected crash"
    );
    println!("deepcot_serve: chaos smoke checkpointed {n} stream(s); entering fault window");
    let mut recoveries = 0u64;
    for round in 0..CHAOS {
        for &id in &ids {
            let tokens = rng.normal_vec(d_lane, 1.0);
            let mut attempts = 0u32;
            loop {
                attempts += 1;
                anyhow::ensure!(
                    attempts <= 100,
                    "stream {id} made no progress in round {round} after {attempts} attempts"
                );
                let step = match client.push(id, &tokens) {
                    Ok(()) => match client.recv_tick(id) {
                        Ok(t) => {
                            anyhow::ensure!(
                                t.logits.iter().all(|v| v.is_finite()),
                                "non-finite logits on stream {id} in round {round}"
                            );
                            Ok(())
                        }
                        Err(e) => Err(e),
                    },
                    Err(e) => Err(e),
                };
                match step {
                    Ok(()) => break,
                    Err(e) => match chaos_recoverable(&e) {
                        Some(true) => {
                            recoveries += 1;
                            // the crash enqueued a terminal error that
                            // may have answered the wrong request; a
                            // metrics round-trip parks any straggler
                            // replies and resynchronizes the connection
                            // before the OPEN-resume goes out
                            let _ = client.metrics();
                            match client.open_resume(id) {
                                // reattached — re-drive from the
                                // checkpoint (pushes past it died with
                                // the crashed worker, as designed)
                                Ok(_) => {}
                                // stale trigger (the stream is live) or
                                // the supervisor hasn't parked the
                                // orphan yet — let the retry loop spin
                                Err(ClientError::Engine(_)) => {
                                    std::thread::sleep(Duration::from_millis(20));
                                }
                                Err(e) => {
                                    return Err(e).with_context(|| {
                                        format!("chaos resume of stream {id}")
                                    })
                                }
                            }
                        }
                        Some(false) => std::thread::sleep(Duration::from_millis(20)),
                        None => {
                            return Err(e)
                                .with_context(|| format!("unrecoverable chaos error, stream {id}"))
                        }
                    },
                }
            }
        }
    }
    // the injected panic must be visible in the metrics: crash counted,
    // streams re-homed, worker respawned (give the supervisor a moment)
    let deadline = Instant::now() + Duration::from_secs(10);
    let m = loop {
        let m = engine.handle().metrics().context("chaos metrics")?;
        if m.shards_respawned >= 1 || Instant::now() >= deadline {
            break m;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    anyhow::ensure!(m.shard_failures >= 1, "no shard failure recorded — did the fault fire?");
    anyhow::ensure!(m.streams_rehomed >= 1, "crash recorded but no stream was re-homed");
    anyhow::ensure!(m.shards_respawned >= 1, "crashed shard was never respawned");
    anyhow::ensure!(m.shards_dead == 0, "a shard is still dead after the respawn window");
    anyhow::ensure!(recoveries >= 1, "client never exercised the resume recovery path");
    if let Some(addr) = metrics_addr {
        let body = scrape(addr, "/metrics")?;
        let respawned = body
            .lines()
            .find_map(|l| l.strip_prefix("deepcot_shards_respawned_total "))
            .and_then(|v| v.trim().parse::<f64>().ok())
            .unwrap_or(0.0);
        anyhow::ensure!(
            respawned >= 1.0,
            "scrape does not report the respawn:\n{body}"
        );
    }
    client.shutdown_server().context("chaos shutdown")?;
    println!(
        "deepcot_serve: chaos smoke ok (failures={} rehomed={} respawned={} client recoveries={})",
        m.shard_failures, m.streams_rehomed, m.shards_respawned, recoveries
    );
    Ok(())
}
