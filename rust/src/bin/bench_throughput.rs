//! Shard-sweep serving throughput bench: the cluster's reason to exist,
//! measured. Spins the engine up at each shard count in `--shards-list`
//! on a hermetic synthetic model (scalar backend — no XLA library, no
//! `make artifacts`), drives it with closed-loop client threads (push →
//! recv → push) over the RAII `Session` API, and reports aggregate
//! throughput plus engine-side tick latency quantiles. Slots are split
//! across shards as `ceil(streams / shards)` per shard, so every
//! configuration admits all streams with (near-)equal headroom —
//! exactly equal when the shard count divides the stream count (the
//! printed `slots` column shows each config's per-shard budget; prefer
//! divisible sweeps for strict apples-to-apples).
//!
//!     cargo run --release --bin bench_throughput -- \
//!         --shards-list 1,2,4 --streams 8 --ticks 200
//!
//! With `--migrate-every N` each client live-migrates its stream to the
//! next shard (round-robin) every N ticks mid-run — the migration smoke
//! (an extra slot per shard is budgeted so targets have headroom), with
//! the attempted/completed/aborted counters and quiesce quantiles
//! printed from `ClusterMetrics`.
//!
//! With `--tcp` the same closed-loop clients talk to the engine through
//! a loopback `net::server::NetServer` front door via the pipelined
//! `net::client::NetClient` — the end-to-end-over-the-wire series of
//! the perf trajectory, directly comparable to the in-process one
//! (same model, same traffic, `"transport"` recorded in `--json`).
//!
//! With `--tcp --conns 100,1000,10000` the bench switches to the
//! connection-fanout sweep: at each count it holds that many concurrent
//! loopback connections open against one server (at most 8 loader
//! threads drive them all — the front door itself runs a fixed worker
//! pool, so its thread count stays O(workers) however many sockets are
//! up, which the sweep asserts via `/proc/self/task` on Linux), pushes
//! `--ticks` ticks per connection, and reports connection-setup and
//! aggregate tick throughput per count. `net::poller::raise_nofile`
//! lifts `RLIMIT_NOFILE` first, and counts that exceed what the host
//! allows are scaled down with a note rather than failing the sweep.
//!
//! `--kernel-dispatch scalar|avx2|neon` forces the shard backends onto
//! one kernel path (`nn::simd`; default `auto` picks the widest the
//! CPU supports). The resolved path and detected CPU features land in
//! the `--json` document, so scalar and SIMD sweeps stay labelled in
//! the perf trajectory. Dispatch never changes stream bits.
//!
//! When the engine runs with `--obs spans` or above (the default),
//! every config also reports the per-stage pipeline breakdown (queue /
//! batch-form / backend-step / deliver spans from `obs::span`), and
//! the `--json` document carries it under `results[].stages` — the
//! where-did-the-latency-go axis of the perf trajectory.
//!
//! With `--registered N` (plus `--slots S`) the bench switches to the
//! hibernation-churn smoke instead of the shard sweep: register N
//! streams over a cluster with only `shards * S` lanes (hibernation
//! on, in-memory store), then hammer random members from worker
//! threads so pushes continually wake hibernated streams and spill
//! warm ones. Reports wakes/s and requires the hibernate/restore
//! counters to have moved — the capacity-beyond-lanes claim, measured.
//!
//! The CI smoke runs use a tiny model, 2 shards and a bounded tick
//! count — see .github/workflows/ci.yml.

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use deepcot::config::{EngineBackend, EngineConfig};
use deepcot::coordinator::engine::EngineThread;
use deepcot::coordinator::slots::StreamId;
use deepcot::net::client::NetClient;
use deepcot::net::poller::raise_nofile;
use deepcot::net::server::{NetConfig, NetServer};
use deepcot::nn::simd::{cpu_features, DispatchChoice, KernelOps};
use deepcot::synthetic::SyntheticServeSpec;
use deepcot::util::cli::Cli;
use deepcot::util::json::{num, obj, Json};
use deepcot::util::rng::Rng;

struct RunResult {
    shards: usize,
    slots_per_shard: usize,
    wall: Duration,
    ticks_per_sec: f64,
    streams_per_sec: f64,
    p50: Duration,
    p99: Duration,
    migrations: (u64, u64, u64),
    quiesce_p50: Duration,
    quiesce_p99: Duration,
    /// Per-stage `(name, count, p50, p99, sum)` pipeline breakdown,
    /// zero-count stages omitted (empty when the engine ran `obs` at a
    /// level below `spans`).
    stages: Vec<(&'static str, u64, Duration, Duration, Duration)>,
}

fn run_one(
    cfg: EngineConfig,
    streams: usize,
    ticks: usize,
    d_in: usize,
    migrate_every: usize,
    tcp: bool,
) -> Result<RunResult> {
    let shards = cfg.effective_shards();
    let slots_per_shard = cfg.slots_per_shard;
    let engine = EngineThread::spawn(cfg)?;
    // --tcp: same closed-loop clients, but every push/recv crosses a
    // loopback socket through the wire protocol (the end-to-end series
    // of the perf trajectory, next to the in-process one)
    let server = if tcp {
        Some(NetServer::start("127.0.0.1:0", engine.handle()).context("starting net server")?)
    } else {
        None
    };
    let addr = server.as_ref().map(|s| s.local_addr());
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for s in 0..streams {
        let h = engine.handle();
        clients.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xBE9C4 ^ ((s as u64 + 1) * 0x9E37));
            if let Some(addr) = addr {
                let mut c = NetClient::connect(addr).context("connect")?;
                c.set_read_timeout(Some(Duration::from_secs(60)))?;
                // total slots >= streams, but an open can race a
                // neighbor's placement; retry briefly
                let stream = {
                    let mut attempt = 0;
                    loop {
                        match c.open() {
                            Ok(stream) => break stream,
                            Err(_) if attempt < 50 => {
                                attempt += 1;
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(e) => return Err(e).context("tcp open"),
                        }
                    }
                };
                for t in 0..ticks {
                    c.push(stream, &rng.normal_vec(d_in, 1.0))
                        .with_context(|| format!("tcp push tick {t}"))?;
                    c.recv_tick(stream).with_context(|| format!("tcp tick {t} result"))?;
                    if migrate_every > 0 && (t + 1) % migrate_every == 0 {
                        // wire ids ARE engine StreamIds, so the bench
                        // can drive migration in-process while the
                        // traffic stays on the socket
                        let id = StreamId(stream);
                        let cur = h.shard_of(id).unwrap_or(0);
                        let _ = h.migrate(id, (cur + 1) % shards.max(1));
                    }
                }
                let _ = c.close(stream);
                return Ok(());
            }
            let sess = {
                let mut attempt = 0;
                loop {
                    match h.open() {
                        Ok(sess) => break sess,
                        Err(_) if attempt < 50 => {
                            attempt += 1;
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => return Err(e).context("open"),
                    }
                }
            };
            for t in 0..ticks {
                sess.push(rng.normal_vec(d_in, 1.0))
                    .with_context(|| format!("push tick {t}"))?;
                sess.recv_timeout(Duration::from_secs(60))
                    .with_context(|| format!("tick {t} result"))?;
                if migrate_every > 0 && (t + 1) % migrate_every == 0 {
                    // hop to the next shard round-robin; a saturated
                    // target aborts the hop with the stream intact, so
                    // the bench keeps running either way
                    let cur = h.shard_of(sess.id()).unwrap_or(0);
                    let _ = h.migrate(sess.id(), (cur + 1) % shards.max(1));
                }
            }
            sess.close();
            Ok(())
        }));
    }
    for c in clients {
        c.join().expect("client thread")?;
    }
    let wall = t0.elapsed();
    let m = engine.handle().metrics()?;
    if let Some(server) = server {
        server.shutdown();
    }
    engine.shutdown()?;
    let total_ticks = (streams * ticks) as f64;
    Ok(RunResult {
        shards,
        slots_per_shard,
        wall,
        ticks_per_sec: total_ticks / wall.as_secs_f64(),
        streams_per_sec: streams as f64 / wall.as_secs_f64(),
        p50: m.tick_latency.quantile(0.5),
        p99: m.tick_latency.quantile(0.99),
        migrations: (m.migrations_attempted, m.migrations_completed, m.migrations_aborted),
        quiesce_p50: m.quiesce_latency.quantile(0.5),
        quiesce_p99: m.quiesce_latency.quantile(0.99),
        stages: m
            .stage_spans
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(s, h)| (s.name(), h.count(), h.quantile(0.5), h.quantile(0.99), h.sum()))
            .collect(),
    })
}

/// Hibernation-churn smoke: register far more streams than the cluster
/// has lanes (hibernation spills the overflow to an in-memory store at
/// open time), then wake random members from closed-loop worker
/// threads — every wake of a hibernated stream restores it into a lane
/// and spills a warmer victim. The run fails unless both churn
/// counters moved, so CI catches a silently-disabled hibernation path.
fn run_churn(cfg: EngineConfig, registered: usize, wakes: usize, d_in: usize) -> Result<()> {
    let shards = cfg.effective_shards();
    let lanes = shards * cfg.slots_per_shard;
    anyhow::ensure!(
        registered > lanes,
        "--registered ({registered}) must exceed total lanes ({lanes}) for churn to happen"
    );
    let engine = EngineThread::spawn(cfg)?;
    let h = engine.handle();
    let t0 = Instant::now();
    let mut sessions = Vec::with_capacity(registered);
    for i in 0..registered {
        sessions.push(h.open().with_context(|| format!("registering stream {i}"))?);
    }
    let register_wall = t0.elapsed();
    println!(
        "hibernation churn: {registered} streams registered over {lanes} lanes \
         ({shards} shards) in {register_wall:.2?}"
    );
    let wakes = if wakes == 0 { registered * 2 } else { wakes };
    let workers = sessions.len().min(8).max(1);
    let per = registered.div_ceil(workers);
    let t1 = Instant::now();
    let mut handles = Vec::new();
    let mut iter = sessions.into_iter();
    for w in 0..workers {
        let mine: Vec<_> = iter.by_ref().take(per).collect();
        if mine.is_empty() {
            break;
        }
        let quota = wakes / workers + usize::from(w < wakes % workers);
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xC0FFEE ^ ((w as u64 + 1) * 0x9E37));
            for _ in 0..quota {
                // random member: overwhelmingly a hibernated stream,
                // so this push transparently restores it into a lane
                let sess = &mine[rng.below(mine.len())];
                sess.push(rng.normal_vec(d_in, 1.0)).context("churn push")?;
                sess.recv_timeout(Duration::from_secs(60)).context("churn tick")?;
            }
            Ok(())
        }));
    }
    for t in handles {
        t.join().expect("churn worker")?;
    }
    let churn_wall = t1.elapsed();
    let m = h.metrics()?;
    engine.shutdown()?;
    println!(
        "hibernation churn: {wakes} wakes in {churn_wall:.2?} ({:.1} wakes/s), \
         hibernated={} restored={} resident={}",
        wakes as f64 / churn_wall.as_secs_f64(),
        m.streams_hibernated,
        m.streams_restored,
        m.hibernated_resident,
    );
    anyhow::ensure!(m.streams_hibernated > 0, "churn never hibernated a stream");
    anyhow::ensure!(m.streams_restored > 0, "churn never restored a hibernated stream");
    Ok(())
}

/// Threads in this process right now (Linux; `None` elsewhere).
fn count_threads() -> Option<u64> {
    std::fs::read_dir("/proc/self/task").ok().map(|d| d.count() as u64)
}

struct ConnResult {
    conns: usize,
    setup: Duration,
    wall: Duration,
    ticks_per_sec: f64,
    /// Process thread count with every connection up (Linux only).
    threads: Option<u64>,
    net_workers: u64,
}

/// Connection-fanout sweep: hold `conns` concurrent loopback
/// connections (one stream each) against one executor-driven server,
/// driven by at most 8 loader threads, and measure setup + aggregate
/// tick throughput. The server's thread count must stay O(workers).
fn run_conns(
    dir: &std::path::Path,
    shards: usize,
    conns: usize,
    ticks: usize,
    d_in: usize,
    deadline_us: u64,
    dispatch: DispatchChoice,
) -> Result<ConnResult> {
    let threads_before = count_threads();
    let cfg = EngineConfig::builder()
        .artifacts_dir(dir)
        .variant(SyntheticServeSpec::variant_name(1))
        .backend(EngineBackend::Scalar)
        .batch_deadline(Duration::from_micros(deadline_us))
        .shards(shards)
        // least-loaded keeps lane demand exactly balanced, so one
        // slot of headroom per shard admits every connection's stream
        .slots_per_shard(conns.div_ceil(shards) + 1)
        .placement(deepcot::config::PlacementPolicy::LeastLoaded)
        .kernel_dispatch(dispatch)
        .net_max_conns(conns + 16)
        .build();
    let net_cfg = NetConfig::from_engine(&cfg);
    let engine = EngineThread::spawn(cfg)?;
    let server = NetServer::start_with("127.0.0.1:0", engine.handle(), net_cfg)
        .context("starting net server")?;
    let addr = server.local_addr();
    let loaders = conns.clamp(1, 8);
    let per = conns.div_ceil(loaders);
    let t0 = Instant::now();
    // phase A: bring every connection up, one stream each
    let mut setup = Vec::new();
    for l in 0..loaders {
        let mine = per.min(conns - (l * per).min(conns));
        if mine == 0 {
            break;
        }
        setup.push(std::thread::spawn(move || -> Result<Vec<(NetClient, u64)>> {
            let mut out = Vec::with_capacity(mine);
            for i in 0..mine {
                let mut c = NetClient::connect(addr)
                    .with_context(|| format!("loader {l} connection {i}"))?;
                c.set_read_timeout(Some(Duration::from_secs(60)))?;
                let stream = {
                    let mut attempt = 0;
                    loop {
                        match c.open() {
                            Ok(stream) => break stream,
                            Err(_) if attempt < 50 => {
                                attempt += 1;
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(e) => return Err(e).context("conn-sweep open"),
                        }
                    }
                };
                out.push((c, stream));
            }
            Ok(out)
        }));
    }
    let fleets: Vec<Vec<(NetClient, u64)>> =
        setup.into_iter().map(|h| h.join().expect("loader thread")).collect::<Result<_>>()?;
    let setup_wall = t0.elapsed();
    let threads_up = count_threads();
    let m = server.metrics();
    anyhow::ensure!(
        m.connections_active as usize == conns,
        "sweep expected {conns} active connections, server reports {}",
        m.connections_active
    );
    if let (Some(before), Some(up)) = (threads_before, threads_up) {
        // the whole point: sockets don't cost threads. Loaders (≤8) +
        // executor + workers (≤8) are the only additions.
        anyhow::ensure!(
            up.saturating_sub(before) < 100,
            "thread count grew by {} for {conns} connections — the executor is supposed to \
             hold it O(workers)",
            up.saturating_sub(before)
        );
    }
    // phase B: closed-loop ticks on every connection
    let t1 = Instant::now();
    let mut drivers = Vec::new();
    for (l, mut mine) in fleets.into_iter().enumerate() {
        drivers.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xC09_15 ^ ((l as u64 + 1) * 0x9E37));
            for t in 0..ticks {
                for (c, stream) in &mut mine {
                    c.push(*stream, &rng.normal_vec(d_in, 1.0))
                        .with_context(|| format!("conn-sweep push tick {t}"))?;
                    c.recv_tick(*stream).with_context(|| format!("conn-sweep tick {t}"))?;
                }
            }
            for (c, stream) in &mut mine {
                let _ = c.close(*stream);
            }
            Ok(())
        }));
    }
    for d in drivers {
        d.join().expect("driver thread")?;
    }
    let wall = t1.elapsed();
    let net_workers = server.metrics().workers;
    server.shutdown();
    engine.shutdown()?;
    Ok(ConnResult {
        conns,
        setup: setup_wall,
        wall,
        ticks_per_sec: (conns * ticks) as f64 / wall.as_secs_f64(),
        threads: threads_up,
        net_workers,
    })
}

fn main() -> Result<()> {
    let cli = Cli::new("bench_throughput: aggregate serving throughput vs shard count")
        .opt("shards-list", "1,2,4", "comma-separated shard counts to sweep")
        .opt("streams", "8", "concurrent closed-loop client streams")
        .opt("ticks", "200", "ticks per stream")
        .opt("d-model", "32", "synthetic model width")
        .opt("n-layers", "2", "synthetic model depth")
        .opt("n-heads", "4", "synthetic attention heads")
        .opt("window", "16", "synthetic continual window")
        .opt("deadline-us", "200", "partial-batch flush deadline (µs)")
        .opt("placement", "hash", "stream placement: hash|least-loaded|round-robin")
        .opt("kernel-dispatch", "auto", "kernel path: auto|scalar|avx2|neon")
        .opt("migrate-every", "0", "live-migrate each stream every N ticks (0 = off)")
        .opt("registered", "0", "hibernation churn: register N streams over few lanes (0 = off)")
        .opt("slots", "32", "hibernation churn: lanes per shard")
        .opt("wakes", "0", "hibernation churn: total random wakes (0 = 2x registered)")
        .opt("json", "", "write sweep results JSON to this path (perf trajectory)")
        .opt("conns", "", "connection-fanout sweep: comma-separated counts (requires --tcp)")
        .flag("tcp", "drive the engine end-to-end over a loopback TCP front door");
    let args = cli.parse()?;
    let tcp = args.has("tcp");
    let shard_counts: Vec<usize> = args
        .get("shards-list")
        .split(',')
        .map(|s| s.trim().parse::<usize>().context("--shards-list entries must be integers"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!shard_counts.is_empty(), "--shards-list must name at least one count");
    let streams = args.get_usize("streams")?.max(1);
    let ticks = args.get_usize("ticks")?.max(1);
    let migrate_every = args.get_usize("migrate-every")?;
    let dispatch: DispatchChoice = args.get("kernel-dispatch").parse()?;
    // resolve up front so a forced-but-unsupported path fails before
    // any engine spins up, and so the sweep can report the real path
    let kops = KernelOps::resolve(dispatch)?;
    let d_model = args.get_usize("d-model")?;
    let spec = SyntheticServeSpec {
        d_in: (d_model / 2).max(1),
        d_model,
        n_heads: args.get_usize("n-heads")?,
        n_layers: args.get_usize("n-layers")?,
        window: args.get_usize("window")?,
        n_classes: 4,
        seed: 0xBE9C4,
        batches: vec![1],
    };
    let dir = spec.write()?;
    println!(
        "bench_throughput[{}]: {} streams x {} ticks, model d={} L={} H={} n={}, \
         dispatch={}, deadline={}µs{}",
        if tcp { "tcp" } else { "in-process" },
        streams,
        ticks,
        spec.d_model,
        spec.n_layers,
        spec.n_heads,
        spec.window,
        kops.path,
        args.get_u64("deadline-us")?,
        if migrate_every > 0 {
            format!(", migrate every {migrate_every} ticks")
        } else {
            String::new()
        },
    );
    if !args.get("conns").is_empty() {
        anyhow::ensure!(args.has("tcp"), "--conns is a TCP front-door sweep; pass --tcp");
        let mut wanted: Vec<usize> = args
            .get("conns")
            .split(',')
            .map(|s| s.trim().parse::<usize>().context("--conns entries must be integers"))
            .collect::<Result<_>>()?;
        anyhow::ensure!(
            wanted.iter().all(|&n| n > 0),
            "--conns entries must be positive connection counts"
        );
        // each connection is one client fd here + one accepted fd in
        // the (same-process) server, plus engine/artifact overhead
        let max = wanted.iter().copied().max().unwrap_or(0);
        let limit = raise_nofile(max as u64 * 2 + 256).unwrap_or(u64::MAX);
        let affordable = (limit.saturating_sub(256) / 2) as usize;
        for n in &mut wanted {
            if *n > affordable {
                println!(
                    "conns: scaling {n} down to {affordable} (RLIMIT_NOFILE allows {limit} fds)"
                );
                *n = affordable.max(1);
            }
        }
        let shards = shard_counts[0].max(1);
        let mut results = Vec::with_capacity(wanted.len());
        for &conns in &wanted {
            results.push(run_conns(
                &dir,
                shards,
                conns,
                ticks,
                spec.d_in,
                args.get_u64("deadline-us")?,
                dispatch,
            )?);
        }
        println!(
            "{:>8} {:>10} {:>10} {:>12} {:>8} {:>8}",
            "conns", "setup", "wall", "ticks/s", "workers", "threads"
        );
        for r in &results {
            println!(
                "{:>8} {:>10.2?} {:>10.2?} {:>12.1} {:>8} {:>8}",
                r.conns,
                r.setup,
                r.wall,
                r.ticks_per_sec,
                r.net_workers,
                r.threads.map(|t| t.to_string()).unwrap_or_else(|| "-".into()),
            );
        }
        if !args.get("json").is_empty() {
            let doc = obj(vec![
                ("bench", Json::Str("throughput".into())),
                ("transport", Json::Str("tcp-loopback".into())),
                ("mode", Json::Str("conn_sweep".into())),
                ("ticks_per_conn", num(ticks as f64)),
                ("shards", num(shards as f64)),
                ("kernel_dispatch", Json::Str(kops.path.as_str().into())),
                ("cpu_features", Json::Str(cpu_features())),
                (
                    "results",
                    Json::Arr(
                        results
                            .iter()
                            .map(|r| {
                                obj(vec![
                                    ("conns", num(r.conns as f64)),
                                    ("setup_s", num(r.setup.as_secs_f64())),
                                    ("wall_s", num(r.wall.as_secs_f64())),
                                    ("ticks_per_sec", num(r.ticks_per_sec)),
                                    ("net_workers", num(r.net_workers as f64)),
                                    (
                                        "process_threads",
                                        num(r.threads.map(|t| t as f64).unwrap_or(-1.0)),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]);
            let path = args.get("json").to_string();
            std::fs::write(&path, doc.to_string() + "\n")
                .with_context(|| format!("writing {path}"))?;
            println!("wrote {path}");
        }
        return Ok(());
    }
    let registered = args.get_usize("registered")?;
    if registered > 0 {
        let shards = shard_counts[0].max(1);
        let cfg = EngineConfig::builder()
            .artifacts_dir(dir.clone())
            .variant(SyntheticServeSpec::variant_name(1))
            .backend(EngineBackend::Scalar)
            .batch_deadline(Duration::from_micros(args.get_u64("deadline-us")?))
            .shards(shards)
            .slots_per_shard(args.get_usize("slots")?.max(1))
            .placement(args.get("placement").parse()?)
            .kernel_dispatch(dispatch)
            .hibernate(true)
            .build();
        return run_churn(cfg, registered, args.get_usize("wakes")?, spec.d_in);
    }
    let mut results = Vec::with_capacity(shard_counts.len());
    for &shards in &shard_counts {
        let shards = shards.max(1);
        // with live migration in the mix, give every shard one slot of
        // headroom so a hop always has somewhere to land
        let slots = streams.div_ceil(shards) + usize::from(migrate_every > 0);
        let cfg = EngineConfig::builder()
            .artifacts_dir(dir.clone())
            .variant(SyntheticServeSpec::variant_name(1))
            .backend(EngineBackend::Scalar)
            .batch_deadline(Duration::from_micros(args.get_u64("deadline-us")?))
            .shards(shards)
            .slots_per_shard(slots)
            .placement(args.get("placement").parse()?)
            .kernel_dispatch(dispatch)
            .build();
        results.push(run_one(cfg, streams, ticks, spec.d_in, migrate_every, tcp)?);
    }
    // speedups are anchored to the 1-shard entry when the sweep has one
    // (the headline sharded-vs-single number); otherwise to the first
    let baseline = results
        .iter()
        .find(|r| r.shards == 1)
        .unwrap_or(&results[0])
        .ticks_per_sec;
    println!(
        "{:>6} {:>6} {:>10} {:>12} {:>12} {:>10} {:>10} {:>8}",
        "shards", "slots", "wall", "ticks/s", "streams/s", "tick p50", "tick p99", "speedup"
    );
    for r in &results {
        println!(
            "{:>6} {:>6} {:>10.2?} {:>12.1} {:>12.2} {:>10.2?} {:>10.2?} {:>7.2}x",
            r.shards,
            r.slots_per_shard,
            r.wall,
            r.ticks_per_sec,
            r.streams_per_sec,
            r.p50,
            r.p99,
            r.ticks_per_sec / baseline
        );
    }
    // per-stage pipeline breakdown (obs=spans and above; the engine
    // default) — where each tick's latency actually went
    for r in results.iter().filter(|r| !r.stages.is_empty()) {
        let cut = r
            .stages
            .iter()
            .map(|(name, n, p50, p99, _)| format!("{name}={n}@(p50 {p50:.2?}, p99 {p99:.2?})"))
            .collect::<Vec<_>>()
            .join(" ");
        println!("stages @{} shards: {cut}", r.shards);
    }
    if !args.get("json").is_empty() {
        let doc = obj(vec![
            ("bench", Json::Str("throughput".into())),
            (
                "transport",
                Json::Str(if tcp { "tcp-loopback".into() } else { "in-process".into() }),
            ),
            ("streams", num(streams as f64)),
            ("ticks", num(ticks as f64)),
            ("migrate_every", num(migrate_every as f64)),
            ("kernel_dispatch", Json::Str(kops.path.as_str().into())),
            ("cpu_features", Json::Str(cpu_features())),
            (
                "model",
                obj(vec![
                    ("d_in", num(spec.d_in as f64)),
                    ("d_model", num(spec.d_model as f64)),
                    ("n_heads", num(spec.n_heads as f64)),
                    ("n_layers", num(spec.n_layers as f64)),
                    ("window", num(spec.window as f64)),
                ]),
            ),
            (
                "results",
                Json::Arr(
                    results
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("shards", num(r.shards as f64)),
                                ("slots_per_shard", num(r.slots_per_shard as f64)),
                                ("wall_s", num(r.wall.as_secs_f64())),
                                ("ticks_per_sec", num(r.ticks_per_sec)),
                                ("streams_per_sec", num(r.streams_per_sec)),
                                ("tick_p50_us", num(r.p50.as_secs_f64() * 1e6)),
                                ("tick_p99_us", num(r.p99.as_secs_f64() * 1e6)),
                                ("speedup_vs_baseline", num(r.ticks_per_sec / baseline)),
                                (
                                    "stages",
                                    Json::Arr(
                                        r.stages
                                            .iter()
                                            .map(|(name, n, p50, p99, sum)| {
                                                obj(vec![
                                                    ("stage", Json::Str((*name).into())),
                                                    ("count", num(*n as f64)),
                                                    ("p50_us", num(p50.as_secs_f64() * 1e6)),
                                                    ("p99_us", num(p99.as_secs_f64() * 1e6)),
                                                    ("sum_us", num(sum.as_secs_f64() * 1e6)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let path = args.get("json").to_string();
        std::fs::write(&path, doc.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if migrate_every > 0 {
        for r in &results {
            let (att, done, aborted) = r.migrations;
            println!(
                "migrations @{} shards: attempted={} completed={} aborted={} \
                 quiesce(p50={:.2?} p99={:.2?})",
                r.shards, att, done, aborted, r.quiesce_p50, r.quiesce_p99
            );
            anyhow::ensure!(
                r.shards == 1 || done > 0,
                "migration smoke expected at least one completed migration on {} shards",
                r.shards
            );
        }
    }
    Ok(())
}
