//! Regenerates Table IV (GLUE-style text grid, 7 tasks x 3 scales) — exp T4.
use anyhow::Result;
use deepcot::bench_harness::tables::{run_table4, BenchOpts, T4_TASKS};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_table4: GLUE grid (paper Table IV)")
        .opt("seed", "0", "workload seed")
        .opt("scale", "1.0", "corpus-size multiplier")
        .opt("scales", "0,1,2", "window scales to run (0=x0.5,1=x1,2=x2)")
        .opt("tasks", "all", "comma-separated task subset (e.g. CoLA,MNLI)")
        .flag("quick", "reduced corpus + time budget")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    if !args.has("quick") {
        opts.scale = args.get_f64("scale")?;
    }
    let scales: Vec<usize> =
        args.get("scales").split(',').filter_map(|s| s.trim().parse().ok()).collect();
    let all: Vec<&str> = T4_TASKS.iter().map(|(t, _)| *t).collect();
    let tasks: Vec<&str> = if args.get("tasks") == "all" {
        all
    } else {
        all.into_iter()
            .filter(|t| args.get("tasks").split(',').any(|x| x.trim() == *t))
            .collect()
    };
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    run_table4(&rt, &opts, &scales, &tasks)?;
    Ok(())
}
