//! Ablations from §IV-F: window-size dependence of the speedup, SOFT
//! activation overhead, PJRT-vs-scalar engine, and slot-batch scaling.
use anyhow::Result;
use deepcot::baselines::{ContinualModel, ScalarModel};
use deepcot::bench_harness::table::{fmt_secs, Table};
use deepcot::bench_harness::tables::BenchOpts;
use deepcot::bench_harness::{adaptive_ticks, measure_ticks};
use deepcot::coordinator::batcher::TickPlan;
use deepcot::coordinator::slot_stepper::SlotStepper;
use deepcot::coordinator::slots::StreamId;
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args = Cli::new("bench_ablations: design-choice ablations (DESIGN.md A1)")
        .opt("seed", "0", "seed")
        .flag("quick", "reduced time budget")
        .parse()?;
    let opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    let seed = args.get_u64("seed")?;
    let rt = Runtime::new(&deepcot::artifacts_dir())?;

    // A1a: SOFT vs softmax activation latency (fig1 geometry, n=64)
    let mut t = Table::new("Ablation — SOFT activation overhead (n=64)", &["Model", "per-tick"]);
    for v in ["fig1_deepcot_n64", "fig1_deepcot_soft_n64"] {
        let mut m = ContinualModel::load(&rt, v)?;
        let (s, _) = measure_ticks(&mut m, 3, 32, seed)?;
        t.row(vec![v.into(), fmt_secs(s.mean_s)]);
    }
    t.emit(&opts.out_dir, "ablations")?;

    // A1b: PJRT executable vs pure-Rust scalar engine (same weights)
    let mut t = Table::new("Ablation — PJRT vs scalar engine (t1_deepcot)", &["Engine", "per-tick"]);
    let mut pjrt = ContinualModel::load(&rt, "t1_deepcot")?;
    let (s, _) = measure_ticks(&mut pjrt, 3, 48, seed)?;
    t.row(vec!["PJRT (XLA AOT)".into(), fmt_secs(s.mean_s)]);
    let mut scalar = ScalarModel::load(&rt, "t1_deepcot")?;
    let (s2, _) = measure_ticks(&mut scalar, 1, 16, seed)?;
    t.row(vec!["scalar Rust".into(), fmt_secs(s2.mean_s)]);
    t.emit(&opts.out_dir, "ablations")?;

    // A1c: slot-batch scaling — tokens/s at B in {1,4,16}
    let mut t = Table::new(
        "Ablation — slot-batch scaling (serve_deepcot_bB, full lanes)",
        &["B", "tick latency", "tokens/s"],
    );
    for b in [1usize, 4, 16] {
        let variant = rt.load(&format!("serve_deepcot_b{b}"))?;
        let cfg = variant.entry.config.clone();
        let mut stepper = SlotStepper::new(variant)?;
        let mut rng = Rng::new(seed);
        let lane = cfg.m_tokens * cfg.d_in;
        let mk_plan = |rng: &mut Rng| TickPlan {
            lanes: (0..b)
                .map(|s| (s, StreamId(s as u64), rng.normal_vec(lane, 1.0), Instant::now()))
                .collect(),
        };
        for _ in 0..3 {
            let p = mk_plan(&mut rng);
            stepper.tick_lanes(&p)?;
        }
        let probe = {
            let p = mk_plan(&mut rng);
            let t0 = Instant::now();
            stepper.tick_lanes(&p)?;
            t0.elapsed()
        };
        let iters = adaptive_ticks(probe, opts.time_budget, 8);
        let t0 = Instant::now();
        for _ in 0..iters {
            let p = mk_plan(&mut rng);
            stepper.tick_lanes(&p)?;
        }
        let per = t0.elapsed() / iters as u32;
        t.row(vec![
            b.to_string(),
            format!("{per:.2?}"),
            format!("{:.1}", b as f64 / per.as_secs_f64()),
        ]);
        let _ = Duration::ZERO;
    }
    t.emit(&opts.out_dir, "ablations")?;
    Ok(())
}
