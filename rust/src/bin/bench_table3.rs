//! Regenerates Table III (SED with the MAT-SED pipeline) — exp T3.
use anyhow::Result;
use deepcot::bench_harness::tables::{run_table3, BenchOpts};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_table3: SED table (paper Table III)")
        .opt("seed", "0", "workload seed")
        .opt("scale", "1.0", "corpus-size multiplier")
        .flag("quick", "reduced corpus + time budget")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    if !args.has("quick") {
        opts.scale = args.get_f64("scale")?;
    }
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    run_table3(&rt, &opts)?;
    Ok(())
}
