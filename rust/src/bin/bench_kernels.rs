//! Kernel-suite microbenchmark: the `nn::kernels` SIMD-friendly path
//! versus the frozen naive baseline, per-op and end-to-end.
//!
//! Per-op rows time the old primitive against its kernel-suite
//! replacement on the bench geometry: sequential-sum `tensor::dot` vs
//! the 8-wide `kernels::dot`, naive `matmul_into` + `add_row` vs the
//! packed fused [`PackedLinear`], per-call `apply_rope_inplace` vs
//! [`RopeTable`] rows, and per-row `iter_rows` attention vs the
//! two-segment kernels on a mid-wrap ring. The end-to-end rows tick
//! the frozen [`NaiveScalarDeepCoT`] against the kernel-suite
//! [`ScalarDeepCoT`] (plus the 4-lane batched stepper, per-lane
//! normalized) on the same synthetic model and weights.
//!
//!     cargo run --release --bin bench_kernels -- \
//!         --d-model 64 --n-heads 4 --n-layers 4 --window 128
//!
//! `--json <path>` writes the numbers for the perf trajectory
//! (`BENCH_KERNELS.json` at the repo root holds the committed
//! baseline); `--quick` bounds iteration counts for CI smokes; and
//! `--assert-speedup X` fails the run if the end-to-end kernel tick is
//! not at least `X` times faster than the naive tick — CI guards the
//! scalar leg at a generous 1.0x (not-slower) and the native SIMD leg
//! at a stricter bar, real numbers live in the JSON.
//!
//! Kernel dispatch: `--kernel-dispatch scalar|avx2|neon|auto` pins the
//! kernel path for the whole run (it also exports
//! `DEEPCOT_KERNEL_DISPATCH` so the end-to-end engine constructors
//! follow); the resolved path and the detected CPU features are printed
//! and recorded in the JSON, so a number is never divorced from the
//! hardware and path that produced it. `--assert-dispatch
//! scalar|avx2|neon|simd` fails the run if the resolved path is not
//! the expected one (`simd` = any non-scalar path) — the CI guard
//! against dispatch silently falling back.

use std::hint::black_box;
use std::time::Instant;

use anyhow::{Context, Result};

use deepcot::manifest::ModelConfig;
use deepcot::nn::batched::BatchedScalarDeepCoT;
use deepcot::nn::encoder::ScalarDeepCoT;
use deepcot::nn::kv_ring::KvRing;
use deepcot::nn::naive::NaiveScalarDeepCoT;
use deepcot::nn::params::ModelParams;
use deepcot::nn::rope::{apply_rope_inplace, RopeTable};
use deepcot::nn::simd::{cpu_features, DispatchChoice, DispatchPath, KernelOps, DISPATCH_ENV};
use deepcot::nn::tensor::{self, Mat};
use deepcot::util::cli::Cli;
use deepcot::util::json::{num, obj, Json};
use deepcot::util::rng::Rng;

/// Best-of-3 nanoseconds per call of `f` (each sample times `iters`
/// calls after a warmup); min is the standard microbench estimator
/// under scheduler noise.
fn time_ns<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let iters = iters.max(1);
    let mut best = f64::INFINITY;
    for _ in 0..iters / 10 + 1 {
        f();
    }
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    best
}

struct OpRow {
    name: &'static str,
    naive_ns: f64,
    kernel_ns: f64,
}

impl OpRow {
    fn speedup(&self) -> f64 {
        self.naive_ns / self.kernel_ns
    }
}

fn bench_ops(cfg: &ModelConfig, iters: usize, kops: &'static KernelOps) -> Vec<OpRow> {
    let mut rng = Rng::new(0xBE9C5);
    let d = cfg.d_model;
    let (h, dh, mlen) = (cfg.n_heads, cfg.d_head(), cfg.mem_len());
    let mut rows = Vec::new();

    // dot: one d_model-wide reduction
    {
        let a = rng.normal_vec(d, 1.0);
        let b = rng.normal_vec(d, 1.0);
        let naive_ns = time_ns(iters * 64, || {
            black_box(tensor::dot(black_box(&a), black_box(&b)));
        });
        let kernel_ns = time_ns(iters * 64, || {
            black_box((kops.dot)(black_box(&a), black_box(&b)));
        });
        rows.push(OpRow { name: "dot_d_model", naive_ns, kernel_ns });
    }

    // fused matmul+bias: one 4-row projection (d x d)
    {
        let w = Mat::from_vec(d, d, rng.normal_vec(d * d, 1.0 / (d as f32).sqrt()));
        let bias = rng.normal_vec(d, 0.02);
        let x = Mat::from_vec(4, d, rng.normal_vec(4 * d, 1.0));
        let mut out = Mat::zeros(4, d);
        let naive_ns = time_ns(iters, || {
            black_box(&x).matmul_into(black_box(&w), &mut out);
            out.add_row(black_box(&bias));
            black_box(out.at(0, 0));
        });
        let packed = deepcot::nn::kernels::PackedLinear::pack_with(&w, &bias, kops);
        let kernel_ns = time_ns(iters, || {
            packed.forward_into(black_box(&x), &mut out);
            black_box(out.at(0, 0));
        });
        rows.push(OpRow { name: "matmul_bias_4xd", naive_ns, kernel_ns });
    }

    // rope: all heads of one token row, fresh position every call
    // (the engine additionally reuses each row across Q/K and layers)
    {
        let row0 = rng.normal_vec(h * dh, 1.0);
        let mut row = row0.clone();
        let mut tab = RopeTable::new(dh, 1);
        let mut pos = 0i32;
        let naive_ns = time_ns(iters, || {
            row.copy_from_slice(&row0);
            pos += 1;
            for hh in 0..h {
                apply_rope_inplace(&mut row[hh * dh..(hh + 1) * dh], pos);
            }
            black_box(row[0]);
        });
        let kernel_ns = time_ns(iters, || {
            row.copy_from_slice(&row0);
            pos += 1;
            let (sin, cos) = tab.row(0, pos);
            (kops.rope_rotate_row)(&mut row, dh, sin, cos);
            black_box(row[0]);
        });
        rows.push(OpRow { name: "rope_token_row", naive_ns, kernel_ns });
    }

    // attention inner loop: scores + V accumulation of one query head
    // over a mid-wrap ring (both segments non-empty)
    {
        let mut kring = KvRing::new(mlen, dh);
        let mut vring = KvRing::new(mlen, dh);
        for _ in 0..mlen + mlen / 2 + 1 {
            kring.push(&rng.normal_vec(dh, 1.0));
            vring.push(&rng.normal_vec(dh, 1.0));
        }
        let q = rng.normal_vec(dh, 1.0);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut s = vec![0.0f32; mlen];
        let mut acc = vec![0.0f32; dh];
        let naive_ns = time_ns(iters, || {
            for (j, krow) in kring.iter_rows().enumerate() {
                s[j] = tensor::dot(black_box(&q), krow) * scale;
            }
            acc.fill(0.0);
            for (j, vrow) in vring.iter_rows().enumerate() {
                let w = s[j];
                for (o, &vv) in acc.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
            black_box(acc[0]);
        });
        let kernel_ns = time_ns(iters, || {
            let (ka, kb) = kring.as_segments();
            let (va, vb) = vring.as_segments();
            (kops.dot_scores_segments)(black_box(&q), ka, kb, scale, &mut s);
            acc.fill(0.0);
            (kops.weighted_sum_segments)(&s, va, vb, &mut acc);
            black_box(acc[0]);
        });
        rows.push(OpRow { name: "attention_head_ring", naive_ns, kernel_ns });
    }

    rows
}

struct EndToEnd {
    naive_ns: f64,
    kernel_ns: f64,
    batched4_ns_per_lane: f64,
}

impl EndToEnd {
    fn speedup(&self) -> f64 {
        self.naive_ns / self.kernel_ns
    }
}

fn bench_end_to_end(cfg: &ModelConfig, ticks: usize, kops: &'static KernelOps) -> Result<EndToEnd> {
    let params = ModelParams::synthetic(cfg, &mut Rng::new(0xBE9C6));
    let mut rng = Rng::new(0xBE9C7);
    let tok_elems = cfg.m_tokens * cfg.d_in;
    let tokens = Mat::from_vec(cfg.m_tokens, cfg.d_in, rng.normal_vec(tok_elems, 1.0));

    let mut naive = NaiveScalarDeepCoT::new(cfg.clone(), params.clone());
    let naive_ns = time_ns(ticks, || {
        let (logits, _) = naive.tick(black_box(&tokens)).expect("naive tick");
        black_box(logits[0]);
    });

    let mut ring = ScalarDeepCoT::new(cfg.clone(), params.clone());
    let kernel_ns = time_ns(ticks, || {
        let (logits, _) = ring.tick(black_box(&tokens)).expect("kernel tick");
        black_box(logits[0]);
    });

    let lanes = 4;
    let mut batched = BatchedScalarDeepCoT::with_lanes_ops(cfg.clone(), params, lanes, kops);
    let stacked = Mat::from_vec(
        lanes * cfg.m_tokens,
        cfg.d_in,
        rng.normal_vec(lanes * cfg.m_tokens * cfg.d_in, 1.0),
    );
    let batched_ns = time_ns(ticks, || {
        let step = batched.tick_all(black_box(&stacked)).expect("batched tick");
        black_box(step.logits.at(0, 0));
    });

    Ok(EndToEnd { naive_ns, kernel_ns, batched4_ns_per_lane: batched_ns / lanes as f64 })
}

fn main() -> Result<()> {
    let args = Cli::new("bench_kernels: nn::kernels suite vs the frozen naive baseline")
        .opt("d-model", "64", "model width")
        .opt("n-heads", "4", "attention heads")
        .opt("n-layers", "4", "encoder depth")
        .opt("window", "128", "continual window (mem_len = window - m)")
        .opt("ticks", "500", "end-to-end ticks per timing sample")
        .opt("iters", "2000", "per-op iterations per timing sample")
        .opt("json", "", "write results JSON to this path")
        .opt(
            "assert-speedup",
            "0",
            "fail unless end-to-end kernel speedup vs naive >= this (0 = off)",
        )
        .opt("kernel-dispatch", "auto", "kernel path: auto|scalar|avx2|neon")
        .opt(
            "assert-dispatch",
            "",
            "fail unless the resolved path is this (scalar|avx2|neon|simd; simd = any non-scalar)",
        )
        .flag("quick", "reduced iteration counts (CI smoke)")
        .parse()?;
    let cfg = ModelConfig::synthetic(
        args.get_usize("d-model")?,
        args.get_usize("n-heads")?,
        args.get_usize("n-layers")?,
        args.get_usize("window")?,
    );
    anyhow::ensure!(cfg.d_model % cfg.n_heads == 0, "d_model must split across heads");
    anyhow::ensure!(cfg.d_head() % 2 == 0, "RoPE needs an even d_head");
    let quick = args.has("quick");
    let ticks = if quick { 120 } else { args.get_usize("ticks")?.max(10) };
    let iters = if quick { 300 } else { args.get_usize("iters")?.max(10) };

    let choice: DispatchChoice = args.get("kernel-dispatch").parse()?;
    if choice != DispatchChoice::Auto {
        // export the force so every Auto-resolving constructor in the
        // end-to-end leg (ScalarDeepCoT and friends) follows the same
        // path this process measures
        std::env::set_var(DISPATCH_ENV, choice.to_string());
    }
    let kops = KernelOps::resolve(choice)?;
    let features = cpu_features();
    println!(
        "bench_kernels: d={} H={} L={} n={} (mem_len {}), {} ticks, {} per-op iters{}",
        cfg.d_model,
        cfg.n_heads,
        cfg.n_layers,
        cfg.window,
        cfg.mem_len(),
        ticks,
        iters,
        if quick { " [quick]" } else { "" },
    );
    println!("kernel dispatch: {} (cpu {features})", kops.path);

    let expect = args.get("assert-dispatch").to_string();
    if !expect.is_empty() {
        let ok = match expect.as_str() {
            "simd" => kops.path != DispatchPath::Scalar,
            other => kops.path.as_str() == other,
        };
        anyhow::ensure!(
            ok,
            "resolved kernel dispatch {} but --assert-dispatch {expect} (cpu {features})",
            kops.path
        );
        println!("dispatch guard passed: {} matches {expect}", kops.path);
    }

    let ops = bench_ops(&cfg, iters, kops);
    println!("{:>22} {:>12} {:>12} {:>9}", "op", "naive ns", "kernel ns", "speedup");
    for r in &ops {
        println!(
            "{:>22} {:>12.1} {:>12.1} {:>8.2}x",
            r.name,
            r.naive_ns,
            r.kernel_ns,
            r.speedup()
        );
    }

    let e2e = bench_end_to_end(&cfg, ticks, kops)?;
    println!(
        "end-to-end tick: naive {:.1}µs, kernel {:.1}µs, batched-4 {:.1}µs/lane — {:.2}x",
        e2e.naive_ns / 1e3,
        e2e.kernel_ns / 1e3,
        e2e.batched4_ns_per_lane / 1e3,
        e2e.speedup()
    );

    if !args.get("json").is_empty() {
        let doc = obj(vec![
            ("bench", Json::Str("kernels".into())),
            ("quick", Json::Bool(quick)),
            ("kernel_dispatch", Json::Str(kops.path.as_str().into())),
            ("cpu_features", Json::Str(features.clone())),
            (
                "geometry",
                obj(vec![
                    ("d_model", num(cfg.d_model as f64)),
                    ("n_heads", num(cfg.n_heads as f64)),
                    ("n_layers", num(cfg.n_layers as f64)),
                    ("window", num(cfg.window as f64)),
                    ("m_tokens", num(cfg.m_tokens as f64)),
                ]),
            ),
            (
                "ops",
                Json::Arr(
                    ops.iter()
                        .map(|r| {
                            obj(vec![
                                ("name", Json::Str(r.name.into())),
                                ("naive_ns", num(r.naive_ns)),
                                ("kernel_ns", num(r.kernel_ns)),
                                ("speedup", num(r.speedup())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "end_to_end",
                obj(vec![
                    ("naive_us_per_tick", num(e2e.naive_ns / 1e3)),
                    ("kernel_us_per_tick", num(e2e.kernel_ns / 1e3)),
                    ("batched4_us_per_lane", num(e2e.batched4_ns_per_lane / 1e3)),
                    ("speedup", num(e2e.speedup())),
                ]),
            ),
        ]);
        let path = args.get("json").to_string();
        std::fs::write(&path, doc.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }

    let threshold = args.get_f64("assert-speedup")?;
    if threshold > 0.0 {
        anyhow::ensure!(
            e2e.speedup() >= threshold,
            "end-to-end kernel tick speedup {:.2}x below the {threshold}x guard \
             (naive {:.1}µs vs kernel {:.1}µs)",
            e2e.speedup(),
            e2e.naive_ns / 1e3,
            e2e.kernel_ns / 1e3,
        );
        println!("speedup guard passed: {:.2}x >= {threshold}x", e2e.speedup());
    }
    Ok(())
}
