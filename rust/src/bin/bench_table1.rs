//! Regenerates Table I (OAD on synthetic THUMOS14) — DESIGN.md exp T1.
use anyhow::Result;
use deepcot::bench_harness::tables::{run_table1, BenchOpts};
use deepcot::runtime::Runtime;
use deepcot::util::cli::Cli;

fn main() -> Result<()> {
    let args = Cli::new("bench_table1: OAD table (paper Table I)")
        .opt("seed", "0", "workload seed")
        .opt("scale", "1.0", "corpus-size multiplier")
        .flag("quick", "reduced corpus + time budget")
        .parse()?;
    let mut opts = if args.has("quick") { BenchOpts::quick() } else { BenchOpts::default() };
    opts.seed = args.get_u64("seed")?;
    if !args.has("quick") {
        opts.scale = args.get_f64("scale")?;
    }
    let rt = Runtime::new(&deepcot::artifacts_dir())?;
    run_table1(&rt, &opts)?;
    Ok(())
}
