//! Durable stream-state storage — the persistence layer under stream
//! hibernation and `deepcot_serve` crash recovery.
//!
//! DeepCoT's continual attention makes the per-stream KV rings the
//! *entire* session state, so a stream can be checkpointed and moved
//! like data. This module owns the at-rest half of that story:
//!
//! - [`codec`] — a versioned, CRC-checksummed binary format for
//!   [`codec::StreamRecord`] (lane state + queued tokens + clocks).
//!   Corruption is always a typed [`StoreError`], never a panic.
//! - [`StateStore`] — the blob-store trait the coordinator hibernates
//!   through (`put`/`get`/`delete`/`list`/`sync`), keyed by stream id.
//! - [`MemStore`] — trivial in-memory impl for tests and for
//!   hibernation without durability (`EngineConfig::hibernate` with no
//!   `state_dir`).
//! - [`disk`] — a std-only single-file log-structured store with
//!   torn-tail recovery and background-free compaction; this is what
//!   `deepcot_serve --state-dir` runs on.
//!
//! The coordinator-side policy (when to spill, how to restore, snapshot
//! cadence) lives in `crate::coordinator::hibernate`; this module knows
//! nothing about engines, only bytes.

use std::collections::BTreeMap;
use std::fmt;

pub mod codec;
pub mod disk;

/// Typed storage failure. Corruption and I/O problems are reported, not
/// panicked, so a damaged state file can never take the server down.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// Bytes failed structural validation (bad magic/version/length,
    /// checksum mismatch, truncated or trailing data).
    Corrupt(String),
    /// The underlying I/O layer failed (open/read/write/sync/rename).
    Io(String),
}

impl StoreError {
    pub(crate) fn corrupt<S: Into<String>>(msg: S) -> StoreError {
        StoreError::Corrupt(msg.into())
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Corrupt(m) => write!(f, "corrupt state: {m}"),
            StoreError::Io(m) => write!(f, "state store i/o: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e.to_string())
    }
}

/// Run `op` up to `attempts` times, sleeping `base_delay` and doubling
/// it between tries (exponential backoff). Returns the final result
/// plus how many retries were spent — the building block of degraded
/// store mode, where a transient I/O failure must not abort a snapshot
/// cycle. `attempts` is clamped to at least 1.
pub fn with_retries<T>(
    attempts: u32,
    base_delay: std::time::Duration,
    mut op: impl FnMut() -> Result<T, StoreError>,
) -> (Result<T, StoreError>, u32) {
    let attempts = attempts.max(1);
    let mut delay = base_delay;
    let mut retries = 0u32;
    loop {
        match op() {
            Ok(v) => return (Ok(v), retries),
            Err(e) => {
                if retries + 1 >= attempts {
                    return (Err(e), retries);
                }
                retries += 1;
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                delay = delay.saturating_mul(2);
            }
        }
    }
}

/// A durable (or not) blob store keyed by stream id.
///
/// Implementations must make `put` replace any previous blob for the
/// same stream, and `list` return each live stream id exactly once in
/// ascending order. Methods take `&mut self` because disk-backed
/// implementations seek; the coordinator serializes access behind its
/// hibernation pool lock.
pub trait StateStore: Send {
    /// Write (or replace) the blob for `stream`.
    fn put(&mut self, stream: u64, blob: &[u8]) -> Result<(), StoreError>;
    /// Read the blob for `stream`, `None` if absent.
    fn get(&mut self, stream: u64) -> Result<Option<Vec<u8>>, StoreError>;
    /// Remove `stream`; returns whether it was present.
    fn delete(&mut self, stream: u64) -> Result<bool, StoreError>;
    /// All live stream ids, ascending.
    fn list(&mut self) -> Result<Vec<u64>, StoreError>;
    /// Flush everything to durable media (no-op for volatile stores).
    fn sync(&mut self) -> Result<(), StoreError>;
}

/// Volatile in-memory [`StateStore`]: hibernation without durability.
#[derive(Debug, Default)]
pub struct MemStore {
    blobs: BTreeMap<u64, Vec<u8>>,
}

impl MemStore {
    /// Fresh empty store.
    pub fn new() -> MemStore {
        MemStore::default()
    }

    /// Number of stored blobs.
    pub fn len(&self) -> usize {
        self.blobs.len()
    }

    /// Whether the store holds no blobs.
    pub fn is_empty(&self) -> bool {
        self.blobs.is_empty()
    }
}

impl StateStore for MemStore {
    fn put(&mut self, stream: u64, blob: &[u8]) -> Result<(), StoreError> {
        self.blobs.insert(stream, blob.to_vec());
        Ok(())
    }

    fn get(&mut self, stream: u64) -> Result<Option<Vec<u8>>, StoreError> {
        Ok(self.blobs.get(&stream).cloned())
    }

    fn delete(&mut self, stream: u64) -> Result<bool, StoreError> {
        Ok(self.blobs.remove(&stream).is_some())
    }

    fn list(&mut self) -> Result<Vec<u64>, StoreError> {
        Ok(self.blobs.keys().copied().collect())
    }

    fn sync(&mut self) -> Result<(), StoreError> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_retries_counts_and_gives_up() {
        use std::time::Duration;
        // succeeds on the 3rd attempt: 2 retries spent
        let mut calls = 0;
        let (res, retries) = with_retries(5, Duration::ZERO, || {
            calls += 1;
            if calls < 3 {
                Err(StoreError::Io("flaky".into()))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(res.unwrap(), 3);
        assert_eq!(retries, 2);
        // a persistent failure exhausts the budget and reports it typed
        let mut calls = 0;
        let (res, retries) = with_retries::<()>(3, Duration::ZERO, || {
            calls += 1;
            Err(StoreError::Io("down".into()))
        });
        assert!(matches!(res, Err(StoreError::Io(_))));
        assert_eq!(retries, 2);
        assert_eq!(calls, 3);
        // attempts=0 still runs the op once
        let (res, retries) = with_retries(0, Duration::ZERO, || Ok(7));
        assert_eq!(res.unwrap(), 7);
        assert_eq!(retries, 0);
    }

    #[test]
    fn memstore_put_get_delete_list() {
        let mut s = MemStore::new();
        assert_eq!(s.get(7).unwrap(), None);
        s.put(7, b"seven").unwrap();
        s.put(3, b"three").unwrap();
        s.put(7, b"SEVEN").unwrap();
        assert_eq!(s.get(7).unwrap().as_deref(), Some(&b"SEVEN"[..]));
        assert_eq!(s.list().unwrap(), vec![3, 7]);
        assert!(s.delete(3).unwrap());
        assert!(!s.delete(3).unwrap());
        assert_eq!(s.list().unwrap(), vec![7]);
        s.sync().unwrap();
    }
}
