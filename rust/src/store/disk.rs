//! Single-file log-structured [`StateStore`], std-only.
//!
//! File layout:
//!
//! ```text
//!   header   [magic u32 = "DCLG"][version u16 = 1][reserved u16 = 0]
//!   entry*   [len u32][kind u8][stream u64][payload…][crc32 u32]
//! ```
//!
//! `len` counts every byte after the length field itself
//! (`1 + 8 + payload + 4`). `kind` is `1` for a put and `2` for a
//! tombstone (empty payload). The CRC covers `kind..payload`, so a torn
//! append — the normal state of the file after a SIGKILL — is detected
//! and truncated away on the next open; everything before the tear is
//! served as usual. Writes append; an in-memory index maps stream id to
//! the live payload's file offset, and when dead bytes outweigh live
//! ones the log is compacted by rewriting live entries to a sibling
//! temp file and atomically renaming it over the log.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::codec::crc32;
use super::{StateStore, StoreError};

/// Log file magic: the bytes `DCLG` read as a little-endian `u32`.
pub const FILE_MAGIC: u32 = 0x474C_4344;
/// Log format version.
pub const FILE_VERSION: u16 = 1;
const HEADER_LEN: u64 = 8;
/// Fixed per-entry overhead after the length field: kind + stream + crc.
const ENTRY_OVERHEAD: u32 = 1 + 8 + 4;
/// Upper bound on a single entry body; counts beyond this are treated as
/// corruption rather than honored with a giant allocation.
const MAX_ENTRY: u32 = 1 << 30;

const KIND_PUT: u8 = 1;
const KIND_DEL: u8 = 2;

/// Compaction triggers once at least this many dead bytes accumulate…
const COMPACT_MIN_DEAD: u64 = 64 * 1024;
/// …and dead bytes outweigh live ones by this factor.
const COMPACT_DEAD_FACTOR: u64 = 1;

/// Single-file log-structured blob store. See the module docs for the
/// format; see [`DiskStore::open`] for recovery semantics.
pub struct DiskStore {
    path: PathBuf,
    file: File,
    /// stream id → (payload offset, payload length) of the live entry.
    index: BTreeMap<u64, (u64, u32)>,
    /// Logical end of the log (append point).
    tail: u64,
    /// Bytes belonging to superseded or deleted entries (incl. headers).
    dead_bytes: u64,
    /// Bytes belonging to live entries (incl. headers).
    live_bytes: u64,
    wbuf: Vec<u8>,
}

impl DiskStore {
    /// Open (or create) the log at `path`, scanning it to rebuild the
    /// index. A torn or corrupt tail — e.g. after SIGKILL mid-append —
    /// is truncated off; every entry before the tear survives. A corrupt
    /// *header* is a hard [`StoreError::Corrupt`]: that file was never
    /// ours or is damaged beyond the append region, and silently wiping
    /// it would destroy user state.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<DiskStore, StoreError> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)?;
        let file_len = file.seek(SeekFrom::End(0))?;
        let mut store = DiskStore {
            path,
            file,
            index: BTreeMap::new(),
            tail: HEADER_LEN,
            dead_bytes: 0,
            live_bytes: 0,
            wbuf: Vec::new(),
        };
        if file_len == 0 {
            store.write_header()?;
            return Ok(store);
        }
        if file_len < HEADER_LEN {
            return Err(StoreError::corrupt(format!(
                "state log shorter than its {HEADER_LEN}-byte header ({file_len} bytes)"
            )));
        }
        store.file.seek(SeekFrom::Start(0))?;
        let mut header = [0u8; HEADER_LEN as usize];
        store.file.read_exact(&mut header)?;
        let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
        let version = u16::from_le_bytes([header[4], header[5]]);
        if magic != FILE_MAGIC {
            return Err(StoreError::corrupt(format!(
                "bad state-log magic {magic:#010x}, expected {FILE_MAGIC:#010x}"
            )));
        }
        if version != FILE_VERSION {
            return Err(StoreError::corrupt(format!(
                "unsupported state-log version {version} (this build reads {FILE_VERSION})"
            )));
        }
        store.scan(file_len)?;
        Ok(store)
    }

    /// Path this store persists to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of live blobs.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// Whether the store holds no live blobs.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// (live, dead) byte accounting for the current log.
    pub fn byte_usage(&self) -> (u64, u64) {
        (self.live_bytes, self.dead_bytes)
    }

    fn write_header(&mut self) -> Result<(), StoreError> {
        let mut h = [0u8; HEADER_LEN as usize];
        h[0..4].copy_from_slice(&FILE_MAGIC.to_le_bytes());
        h[4..6].copy_from_slice(&FILE_VERSION.to_le_bytes());
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&h)?;
        self.tail = HEADER_LEN;
        Ok(())
    }

    /// Replay the log from just past the header, rebuilding the index.
    /// Stops at the first structurally invalid or checksum-failing entry
    /// and truncates the file there (torn-append recovery).
    fn scan(&mut self, file_len: u64) -> Result<(), StoreError> {
        let mut buf = Vec::new();
        self.file.seek(SeekFrom::Start(HEADER_LEN))?;
        buf.resize((file_len - HEADER_LEN) as usize, 0);
        self.file.read_exact(&mut buf)?;
        let mut at = 0usize;
        let mut valid_end = HEADER_LEN;
        while at < buf.len() {
            let Some(entry) = parse_entry(&buf[at..]) else { break };
            let (stream, kind, payload_off, payload_len, entry_len) = entry;
            let abs_payload = HEADER_LEN + (at + payload_off) as u64;
            match kind {
                KIND_PUT => {
                    if let Some((_, old_len)) = self.index.insert(stream, (abs_payload, payload_len))
                    {
                        let old_entry = 4 + ENTRY_OVERHEAD as u64 + old_len as u64;
                        self.dead_bytes += old_entry;
                        self.live_bytes -= old_entry;
                    }
                    self.live_bytes += entry_len as u64;
                }
                KIND_DEL => {
                    if let Some((_, old_len)) = self.index.remove(&stream) {
                        let old_entry = 4 + ENTRY_OVERHEAD as u64 + old_len as u64;
                        self.dead_bytes += old_entry;
                        self.live_bytes -= old_entry;
                    }
                    // The tombstone itself is immediately dead weight.
                    self.dead_bytes += entry_len as u64;
                }
                _ => break,
            }
            at += entry_len;
            valid_end = HEADER_LEN + at as u64;
        }
        self.tail = valid_end;
        if valid_end < file_len {
            // Torn tail: cut it off so future appends start clean.
            self.file.set_len(valid_end)?;
        }
        Ok(())
    }

    fn append_entry(&mut self, kind: u8, stream: u64, payload: &[u8]) -> Result<u64, StoreError> {
        let len = ENTRY_OVERHEAD + payload.len() as u32;
        if len > MAX_ENTRY {
            return Err(StoreError::corrupt(format!(
                "refusing to write {}-byte entry (cap {MAX_ENTRY})",
                payload.len()
            )));
        }
        let mut wbuf = std::mem::take(&mut self.wbuf);
        wbuf.clear();
        wbuf.extend_from_slice(&len.to_le_bytes());
        wbuf.push(kind);
        wbuf.extend_from_slice(&stream.to_le_bytes());
        wbuf.extend_from_slice(payload);
        let crc = crc32(&wbuf[4..]);
        wbuf.extend_from_slice(&crc.to_le_bytes());
        self.file.seek(SeekFrom::Start(self.tail))?;
        let res = self.file.write_all(&wbuf);
        let written = wbuf.len() as u64;
        self.wbuf = wbuf;
        res?;
        let payload_abs = self.tail + 4 + 1 + 8;
        self.tail += written;
        Ok(payload_abs)
    }

    fn retire(&mut self, old_payload_len: u32) {
        let old_entry = 4 + ENTRY_OVERHEAD as u64 + old_payload_len as u64;
        self.dead_bytes += old_entry;
        self.live_bytes -= old_entry;
    }

    fn maybe_compact(&mut self) -> Result<(), StoreError> {
        if self.dead_bytes >= COMPACT_MIN_DEAD && self.dead_bytes > self.live_bytes * COMPACT_DEAD_FACTOR
        {
            self.compact()?;
        }
        Ok(())
    }

    /// Rewrite only the live entries to a temp file and atomically
    /// rename it over the log. Callable any time; also runs
    /// automatically when dead bytes outweigh live ones.
    pub fn compact(&mut self) -> Result<(), StoreError> {
        let tmp_path = self.path.with_extension("compact-tmp");
        let mut tmp = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp_path)?;
        let mut h = [0u8; HEADER_LEN as usize];
        h[0..4].copy_from_slice(&FILE_MAGIC.to_le_bytes());
        h[4..6].copy_from_slice(&FILE_VERSION.to_le_bytes());
        tmp.write_all(&h)?;

        let ids: Vec<u64> = self.index.keys().copied().collect();
        let mut new_index = BTreeMap::new();
        let mut new_tail = HEADER_LEN;
        let mut live = 0u64;
        let mut payload = Vec::new();
        let mut entry = Vec::new();
        for stream in ids {
            let (off, plen) = self.index[&stream];
            payload.resize(plen as usize, 0);
            self.file.seek(SeekFrom::Start(off))?;
            self.file.read_exact(&mut payload)?;
            let len = ENTRY_OVERHEAD + plen;
            entry.clear();
            entry.extend_from_slice(&len.to_le_bytes());
            entry.push(KIND_PUT);
            entry.extend_from_slice(&stream.to_le_bytes());
            entry.extend_from_slice(&payload);
            let crc = crc32(&entry[4..]);
            entry.extend_from_slice(&crc.to_le_bytes());
            tmp.write_all(&entry)?;
            new_index.insert(stream, (new_tail + 4 + 1 + 8, plen));
            new_tail += entry.len() as u64;
            live += entry.len() as u64;
        }
        tmp.sync_all()?;
        std::fs::rename(&tmp_path, &self.path)?;
        // the rename only becomes durable once the parent directory's
        // entry for it is on disk — without this fsync a crash right
        // after compaction can resurrect the old (pre-compaction) log
        // on some filesystems
        let parent = match self.path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        File::open(parent)?.sync_all()?;
        self.file = tmp;
        self.index = new_index;
        self.tail = new_tail;
        self.live_bytes = live;
        self.dead_bytes = 0;
        Ok(())
    }
}

/// Try to parse one entry at the head of `buf`. Returns
/// `(stream, kind, payload offset within buf, payload len, total entry len)`
/// or `None` if the bytes are truncated/corrupt (scan stops there).
#[allow(clippy::type_complexity)]
fn parse_entry(buf: &[u8]) -> Option<(u64, u8, usize, u32, usize)> {
    if buf.len() < 4 {
        return None;
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if len < ENTRY_OVERHEAD || len > MAX_ENTRY {
        return None;
    }
    let total = 4 + len as usize;
    if buf.len() < total {
        return None;
    }
    let body = &buf[4..total];
    let (content, crc_bytes) = body.split_at(body.len() - 4);
    let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    if crc32(content) != stored {
        return None;
    }
    let kind = content[0];
    if kind != KIND_PUT && kind != KIND_DEL {
        return None;
    }
    let stream = u64::from_le_bytes([
        content[1], content[2], content[3], content[4], content[5], content[6], content[7],
        content[8],
    ]);
    let payload_len = len - ENTRY_OVERHEAD;
    Some((stream, kind, 4 + 1 + 8, payload_len, total))
}

impl StateStore for DiskStore {
    fn put(&mut self, stream: u64, blob: &[u8]) -> Result<(), StoreError> {
        let payload_abs = self.append_entry(KIND_PUT, stream, blob)?;
        if let Some((_, old_len)) = self.index.insert(stream, (payload_abs, blob.len() as u32)) {
            self.retire(old_len);
        }
        self.live_bytes += 4 + ENTRY_OVERHEAD as u64 + blob.len() as u64;
        self.maybe_compact()
    }

    fn get(&mut self, stream: u64) -> Result<Option<Vec<u8>>, StoreError> {
        let Some(&(off, len)) = self.index.get(&stream) else {
            return Ok(None);
        };
        let mut blob = vec![0u8; len as usize];
        self.file.seek(SeekFrom::Start(off))?;
        self.file.read_exact(&mut blob)?;
        Ok(Some(blob))
    }

    fn delete(&mut self, stream: u64) -> Result<bool, StoreError> {
        let Some((_, old_len)) = self.index.remove(&stream) else {
            return Ok(false);
        };
        self.retire(old_len);
        self.append_entry(KIND_DEL, stream, &[])?;
        self.dead_bytes += 4 + ENTRY_OVERHEAD as u64;
        self.maybe_compact()?;
        Ok(true)
    }

    fn list(&mut self) -> Result<Vec<u64>, StoreError> {
        Ok(self.index.keys().copied().collect())
    }

    fn sync(&mut self) -> Result<(), StoreError> {
        self.file.sync_all()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("deepcot-diskstore-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn put_get_delete_survive_reopen() {
        let path = tmp_path("reopen");
        {
            let mut s = DiskStore::open(&path).unwrap();
            s.put(1, b"one").unwrap();
            s.put(2, b"two").unwrap();
            s.put(1, b"ONE").unwrap();
            s.delete(2).unwrap();
            s.sync().unwrap();
        }
        let mut s = DiskStore::open(&path).unwrap();
        assert_eq!(s.list().unwrap(), vec![1]);
        assert_eq!(s.get(1).unwrap().as_deref(), Some(&b"ONE"[..]));
        assert_eq!(s.get(2).unwrap(), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let path = tmp_path("torn");
        {
            let mut s = DiskStore::open(&path).unwrap();
            s.put(1, b"alpha").unwrap();
            s.put(2, b"beta").unwrap();
            s.sync().unwrap();
        }
        // Tear the last entry mid-payload, as a SIGKILL mid-append would.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let mut s = DiskStore::open(&path).unwrap();
        assert_eq!(s.list().unwrap(), vec![1]);
        assert_eq!(s.get(1).unwrap().as_deref(), Some(&b"alpha"[..]));
        // The store still accepts writes after recovery.
        s.put(3, b"gamma").unwrap();
        assert_eq!(s.list().unwrap(), vec![1, 3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_header_is_a_typed_error() {
        let path = tmp_path("header");
        std::fs::write(&path, b"definitely not a deepcot log").unwrap();
        match DiskStore::open(&path) {
            Err(StoreError::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_drops_dead_bytes_and_preserves_blobs() {
        let path = tmp_path("compact");
        let mut s = DiskStore::open(&path).unwrap();
        let blob = vec![0xAB; 512];
        for _round in 0..300u64 {
            for id in 0..8u64 {
                s.put(id, &blob).unwrap();
            }
        }
        // Auto-compaction must have kept dead weight bounded.
        let (live, dead) = s.byte_usage();
        assert!(dead <= COMPACT_MIN_DEAD.max(live), "dead {dead} live {live}");
        s.compact().unwrap();
        let (_, dead) = s.byte_usage();
        assert_eq!(dead, 0);
        for id in 0..8u64 {
            assert_eq!(s.get(id).unwrap().as_deref(), Some(&blob[..]));
        }
        // And the compacted file reopens cleanly.
        drop(s);
        let mut s = DiskStore::open(&path).unwrap();
        assert_eq!(s.list().unwrap().len(), 8);
        assert_eq!(s.get(3).unwrap().as_deref(), Some(&blob[..]));
        let _ = std::fs::remove_file(&path);
    }
}
