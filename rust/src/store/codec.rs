//! Versioned, checksummed binary codec for hibernated stream records.
//!
//! A [`StreamRecord`] is everything the coordinator needs to transparently
//! resurrect a stream into a backend lane: the portable
//! `StreamState` payload (KV rings + ring write heads + `pos` clock), the
//! stream's tick ordinal, and any tokens that were still queued in the
//! batcher when the stream was spilled.
//!
//! Wire layout (all little-endian, `f32` stored as raw bit patterns so
//! NaN payloads and signed zeros round-trip bit-exactly):
//!
//! ```text
//!   offset  size  field
//!        0     4  magic      0x31_54_53_44 ("DST1")
//!        4     2  version    currently 1
//!        6     2  flags      must be 0 (reserved)
//!        8     8  stream id  u64
//!       16     8  ticks      u64 (delivered tick ordinal)
//!       24     8  pos        i64 (continual position clock, widened)
//!       32     4  n_heads    u32
//!       36     4  n_kv       u32
//!       40     4  n_queued   u32
//!       44     …  heads      n_heads × u32
//!        …     …  kv rings   n_kv × u32 (f32 bits)
//!        …     …  queued     n_queued × (u32 len + len × u32 f32 bits)
//!     tail     4  crc32      IEEE CRC-32 over every preceding byte
//! ```
//!
//! Decoding is hardened: every length is bounds-checked against the
//! remaining input *before* any allocation, the checksum is verified
//! before the payload is trusted, and any structural violation returns a
//! typed [`StoreError::Corrupt`] — never a panic, never a huge
//! speculative allocation driven by a corrupt count field.

use super::StoreError;

/// Magic prefix: the bytes `DST1` read as a little-endian `u32`.
pub const MAGIC: u32 = 0x3154_5344;
/// Current (and only) codec version.
pub const VERSION: u16 = 1;
/// Fixed header length in bytes (everything before the variable arrays).
pub const HEADER_LEN: usize = 44;
/// Smallest well-formed record: header + trailing CRC, no array elements.
pub const MIN_LEN: usize = HEADER_LEN + 4;

/// A hibernated stream, fully described: identity, clocks, backend lane
/// state, and tokens still queued for future ticks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamRecord {
    /// Engine-assigned stream id.
    pub stream: u64,
    /// Delivered tick ordinal (the next tick this stream receives is
    /// `ticks + 1`, so resumed streams keep a continuous tick series).
    pub ticks: u64,
    /// Continual position clock (RoPE phase) at hibernation time.
    pub pos: i32,
    /// KV ring write heads, one per (layer, head, K/V) ring.
    pub write_heads: Vec<usize>,
    /// Flattened KV ring contents, `f32` preserved bit-exactly.
    pub kv_rings: Vec<f32>,
    /// Batcher-queued token vectors (FIFO order, oldest first) that had
    /// not ticked when the stream was spilled.
    pub queued: Vec<Vec<f32>>,
}

impl StreamRecord {
    /// Exact encoded size of this record in bytes.
    pub fn encoded_len(&self) -> usize {
        MIN_LEN
            + 4 * self.write_heads.len()
            + 4 * self.kv_rings.len()
            + self.queued.iter().map(|q| 4 + 4 * q.len()).sum::<usize>()
    }

    /// Encode into `out`, clearing it first. Reuses `out`'s capacity, so
    /// repeated encodes through a warm buffer are allocation-free.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.encoded_len());
        put_u32(out, MAGIC);
        put_u16(out, VERSION);
        put_u16(out, 0); // flags
        put_u64(out, self.stream);
        put_u64(out, self.ticks);
        put_u64(out, self.pos as i64 as u64);
        put_u32(out, self.write_heads.len() as u32);
        put_u32(out, self.kv_rings.len() as u32);
        put_u32(out, self.queued.len() as u32);
        for &h in &self.write_heads {
            debug_assert!(h <= u32::MAX as usize, "ring head exceeds u32");
            put_u32(out, h as u32);
        }
        for &v in &self.kv_rings {
            put_u32(out, v.to_bits());
        }
        for q in &self.queued {
            put_u32(out, q.len() as u32);
            for &v in q {
                put_u32(out, v.to_bits());
            }
        }
        let crc = crc32(out);
        put_u32(out, crc);
    }

    /// Encode into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Decode a record from `buf`.
    pub fn decode(buf: &[u8]) -> Result<StreamRecord, StoreError> {
        let mut rec = StreamRecord::default();
        rec.decode_into(buf)?;
        Ok(rec)
    }

    /// Decode `buf` into `self`, reusing the existing vector capacities.
    /// When the shapes match a previous decode this performs no
    /// allocation (the hibernation snapshot hot path relies on this).
    pub fn decode_into(&mut self, buf: &[u8]) -> Result<(), StoreError> {
        if buf.len() < MIN_LEN {
            return Err(StoreError::corrupt(format!(
                "record too short: {} bytes, need at least {MIN_LEN}",
                buf.len()
            )));
        }
        let (body, tail) = buf.split_at(buf.len() - 4);
        let stored_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let actual_crc = crc32(body);
        if stored_crc != actual_crc {
            return Err(StoreError::corrupt(format!(
                "checksum mismatch: stored {stored_crc:#010x}, computed {actual_crc:#010x}"
            )));
        }
        let mut cur = Cursor::new(body);
        let magic = cur.u32()?;
        if magic != MAGIC {
            return Err(StoreError::corrupt(format!(
                "bad magic {magic:#010x}, expected {MAGIC:#010x}"
            )));
        }
        let version = cur.u16()?;
        if version != VERSION {
            return Err(StoreError::corrupt(format!(
                "unsupported record version {version} (this build reads {VERSION})"
            )));
        }
        let flags = cur.u16()?;
        if flags != 0 {
            return Err(StoreError::corrupt(format!("reserved flags set: {flags:#06x}")));
        }
        self.stream = cur.u64()?;
        self.ticks = cur.u64()?;
        let pos = cur.u64()? as i64;
        self.pos = i32::try_from(pos)
            .map_err(|_| StoreError::corrupt(format!("pos clock {pos} outside i32 range")))?;
        let n_heads = cur.u32()? as usize;
        let n_kv = cur.u32()? as usize;
        let n_queued = cur.u32()? as usize;

        // Validate the fixed-width arrays against the remaining bytes
        // BEFORE allocating anything: a corrupt count must not drive a
        // multi-gigabyte reserve.
        let fixed = n_heads
            .checked_mul(4)
            .and_then(|a| n_kv.checked_mul(4).and_then(|b| a.checked_add(b)))
            .ok_or_else(|| StoreError::corrupt("array counts overflow".to_string()))?;
        if fixed > cur.remaining() {
            return Err(StoreError::corrupt(format!(
                "array counts ({n_heads} heads, {n_kv} kv) exceed {} remaining bytes",
                cur.remaining()
            )));
        }
        // Each queued vector costs at least its 4-byte length prefix.
        if n_queued.checked_mul(4).map(|q| fixed + q > cur.remaining()).unwrap_or(true) {
            return Err(StoreError::corrupt(format!(
                "queued count {n_queued} exceeds {} remaining bytes",
                cur.remaining()
            )));
        }

        self.write_heads.clear();
        self.write_heads.reserve(n_heads);
        for _ in 0..n_heads {
            self.write_heads.push(cur.u32()? as usize);
        }
        self.kv_rings.clear();
        self.kv_rings.reserve(n_kv);
        for _ in 0..n_kv {
            self.kv_rings.push(f32::from_bits(cur.u32()?));
        }
        // Reuse the outer queued vec and as many inner vecs as survive.
        self.queued.truncate(n_queued);
        for i in 0..n_queued {
            let len = cur.u32()? as usize;
            if len.checked_mul(4).map(|b| b > cur.remaining()).unwrap_or(true) {
                return Err(StoreError::corrupt(format!(
                    "queued[{i}] length {len} exceeds {} remaining bytes",
                    cur.remaining()
                )));
            }
            if i == self.queued.len() {
                self.queued.push(Vec::with_capacity(len));
            }
            let q = &mut self.queued[i];
            q.clear();
            q.reserve(len);
            for _ in 0..len {
                q.push(f32::from_bits(cur.u32()?));
            }
        }
        if cur.remaining() != 0 {
            return Err(StoreError::corrupt(format!(
                "{} trailing bytes after record payload",
                cur.remaining()
            )));
        }
        Ok(())
    }
}

/// Bounds-checked little-endian reader over a byte slice.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, at: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if self.remaining() < n {
            return Err(StoreError::corrupt(format!(
                "truncated record: wanted {n} bytes, {} remain",
                self.remaining()
            )));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, StoreError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, StoreError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, StoreError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

const CRC_TABLE: [u32; 256] = make_crc_table();

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE CRC-32 (the zlib/zip polynomial) over `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StreamRecord {
        StreamRecord {
            stream: 42,
            ticks: 7,
            pos: -3,
            write_heads: vec![0, 5, 2, 5],
            kv_rings: vec![1.5, -0.0, f32::NAN, f32::INFINITY, 3.25e-12],
            queued: vec![vec![1.0, 2.0], vec![], vec![-4.5]],
        }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let rec = sample();
        let blob = rec.encode();
        assert_eq!(blob.len(), rec.encoded_len());
        let back = StreamRecord::decode(&blob).unwrap();
        assert_eq!(back.stream, rec.stream);
        assert_eq!(back.ticks, rec.ticks);
        assert_eq!(back.pos, rec.pos);
        assert_eq!(back.write_heads, rec.write_heads);
        assert_eq!(back.kv_rings.len(), rec.kv_rings.len());
        for (a, b) in back.kv_rings.iter().zip(&rec.kv_rings) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.queued.len(), rec.queued.len());
        for (a, b) in back.queued.iter().zip(&rec.queued) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn decode_into_reuses_capacity() {
        let rec = sample();
        let blob = rec.encode();
        let mut target = StreamRecord::decode(&blob).unwrap();
        let heads_ptr = target.write_heads.as_ptr();
        let kv_ptr = target.kv_rings.as_ptr();
        target.decode_into(&blob).unwrap();
        assert_eq!(target.write_heads.as_ptr(), heads_ptr);
        assert_eq!(target.kv_rings.as_ptr(), kv_ptr);
        assert_eq!(target, StreamRecord::decode(&blob).unwrap());
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let blob = sample().encode();
        for cut in 0..blob.len() {
            let err = StreamRecord::decode(&blob[..cut]);
            assert!(err.is_err(), "decode of {cut}-byte prefix must fail");
        }
    }

    #[test]
    fn bitflips_are_detected() {
        let blob = sample().encode();
        for byte in 0..blob.len() {
            let mut bad = blob.clone();
            bad[byte] ^= 0x01;
            assert!(
                StreamRecord::decode(&bad).is_err(),
                "single bitflip at byte {byte} must be detected"
            );
        }
    }

    #[test]
    fn corrupt_counts_do_not_allocate() {
        // Forge a record whose kv count claims 1 billion entries but keep
        // a valid CRC: the decoder must reject it on bounds, not reserve.
        let mut rec = sample();
        rec.queued.clear();
        let mut blob = rec.encode();
        let kv_count_off = 36;
        blob[kv_count_off..kv_count_off + 4].copy_from_slice(&1_000_000_000u32.to_le_bytes());
        let body_len = blob.len() - 4;
        let crc = crc32(&blob[..body_len]);
        blob[body_len..].copy_from_slice(&crc.to_le_bytes());
        let err = StreamRecord::decode(&blob).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt(_)), "got {err:?}");
    }

    #[test]
    fn wrong_magic_version_flags_rejected() {
        let good = sample().encode();
        for (off, val) in [(0usize, 0xDEADBEEFu32), (4, 99), (6, 1 << 16 | 1)] {
            let mut bad = good.clone();
            // Patch the field then re-seal the CRC so only the field is wrong.
            let bytes = (val as u32).to_le_bytes();
            let width = if off == 0 { 4 } else { 2 };
            bad[off..off + width].copy_from_slice(&bytes[..width]);
            let body_len = bad.len() - 4;
            let crc = crc32(&bad[..body_len]);
            bad[body_len..].copy_from_slice(&crc.to_le_bytes());
            assert!(StreamRecord::decode(&bad).is_err(), "field at {off} must be checked");
        }
    }
}
