//! The pre-refactor continual stepper, preserved verbatim as a
//! benchmark baseline and refactor oracle.
//!
//! [`NaiveScalarDeepCoT`] is what `ScalarDeepCoT` looked like before
//! the ring-buffer refactor: per tick per layer per head it (a)
//! materializes a fresh `[memory; new]` concatenation for attention,
//! (b) rolls the flat K/V memory with `copy_within`, and (c) clones the
//! model config — allocator traffic and O(mem_len·d_head) shuffles that
//! polluted the step-latency numbers the paper's runtime comparisons
//! rest on. `bench_fig1`'s scalar sweep reports it side by side with
//! the ring-buffer engine, `bench_kernels` measures the `nn::kernels`
//! suite's per-op and end-to-end speedups against it, and
//! `tests/scalar_continual.rs` / `tests/kernels_equiv.rs` pin the two
//! to equivalent numerics (1e-4 relative — the kernel suite's split
//! accumulators legitimately reassociate f32 sums).

use anyhow::Result;

use crate::manifest::ModelConfig;
use crate::nn::encoder::{attn_weights, ffn, head_slice, project, residual};
use crate::nn::params::ModelParams;
use crate::nn::rope::apply_rope_inplace;
use crate::nn::tensor::Mat;

/// Pre-refactor continual stepper, one lane. Do not optimize: its
/// allocation and memory-roll behavior IS the baseline being measured.
pub struct NaiveScalarDeepCoT {
    pub cfg: ModelConfig,
    p: ModelParams,
    /// kmem[layer][head]: (mem_len x dh), rolled flat every tick.
    kmem: Vec<Vec<Mat>>,
    vmem: Vec<Vec<Mat>>,
    pub pos: i32,
}

impl NaiveScalarDeepCoT {
    pub fn new(cfg: ModelConfig, p: ModelParams) -> Self {
        let (l, h, mlen, dh) = (cfg.n_layers, cfg.n_heads, cfg.mem_len(), cfg.d_head());
        let zmem = || vec![vec![Mat::zeros(mlen, dh); h]; l];
        Self { cfg, p, kmem: zmem(), vmem: zmem(), pos: 0 }
    }

    pub fn reset(&mut self) {
        for lm in self.kmem.iter_mut().chain(self.vmem.iter_mut()) {
            for m in lm {
                m.data.iter_mut().for_each(|v| *v = 0.0);
            }
        }
        self.pos = 0;
    }

    /// One tick: `tokens` (m x d_in) -> (logits, out (m x d)).
    pub fn tick(&mut self, tokens: &Mat) -> Result<(Vec<f32>, Mat)> {
        // per-tick config clone: part of the preserved pre-refactor
        // allocator behavior (the refactored engine borrows instead)
        let cfg = self.cfg.clone();
        let (m, h, dh, mlen) = (cfg.m_tokens, cfg.n_heads, cfg.d_head(), cfg.mem_len());
        anyhow::ensure!(tokens.rows == m && tokens.cols == cfg.d_in);
        let mut x = project(tokens, &self.p.w_in, &self.p.b_in);
        for (li, lp) in self.p.layers.iter().enumerate() {
            let mut q = project(&x, &lp.wq, &lp.bq);
            let mut k = project(&x, &lp.wk, &lp.bk);
            let v = project(&x, &lp.wv, &lp.bv);
            if cfg.pos == "rope" {
                for t in 0..m {
                    for hh in 0..h {
                        let pp = self.pos + t as i32;
                        apply_rope_inplace(&mut q.row_mut(t)[hh * dh..(hh + 1) * dh], pp);
                        apply_rope_inplace(&mut k.row_mut(t)[hh * dh..(hh + 1) * dh], pp);
                    }
                }
            }
            let mut attn_out = Mat::zeros(m, cfg.d_model);
            for hh in 0..h {
                // kcat = [memory; new keys]  (n x dh)
                let mut kcat = Mat::zeros(mlen + m, dh);
                let mut vcat = Mat::zeros(mlen + m, dh);
                for j in 0..mlen {
                    kcat.row_mut(j).copy_from_slice(self.kmem[li][hh].row(j));
                    vcat.row_mut(j).copy_from_slice(self.vmem[li][hh].row(j));
                }
                for t in 0..m {
                    kcat.row_mut(mlen + t).copy_from_slice(head_slice(&k, t, hh, dh));
                    vcat.row_mut(mlen + t).copy_from_slice(head_slice(&v, t, hh, dh));
                }
                for t in 0..m {
                    let w = attn_weights(&cfg, head_slice(&q, t, hh, dh), &kcat);
                    let orow = &mut attn_out.row_mut(t)[hh * dh..(hh + 1) * dh];
                    for (j, &wj) in w.iter().enumerate() {
                        for (o, &vv) in orow.iter_mut().zip(vcat.row(j)) {
                            *o += wj * vv;
                        }
                    }
                }
                // roll memory: drop oldest m rows, append the new ones
                let km = &mut self.kmem[li][hh];
                let vm = &mut self.vmem[li][hh];
                km.data.copy_within(m * dh.., 0);
                vm.data.copy_within(m * dh.., 0);
                for t in 0..m {
                    let dst = (mlen - m + t) * dh;
                    km.data[dst..dst + dh].copy_from_slice(head_slice(&k, t, hh, dh));
                    vm.data[dst..dst + dh].copy_from_slice(head_slice(&v, t, hh, dh));
                }
            }
            let a = project(&attn_out, &lp.wo, &lp.bo);
            residual(lp, &mut x, &a, 0);
            let f = ffn(&cfg, lp, &x);
            residual(lp, &mut x, &f, 1);
        }
        self.pos += m as i32;
        let last = Mat::from_vec(1, cfg.d_model, x.row(m - 1).to_vec());
        let mut logits = last.matmul(&self.p.w_cls);
        logits.add_row(&self.p.b_cls);
        Ok((logits.data, x))
    }
}
