//! Dense linear algebra for the probe trainer: Cholesky factorization
//! and SPD solves (ridge regression normal equations).
//!
//! The solves sweep **rows** with stride-1 inner loops over the
//! `nn::kernels` primitives: the old `cholesky_solve` walked
//! `x.at(k, col)` column-major (stride `cols` per inner-loop step), and
//! `ridge` materialized `X^T` to feed two naive matmuls. Both rewrites
//! preserve the per-element summation order bitwise (elementwise
//! `axpy` updates applied in the same `k`/row sequence), pinned in
//! `tests/kernels_equiv.rs` against the old column-walk.

use anyhow::{bail, Result};

use crate::nn::kernels::axpy;
use crate::nn::tensor::Mat;

/// In-place lower Cholesky of an SPD matrix. Returns L (rows x rows).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky needs a square matrix");
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite (pivot {s} at {i})");
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve L L^T x = b for multiple right-hand sides (columns of B).
///
/// Row-sweep substitution: all right-hand sides advance together, and
/// every inner update is a contiguous unrolled `axpy` over a full row
/// (the old implementation walked `x.at(k, col)` at stride `cols`, one
/// cache line per element once `cols` grew). Per element the update
/// sequence — subtract `l[i][k]·x[k]` for ascending `k`, then divide —
/// is unchanged, so results are bitwise identical to the column walk.
pub fn cholesky_solve(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    let cols = b.cols;
    let mut x = b.clone();
    // forward: L y = b
    for i in 0..n {
        let (done, rest) = x.data.split_at_mut(i * cols);
        let xi = &mut rest[..cols];
        for k in 0..i {
            axpy(-l.at(i, k), &done[k * cols..(k + 1) * cols], xi);
        }
        let d = l.at(i, i);
        for v in xi.iter_mut() {
            *v /= d;
        }
    }
    // backward: L^T x = y
    for i in (0..n).rev() {
        let (head, tail) = x.data.split_at_mut((i + 1) * cols);
        let xi = &mut head[i * cols..];
        for k in i + 1..n {
            let off = (k - i - 1) * cols;
            axpy(-l.at(k, i), &tail[off..off + cols], xi);
        }
        let d = l.at(i, i);
        for v in xi.iter_mut() {
            *v /= d;
        }
    }
    x
}

/// Ridge regression: W = (X^T X + lambda I)^-1 X^T Y.
/// X: (n x d), Y: (n x c) -> W: (d x c).
///
/// The gram matrix and X^T Y are accumulated as sums of row outer
/// products — each row of X/Y is read once, contiguously, and every
/// update is an unrolled `axpy` — instead of materializing `X^T` and
/// running two naive matmuls. The row-ascending accumulation matches
/// the old matmul's inner-dimension order, so results are bitwise
/// identical.
pub fn ridge(x: &Mat, y: &Mat, lambda: f32) -> Result<Mat> {
    anyhow::ensure!(x.rows == y.rows, "ridge: X has {} rows, Y has {}", x.rows, y.rows);
    let (d, c) = (x.cols, y.cols);
    let mut gram = Mat::zeros(d, d);
    let mut xty = Mat::zeros(d, c);
    for r in 0..x.rows {
        let xr = x.row(r);
        let yr = y.row(r);
        for (i, &xv) in xr.iter().enumerate() {
            axpy(xv, xr, gram.row_mut(i));
            axpy(xv, yr, xty.row_mut(i));
        }
    }
    for i in 0..d {
        *gram.at_mut(i, i) += lambda;
    }
    let l = cholesky(&gram)?;
    Ok(cholesky_solve(&l, &xty))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_identity() {
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            *a.at_mut(i, i) = 4.0;
        }
        let l = cholesky(&a).unwrap();
        for i in 0..3 {
            assert!((l.at(i, i) - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::zeros(2, 2);
        *a.at_mut(0, 0) = 1.0;
        *a.at_mut(1, 1) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn ridge_recovers_planted_weights() {
        let mut rng = Rng::new(11);
        let (n, d, c) = (400, 8, 3);
        let w_true = Mat::from_vec(d, c, rng.normal_vec(d * c, 1.0));
        let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
        let mut y = x.matmul(&w_true);
        for v in y.data.iter_mut() {
            *v += rng.normal_f32() * 0.01;
        }
        let w = ridge(&x, &y, 1e-3).unwrap();
        for (a, b) in w.data.iter().zip(&w_true.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        // A = L L^T with known L
        let l0 = Mat::from_vec(2, 2, vec![2.0, 0.0, 1.0, 1.5]);
        let a = l0.matmul(&l0.transpose());
        let b = Mat::from_vec(2, 1, vec![3.0, 5.0]);
        let l = cholesky(&a).unwrap();
        let x = cholesky_solve(&l, &b);
        let back = a.matmul(&x);
        for (g, w) in back.data.iter().zip(&b.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
