//! Dense linear algebra for the probe trainer: Cholesky factorization
//! and SPD solves (ridge regression normal equations).

use anyhow::{bail, Result};

use crate::nn::tensor::Mat;

/// In-place lower Cholesky of an SPD matrix. Returns L (rows x rows).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky needs a square matrix");
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix not positive definite (pivot {s} at {i})");
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// Solve L L^T x = b for multiple right-hand sides (columns of B).
pub fn cholesky_solve(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows;
    let mut x = b.clone();
    // forward: L y = b
    for col in 0..b.cols {
        for i in 0..n {
            let mut s = x.at(i, col);
            for k in 0..i {
                s -= l.at(i, k) * x.at(k, col);
            }
            *x.at_mut(i, col) = s / l.at(i, i);
        }
        // backward: L^T x = y
        for i in (0..n).rev() {
            let mut s = x.at(i, col);
            for k in i + 1..n {
                s -= l.at(k, i) * x.at(k, col);
            }
            *x.at_mut(i, col) = s / l.at(i, i);
        }
    }
    x
}

/// Ridge regression: W = (X^T X + lambda I)^-1 X^T Y.
/// X: (n x d), Y: (n x c) -> W: (d x c).
pub fn ridge(x: &Mat, y: &Mat, lambda: f32) -> Result<Mat> {
    let xt = x.transpose();
    let mut gram = xt.matmul(x);
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += lambda;
    }
    let l = cholesky(&gram)?;
    let xty = xt.matmul(y);
    Ok(cholesky_solve(&l, &xty))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cholesky_identity() {
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            *a.at_mut(i, i) = 4.0;
        }
        let l = cholesky(&a).unwrap();
        for i in 0..3 {
            assert!((l.at(i, i) - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::zeros(2, 2);
        *a.at_mut(0, 0) = 1.0;
        *a.at_mut(1, 1) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn ridge_recovers_planted_weights() {
        let mut rng = Rng::new(11);
        let (n, d, c) = (400, 8, 3);
        let w_true = Mat::from_vec(d, c, rng.normal_vec(d * c, 1.0));
        let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
        let mut y = x.matmul(&w_true);
        for v in y.data.iter_mut() {
            *v += rng.normal_f32() * 0.01;
        }
        let w = ridge(&x, &y, 1e-3).unwrap();
        for (a, b) in w.data.iter().zip(&w_true.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        // A = L L^T with known L
        let l0 = Mat::from_vec(2, 2, vec![2.0, 0.0, 1.0, 1.5]);
        let a = l0.matmul(&l0.transpose());
        let b = Mat::from_vec(2, 1, vec![3.0, 5.0]);
        let l = cholesky(&a).unwrap();
        let x = cholesky_solve(&l, &b);
        let back = a.matmul(&x);
        for (g, w) in back.data.iter().zip(&b.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }
}
