//! SIMD-friendly kernel suite for the hot continual-stepping path.
//!
//! The scalar engine's tick used to spend its time in naive triple-loop
//! matmuls, sequential-sum `dot`s (which LLVM cannot vectorize without
//! reassociating f32 math), and a RoPE that recomputed `powf`/`sin_cos`
//! per pair per row per layer per tick. This module provides the
//! replacements the batched stepper runs on:
//!
//! * [`dot`] / [`sqdist`] / [`axpy`] — fixed-width 8-wide unrolled
//!   primitives with split accumulators, written so the autovectorizer
//!   can emit packed FMAs without `-ffast-math`;
//! * [`PackedLinear`] — fused matmul+bias over a weight layout packed
//!   (transposed) once at load time, so every output element is one
//!   contiguous 8-wide dot and the bias add costs nothing extra;
//! * [`PackedParams`] — the whole-model packing pass
//!   (`ModelParams` → packed layout, done once at construction so the
//!   steady state stays zero-alloc);
//! * [`dot_scores_segments`] / [`soft_scores_segments`] /
//!   [`weighted_sum_segments`] — attention over the ring memory's
//!   two-segment contiguous view
//!   ([`KvRing::as_segments`](crate::nn::kv_ring::KvRing::as_segments)),
//!   replacing per-row iterator dispatch with tight loops over at most
//!   two contiguous slices;
//! * [`residual_fused`] — the bias/residual/norm epilogue as single
//!   row sweeps over contiguous slices instead of per-element indexed
//!   walks.
//!
//! # Determinism policy
//!
//! Every kernel uses a **fixed summation order that depends only on the
//! operand lengths** — never on memory alignment, ring wraparound
//! state, or how many lanes are stacked in a batch:
//!
//! * [`dot`] / [`sqdist`] accumulate into 8 split accumulators
//!   (`chunks_exact(8)`, remainder elements folded into accumulators
//!   `0..len % 8`) and reduce them in one fixed pairwise tree;
//! * [`axpy`] and the fused epilogues are elementwise (no reduction),
//!   so their results are independent of processing order by
//!   construction;
//! * the two-segment attention kernels visit rows in logical
//!   (oldest → newest) order, and each row is a single contiguous
//!   `d_head`-wide slice regardless of where the ring's write head
//!   sits, so per-score numerics are invariant to wraparound state.
//!
//! Because every per-stream quantity is therefore a pure function of
//! that stream's own history, the bitwise cluster invariants pinned in
//! `tests/cluster.rs` (1-shard ≡ 4-shard shard-layout equivalence,
//! migration transparency) and the lane-snapshot roundtrip in
//! `nn::batched` survive vectorization unchanged. Versus `nn::naive`
//! (sequential summation), results legitimately differ by float
//! reassociation; equivalence is asserted within 1e-4 relative
//! tolerance in `tests/kernels_equiv.rs`.
//!
//! ## SIMD lane mapping (`nn::simd`)
//!
//! The explicit-SIMD kernels in [`simd`](crate::nn::simd) are held to
//! the same policy **bitwise**, which pins the mapping between this
//! module's scalar code and the vector registers:
//!
//! * the 8 split accumulators of [`dot`] / [`sqdist`] ARE the 8 f32
//!   lanes of one AVX2 register (a NEON register pair): scalar
//!   `acc[j] += xs[j] * ys[j]` and a per-lane packed mul-then-add are
//!   the same two IEEE-754 operations on the same values;
//! * **no FMA, ever** — a fused multiply-add rounds once where
//!   mul-then-add rounds twice, so `_mm256_fmadd_ps` / `vfmaq_f32`
//!   would change bits. Packed multiplies and adds only;
//! * the vector accumulator is spilled back to a `[f32; 8]` and fed
//!   through the **same** [`reduce`] pairwise tree — SIMD
//!   horizontal-add shuffles impose a different tree shape and are
//!   forbidden;
//! * remainder elements (`len % 8`) run the scalar remainder code,
//!   folding into accumulator lanes `0..len % 8` exactly as here.
//!
//! `tests/simd_equiv.rs` pins every SIMD kernel against its scalar
//! twin bit for bit; because both satisfy the fixed-summation-order
//! contract, dispatch choice (scalar / AVX2 / NEON — see
//! [`KernelOps`](crate::nn::simd::KernelOps)) is invisible to every
//! bitwise invariant above.

use crate::nn::params::{ModelParams, Norm};
use crate::nn::simd::KernelOps;
use crate::nn::tensor::{gelu, layer_norm_inplace, Mat};

/// Unroll width of the split-accumulator kernels. Eight f32 lanes: one
/// AVX/NEON-friendly register's worth, and wide enough that LLVM emits
/// packed FMAs for the accumulator updates.
pub const UNROLL: usize = 8;

/// Reduce the split accumulators in a fixed pairwise tree. The order is
/// a function of nothing at all — every `dot`/`sqdist` of a given
/// length sums in exactly this shape. Public so the `nn::simd` kernels
/// can spill their vector accumulators into the identical tree.
#[inline]
pub fn reduce(acc: [f32; UNROLL]) -> f32 {
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// Dot product with 8 split accumulators and a fixed reduction tree.
/// Summation order depends only on `a.len()`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; UNROLL];
    let mut ca = a.chunks_exact(UNROLL);
    let mut cb = b.chunks_exact(UNROLL);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        for j in 0..UNROLL {
            acc[j] += xs[j] * ys[j];
        }
    }
    for (j, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        acc[j] += x * y;
    }
    reduce(acc)
}

/// Squared Euclidean distance, same accumulator discipline as [`dot`].
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; UNROLL];
    let mut ca = a.chunks_exact(UNROLL);
    let mut cb = b.chunks_exact(UNROLL);
    for (xs, ys) in (&mut ca).zip(&mut cb) {
        for j in 0..UNROLL {
            let d = xs[j] - ys[j];
            acc[j] += d * d;
        }
    }
    for (j, (x, y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        let d = x - y;
        acc[j] += d * d;
    }
    reduce(acc)
}

/// `y += a * x`, unrolled. Elementwise (no reduction), so the result is
/// bitwise independent of the chunking.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut cx = x.chunks_exact(UNROLL);
    let mut cy = y.chunks_exact_mut(UNROLL);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        for j in 0..UNROLL {
            ys[j] += a * xs[j];
        }
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += a * x;
    }
}

/// `y += x`, unrolled. Elementwise.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut cx = x.chunks_exact(UNROLL);
    let mut cy = y.chunks_exact_mut(UNROLL);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        for j in 0..UNROLL {
            ys[j] += xs[j];
        }
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += x;
    }
}

// ---------------------------------------------------------------------
// Two-segment ring attention

/// Scaled dot scores of one query head against a two-segment K view
/// (`KvRing::as_segments`): `out[j] = dot(q, k_j) * scale` for the
/// `dh`-wide rows of `seg_a` then `seg_b` in logical order. Each row is
/// contiguous within its segment (segment splits land on row
/// boundaries), so every score is computed by the identical [`dot`] op
/// sequence regardless of where the ring's head sits.
pub fn dot_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
    let dh = q.len().max(1);
    debug_assert_eq!((seg_a.len() + seg_b.len()) % dh, 0);
    debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
    let mut idx = 0;
    for seg in [seg_a, seg_b] {
        for krow in seg.chunks_exact(dh) {
            out[idx] = dot(q, krow) * scale;
            idx += 1;
        }
    }
}

/// SOFT-attention scores (paper Eq. 4, unnormalized Gaussian kernel)
/// over a two-segment K view: `out[j] = exp(-sqdist(q, k_j) * 0.5 *
/// scale)`, rows in logical order. Same invariances as
/// [`dot_scores_segments`].
pub fn soft_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
    let dh = q.len().max(1);
    debug_assert_eq!((seg_a.len() + seg_b.len()) % dh, 0);
    debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
    let mut idx = 0;
    for seg in [seg_a, seg_b] {
        for krow in seg.chunks_exact(dh) {
            out[idx] = (-sqdist(q, krow) * 0.5 * scale).exp();
            idx += 1;
        }
    }
}

/// `out += Σ_j weights[j] * v_j` over a two-segment V view, rows in
/// logical order (the exact summation order of the old per-row
/// iterator walk). Elementwise accumulation via [`axpy`].
pub fn weighted_sum_segments(weights: &[f32], seg_a: &[f32], seg_b: &[f32], out: &mut [f32]) {
    let dh = out.len().max(1);
    debug_assert_eq!((seg_a.len() + seg_b.len()) % dh, 0);
    debug_assert_eq!(weights.len() * dh, seg_a.len() + seg_b.len());
    let mut idx = 0;
    for seg in [seg_a, seg_b] {
        for vrow in seg.chunks_exact(dh) {
            axpy(weights[idx], vrow, out);
            idx += 1;
        }
    }
}

// ---------------------------------------------------------------------
// Packed fused matmul + bias

/// A linear layer packed for the dot kernel: the weight matrix stored
/// transposed (`out_dim x in_dim`, each output's weights contiguous)
/// with its bias fused alongside. Packing happens once at load /
/// construction time; `forward_*` then computes each output element as
/// one contiguous 8-wide [`dot`] plus the bias — no separate bias
/// sweep, no strided column walks.
///
/// Every output row is a pure function of the matching input row, so
/// stacking more lanes into `x` never changes an existing row's bits
/// (the lane-count invariance the sharded cluster's bitwise tests rely
/// on).
#[derive(Debug, Clone)]
pub struct PackedLinear {
    in_dim: usize,
    out_dim: usize,
    /// (out_dim x in_dim): row `j` is column `j` of the source matrix.
    wt: Vec<f32>,
    bias: Vec<f32>,
    /// Kernel path resolved once at pack time (`&'static` dispatch
    /// table — no per-call-site feature branching in the tick loop).
    ops: &'static KernelOps,
}

impl PackedLinear {
    /// Pack `w` (`in_dim x out_dim`, the `x @ w` convention of
    /// [`Mat::matmul`]) and its bias, resolving the kernel path under
    /// [`DispatchChoice::Auto`](crate::nn::simd::DispatchChoice). One
    /// transposition pass; the source matrix can be dropped afterwards.
    pub fn pack(w: &Mat, bias: &[f32]) -> Self {
        Self::pack_with(w, bias, KernelOps::auto())
    }

    /// [`PackedLinear::pack`] onto an explicit, already-resolved kernel
    /// path.
    pub fn pack_with(w: &Mat, bias: &[f32], ops: &'static KernelOps) -> Self {
        assert_eq!(w.cols, bias.len(), "PackedLinear::pack bias length");
        assert!(w.rows > 0 && w.cols > 0, "PackedLinear::pack empty weight");
        let (k, c) = (w.rows, w.cols);
        let mut wt = vec![0.0f32; k * c];
        for r in 0..k {
            for j in 0..c {
                wt[j * k + r] = w.at(r, j);
            }
        }
        Self { in_dim: k, out_dim: c, wt, bias: bias.to_vec(), ops }
    }

    /// Input width (`k`).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Output width (`c`).
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// One row: `out = x @ W + b` (bias added after the completed
    /// product sum, matching the naive matmul-then-`add_row` order),
    /// via the monolithic fused row sweep of the resolved kernel path
    /// (one indirect call per row, not per output dot).
    pub fn forward_row_into(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(out.len(), self.out_dim);
        (self.ops.linear_forward)(x, &self.wt, &self.bias, out);
    }

    /// `out = x @ W + b` over all rows. `out` must not alias `x`.
    pub fn forward_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.cols, self.in_dim, "PackedLinear::forward_into in_dim");
        assert_eq!(out.cols, self.out_dim, "PackedLinear::forward_into out_dim");
        assert_eq!(x.rows, out.rows, "PackedLinear::forward_into rows");
        for r in 0..x.rows {
            (self.ops.linear_forward)(x.row(r), &self.wt, &self.bias, out.row_mut(r));
        }
    }

    /// `out = gelu(x @ W + b)` — the FFN up-projection with the
    /// activation applied in a second in-place sweep over the freshly
    /// written row. The activation input values are bit-identical to
    /// [`PackedLinear::forward_into`]'s output, so fusing or splitting
    /// the sweep cannot change bits (pinned in the tests below).
    pub fn forward_gelu_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.cols, self.in_dim, "PackedLinear::forward_gelu_into in_dim");
        assert_eq!(out.cols, self.out_dim, "PackedLinear::forward_gelu_into out_dim");
        assert_eq!(x.rows, out.rows, "PackedLinear::forward_gelu_into rows");
        for r in 0..x.rows {
            let orow = out.row_mut(r);
            (self.ops.linear_forward)(x.row(r), &self.wt, &self.bias, orow);
            for v in orow.iter_mut() {
                *v = gelu(*v);
            }
        }
    }
}

/// One encoder layer's projections in packed layout.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// Query projection.
    pub wq: PackedLinear,
    /// Key projection.
    pub wk: PackedLinear,
    /// Value projection.
    pub wv: PackedLinear,
    /// Attention output projection.
    pub wo: PackedLinear,
    /// FFN up-projection.
    pub w1: PackedLinear,
    /// FFN down-projection.
    pub w2: PackedLinear,
}

/// The whole-model weight-packing pass: every matmul the continual tick
/// performs, in packed (transposed, bias-fused) layout. Built once at
/// stepper construction — steady-state ticks touch only these buffers,
/// so the zero-allocation guarantee of the scratch-workspace design is
/// preserved. Norm parameters are not packed (the fused residual
/// sweeps read [`Norm`] values directly); the batched stepper keeps a
/// clone of those and drops the naive-layout [`ModelParams`], so each
/// weight is resident exactly once.
#[derive(Debug, Clone)]
pub struct PackedParams {
    /// Input projection.
    pub w_in: PackedLinear,
    /// Per-layer packed projections.
    pub layers: Vec<PackedLayer>,
    /// Classifier head.
    pub w_cls: PackedLinear,
}

impl PackedParams {
    /// Pack every projection of `p`, resolving the kernel path under
    /// [`DispatchChoice::Auto`](crate::nn::simd::DispatchChoice). `p`
    /// itself is untouched (the stepper keeps it for norm parameters
    /// and snapshots).
    pub fn pack(p: &ModelParams) -> Self {
        Self::pack_with(p, KernelOps::auto())
    }

    /// [`PackedParams::pack`] onto an explicit, already-resolved kernel
    /// path (the stepper-construction entry point: the dispatch choice
    /// from `EngineConfig` / `--kernel-dispatch` is resolved once and
    /// threaded here).
    pub fn pack_with(p: &ModelParams, ops: &'static KernelOps) -> Self {
        let layers = p
            .layers
            .iter()
            .map(|lp| PackedLayer {
                wq: PackedLinear::pack_with(&lp.wq, &lp.bq, ops),
                wk: PackedLinear::pack_with(&lp.wk, &lp.bk, ops),
                wv: PackedLinear::pack_with(&lp.wv, &lp.bv, ops),
                wo: PackedLinear::pack_with(&lp.wo, &lp.bo, ops),
                w1: PackedLinear::pack_with(&lp.w1, &lp.b1, ops),
                w2: PackedLinear::pack_with(&lp.w2, &lp.b2, ops),
            })
            .collect();
        Self {
            w_in: PackedLinear::pack_with(&p.w_in, &p.b_in, ops),
            layers,
            w_cls: PackedLinear::pack_with(&p.w_cls, &p.b_cls, ops),
        }
    }
}

// ---------------------------------------------------------------------
// Fused residual epilogues

/// Post-norm residual as single row sweeps: `x += sub` (scaled for
/// ReZero) then the sub-layer norm, over contiguous row slices instead
/// of per-element `at_mut` walks. `idx` selects the attention (0) or
/// FFN (1) parameter set — the same contract as
/// `nn::encoder::residual` (which takes the layer's [`Norm`] via its
/// `LayerParams`), and elementwise-identical numerics. The add/axpy
/// sweeps run on the resolved kernel path `ops`; the norm itself is
/// the shared scalar [`layer_norm_inplace`] on every path (a shared op
/// is trivially bitwise-identical across dispatch choices).
pub fn residual_fused(ops: &KernelOps, norm: &Norm, x: &mut Mat, sub: &Mat, idx: usize) {
    debug_assert_eq!(x.rows, sub.rows);
    debug_assert_eq!(x.cols, sub.cols);
    match (norm, idx) {
        (Norm::LayerNorm { g1, be1, .. }, 0) => {
            for t in 0..x.rows {
                let row = x.row_mut(t);
                (ops.add_assign)(row, sub.row(t));
                layer_norm_inplace(row, g1, be1);
            }
        }
        (Norm::LayerNorm { g2, be2, .. }, _) => {
            for t in 0..x.rows {
                let row = x.row_mut(t);
                (ops.add_assign)(row, sub.row(t));
                layer_norm_inplace(row, g2, be2);
            }
        }
        (Norm::ReZero { a1, a2 }, _) => {
            let a = if idx == 0 { *a1 } else { *a2 };
            for t in 0..x.rows {
                (ops.axpy)(a, sub.row(t), x.row_mut(t));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dot_zero_len_and_small() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn dot_matches_sequential_within_tolerance() {
        let mut rng = Rng::new(5);
        for len in [7, 8, 9, 15, 16, 17, 64, 100] {
            let a = rng.normal_vec(len, 1.0);
            let b = rng.normal_vec(len, 1.0);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-4 + 1e-4 * want.abs(), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn sqdist_nonnegative_and_zero_on_self() {
        let mut rng = Rng::new(6);
        let a = rng.normal_vec(19, 1.0);
        let b = rng.normal_vec(19, 1.0);
        assert_eq!(sqdist(&a, &a), 0.0);
        assert!(sqdist(&a, &b) > 0.0);
    }

    #[test]
    fn axpy_and_add_assign_are_elementwise_exact() {
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(21, 1.0);
        let y0 = rng.normal_vec(21, 1.0);
        let mut y = y0.clone();
        axpy(0.5, &x, &mut y);
        for i in 0..21 {
            assert_eq!(y[i].to_bits(), (y0[i] + 0.5 * x[i]).to_bits(), "axpy[{i}]");
        }
        let mut z = y0.clone();
        add_assign(&mut z, &x);
        for i in 0..21 {
            assert_eq!(z[i].to_bits(), (y0[i] + x[i]).to_bits(), "add_assign[{i}]");
        }
    }

    #[test]
    fn packed_linear_matches_matmul_add_row() {
        let mut rng = Rng::new(8);
        for (k, c) in [(5usize, 3usize), (8, 8), (12, 20), (33, 7)] {
            let w = Mat::from_vec(k, c, rng.normal_vec(k * c, 1.0));
            let bias = rng.normal_vec(c, 0.5);
            let x = Mat::from_vec(3, k, rng.normal_vec(3 * k, 1.0));
            let mut want = x.matmul(&w);
            want.add_row(&bias);
            let packed = PackedLinear::pack(&w, &bias);
            assert_eq!(packed.in_dim(), k);
            assert_eq!(packed.out_dim(), c);
            let mut got = Mat::zeros(3, c);
            packed.forward_into(&x, &mut got);
            for (g, wv) in got.data.iter().zip(&want.data) {
                assert!((g - wv).abs() <= 1e-4 + 1e-4 * wv.abs(), "{k}x{c}: {g} vs {wv}");
            }
            // fused GELU epilogue
            let mut got_g = Mat::zeros(3, c);
            packed.forward_gelu_into(&x, &mut got_g);
            for (g, wv) in got_g.data.iter().zip(&got.data) {
                assert_eq!(g.to_bits(), gelu(*wv).to_bits());
            }
        }
    }

    #[test]
    fn segment_kernels_match_single_segment_layout() {
        // the same logical rows split at every possible boundary must
        // produce bitwise-identical scores and weighted sums
        let mut rng = Rng::new(9);
        let (rows, dh) = (6usize, 10usize);
        let flat = rng.normal_vec(rows * dh, 1.0);
        let q = rng.normal_vec(dh, 1.0);
        let mut want = vec![0.0f32; rows];
        dot_scores_segments(&q, &flat, &[], 0.25, &mut want);
        let mut want_soft = vec![0.0f32; rows];
        soft_scores_segments(&q, &flat, &[], 0.25, &mut want_soft);
        let mut want_sum = vec![0.0f32; dh];
        weighted_sum_segments(&want, &flat, &[], &mut want_sum);
        for split in 0..=rows {
            let (a, b) = flat.split_at(split * dh);
            let mut got = vec![0.0f32; rows];
            dot_scores_segments(&q, a, b, 0.25, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "dot scores at split {split}"
            );
            let mut got_soft = vec![0.0f32; rows];
            soft_scores_segments(&q, a, b, 0.25, &mut got_soft);
            assert_eq!(
                got_soft.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want_soft.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "soft scores at split {split}"
            );
            let mut got_sum = vec![0.0f32; dh];
            weighted_sum_segments(&got, a, b, &mut got_sum);
            assert_eq!(
                got_sum.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want_sum.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "weighted sum at split {split}"
            );
        }
    }
}
