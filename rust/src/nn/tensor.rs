//! Minimal dense-tensor math for the scalar reference engine.
//!
//! Row-major `Mat` (2-D) is all the engine needs; higher-rank shapes are
//! handled as explicit loops at call sites for clarity over generality.
//! The `_into` variants plus [`RowsRef`]/[`RowsMut`] row-range views let
//! callers work without steady-state heap allocation.
//!
//! The free functions here ([`dot`], [`sqdist`], …) are deliberately
//! **sequential-summation naive**: they are the oracle/baseline
//! numerics that `nn::naive`, `nn::encoder` and the golden tests pin
//! down, and what `bench_kernels` measures the 8-wide unrolled
//! `nn::kernels` suite against. The batched hot path does not call
//! them.

/// Row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "Mat::from_vec shape mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Overwrite every element.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Borrow rows `[r0, r0 + n)` as an immutable sub-matrix view.
    pub fn rows_view(&self, r0: usize, n: usize) -> RowsRef<'_> {
        assert!(r0 + n <= self.rows, "rows_view out of range");
        RowsRef { rows: n, cols: self.cols, data: &self.data[r0 * self.cols..(r0 + n) * self.cols] }
    }

    /// Borrow rows `[r0, r0 + n)` as a mutable sub-matrix view.
    pub fn rows_view_mut(&mut self, r0: usize, n: usize) -> RowsMut<'_> {
        assert!(r0 + n <= self.rows, "rows_view_mut out of range");
        let cols = self.cols;
        RowsMut { rows: n, cols, data: &mut self.data[r0 * cols..(r0 + n) * cols] }
    }

    /// self (r x k) @ other (k x c) -> (r x c). Naive triple loop with
    /// the k-loop innermost over contiguous rows — the scalar baseline
    /// the paper's "standard implementation" framing implies.
    ///
    /// Deliberately branch-free in the inner loops: a data-dependent
    /// zero-skip would make benchmark timings input-dependent
    /// (zero-heavy windows looking artificially fast) and skew
    /// FLOP-vs-time comparisons.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// In-place matmul: overwrite `out` (r x c) with self @ other.
    /// Same loop order and summation order as [`Mat::matmul`], zero
    /// allocation.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        assert_eq!(out.rows, self.rows, "matmul_into out rows");
        assert_eq!(out.cols, other.cols, "matmul_into out cols");
        out.data.fill(0.0);
        for i in 0..self.rows {
            let arow = self.row(i);
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a) in arow.iter().enumerate() {
                let orow = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
    }

    /// Add a broadcast row vector.
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (v, &b) in self.row_mut(r).iter_mut().zip(bias) {
                *v += b;
            }
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }
}

/// Immutable view of a contiguous row range of a [`Mat`].
#[derive(Debug, Clone, Copy)]
pub struct RowsRef<'a> {
    pub rows: usize,
    pub cols: usize,
    data: &'a [f32],
}

impl<'a> RowsRef<'a> {
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// The backing contiguous slice (rows * cols).
    pub fn as_slice(&self) -> &'a [f32] {
        self.data
    }
}

/// Mutable view of a contiguous row range of a [`Mat`].
#[derive(Debug)]
pub struct RowsMut<'a> {
    pub rows: usize,
    pub cols: usize,
    data: &'a mut [f32],
}

impl RowsMut<'_> {
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// The backing contiguous slice (rows * cols).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut *self.data
    }
}

/// Dot product of two equal-length slices.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared Euclidean distance.
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(v: &mut [f32]) {
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in v.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

/// tanh-approximation GELU — matches `jax.nn.gelu` (approximate=True).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place LayerNorm with eps 1e-5 (matches the L2 model).
pub fn layer_norm_inplace(v: &mut [f32], gamma: &[f32], beta: &[f32]) {
    let n = v.len() as f32;
    let mu = v.iter().sum::<f32>() / n;
    let var = v.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for ((x, &g), &b) in v.iter_mut().zip(gamma).zip(beta) {
        *x = (*x - mu) * inv * g + b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_into_matches_matmul_and_overwrites() {
        let a = Mat::from_vec(2, 3, (0..6).map(|x| x as f32 - 2.0).collect());
        let b = Mat::from_vec(3, 2, (0..6).map(|x| 0.5 * x as f32).collect());
        let mut out = Mat::from_vec(2, 2, vec![9.0; 4]); // stale contents
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
    }

    #[test]
    fn matmul_handles_zero_rows_exactly() {
        // the old zero-skip fast path is gone; zeros must still multiply
        // out to exact zeros through the branch-free loop
        let a = Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 0.0]);
        let b = Mat::from_vec(2, 2, vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.matmul(&b).data, vec![0.0, 0.0, 2.0, 3.0]);
    }

    #[test]
    fn rows_views_window_correctly() {
        let mut m = Mat::from_vec(4, 2, (0..8).map(|x| x as f32).collect());
        let v = m.rows_view(1, 2);
        assert_eq!(v.rows, 2);
        assert_eq!(v.row(0), &[2.0, 3.0]);
        assert_eq!(v.at(1, 1), 5.0);
        assert_eq!(v.as_slice(), &[2.0, 3.0, 4.0, 5.0]);
        let mut w = m.rows_view_mut(2, 2);
        w.row_mut(0)[0] = -1.0;
        w.as_mut_slice()[3] = -2.0;
        assert_eq!(m.at(2, 0), -1.0);
        assert_eq!(m.at(3, 1), -2.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_vec(2, 3, (0..6).map(|x| x as f32).collect());
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, 1e4];
        softmax_inplace(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(v[3] > 0.999);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        layer_norm_inplace(&mut v, &g, &b);
        let mu: f32 = v.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }

    #[test]
    fn add_row_broadcasts() {
        let mut a = Mat::zeros(2, 3);
        a.add_row(&[1.0, 2.0, 3.0]);
        assert_eq!(a.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn fill_overwrites() {
        let mut a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        a.fill(0.0);
        assert_eq!(a.data, vec![0.0; 4]);
    }
}
