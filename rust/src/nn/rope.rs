//! Rotary Position Embedding — scalar mirror of `python/compile/rope.py`
//! (interleaved-pair convention, base 10000).

pub const BASE: f32 = 10000.0;

/// Rotate one head vector (len dh, even) in place by absolute `pos`.
pub fn apply_rope_inplace(x: &mut [f32], pos: i32) {
    let dh = x.len();
    debug_assert_eq!(dh % 2, 0);
    let half = dh / 2;
    for i in 0..half {
        let freq = 1.0 / BASE.powf((2 * i) as f32 / dh as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let e = x[2 * i];
        let o = x[2 * i + 1];
        x[2 * i] = e * cos - o * sin;
        x[2 * i + 1] = e * sin + o * cos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pos_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        apply_rope_inplace(&mut x, 0);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rotation_preserves_pair_norms() {
        let mut x = vec![3.0, 4.0, 1.0, 2.0];
        apply_rope_inplace(&mut x, 17);
        assert!((x[0] * x[0] + x[1] * x[1] - 25.0).abs() < 1e-4);
        assert!((x[2] * x[2] + x[3] * x[3] - 5.0).abs() < 1e-4);
    }

    /// RoPE's defining property: <rot(q,p1), rot(k,p2)> depends only on
    /// p1 - p2 (this is what makes it circular / stream-safe, supp. §III).
    #[test]
    fn relative_property() {
        let q0 = vec![0.3, -1.2, 0.7, 0.5];
        let k0 = vec![1.0, 0.2, -0.4, 0.9];
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let mut q1 = q0.clone();
        let mut k1 = k0.clone();
        apply_rope_inplace(&mut q1, 5);
        apply_rope_inplace(&mut k1, 2);
        let mut q2 = q0.clone();
        let mut k2 = k0.clone();
        apply_rope_inplace(&mut q2, 105);
        apply_rope_inplace(&mut k2, 102);
        assert!((dot(&q1, &k1) - dot(&q2, &k2)).abs() < 1e-3);
    }
}
