//! Rotary Position Embedding — scalar mirror of `python/compile/rope.py`
//! (interleaved-pair convention, base 10000).
//!
//! Two paths share one op sequence:
//!
//! * [`apply_rope_inplace`] — the reference per-call path: recomputes
//!   `powf` + `sin_cos` for every pair on every call. Used by the
//!   frozen `nn::naive` baseline and the full-window oracle.
//! * [`RopeTable`] — the kernel-suite path: inverse frequencies are
//!   precomputed once at construction and per-position sin/cos rows are
//!   memoized in preallocated storage. Both paths compute each angle as
//!   `pos as f32 * inv_freq(dh, i)` with the identical [`inv_freq`]
//!   expression, so the table is **bitwise-transparent**: rotating with
//!   a cached row equals rotating with [`apply_rope_inplace`] bit for
//!   bit (pinned in `tests/kernels_equiv.rs`). That is what lets the
//!   batched stepper reuse one row across Q/K, all heads, and all
//!   layers of a tick without perturbing the cluster's bitwise
//!   invariants.

pub const BASE: f32 = 10000.0;

/// Inverse frequency of pair `i` in a `dh`-wide head: the single op
/// sequence shared by the per-call path and [`RopeTable`] (any
/// divergence here would break the table's bitwise transparency).
#[inline]
pub fn inv_freq(dh: usize, i: usize) -> f32 {
    1.0 / BASE.powf((2 * i) as f32 / dh as f32)
}

/// Rotate one head vector (len dh, even) in place by absolute `pos`.
pub fn apply_rope_inplace(x: &mut [f32], pos: i32) {
    let dh = x.len();
    debug_assert_eq!(dh % 2, 0);
    let half = dh / 2;
    for i in 0..half {
        let freq = inv_freq(dh, i);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let e = x[2 * i];
        let o = x[2 * i + 1];
        x[2 * i] = e * cos - o * sin;
        x[2 * i + 1] = e * sin + o * cos;
    }
}

/// Rotate one head vector in place with a precomputed sin/cos row
/// (`sin.len() == cos.len() == x.len() / 2`). Identical arithmetic to
/// [`apply_rope_inplace`] given identical sin/cos values.
#[inline]
pub fn apply_rope_cached(x: &mut [f32], sin: &[f32], cos: &[f32]) {
    let half = x.len() / 2;
    debug_assert_eq!(half * 2, x.len());
    debug_assert_eq!(sin.len(), half);
    debug_assert_eq!(cos.len(), half);
    for i in 0..half {
        let e = x[2 * i];
        let o = x[2 * i + 1];
        x[2 * i] = e * cos[i] - o * sin[i];
        x[2 * i + 1] = e * sin[i] + o * cos[i];
    }
}

/// Rotate every `dh`-wide head chunk of a stacked `(n_heads * dh)` row
/// with one shared sin/cos row — all heads of a token share the same
/// position and head width, so the row is computed once per token
/// instead of once per head per Q/K. This is the scalar entry of the
/// `rope_rotate_row` dispatch slot in
/// [`KernelOps`](crate::nn::simd::KernelOps); the SIMD rotates consume
/// the identical memoized [`RopeTable`] rows and are pinned bitwise
/// against this function in `tests/simd_equiv.rs`.
#[inline]
pub fn apply_rope_row(row: &mut [f32], dh: usize, sin: &[f32], cos: &[f32]) {
    for chunk in row.chunks_exact_mut(dh) {
        apply_rope_cached(chunk, sin, cos);
    }
}

/// Precomputed inverse-frequency table plus memoized per-position
/// sin/cos rows, in storage sized once at construction (steady-state
/// use performs no heap allocation).
///
/// Memoization is keyed per `slot` (the caller's stacked-row index):
/// [`RopeTable::row`] recomputes the row only when that slot's position
/// changed since its last call. In the batched stepper this turns
/// `2 · n_heads · n_layers` trig evaluations per token per tick into
/// one (the first layer computes, every later layer and the K/Q twin
/// hit the memo), and masked lanes — whose clocks don't advance — hit
/// the memo across ticks entirely. Because a row's contents are a pure
/// function of `pos` alone, memoization never changes results: stale
/// slots are simply recomputed on their next use, and resets /
/// snapshot imports need no cache invalidation.
#[derive(Debug, Clone)]
pub struct RopeTable {
    half: usize,
    inv_freq: Vec<f32>,
    /// Position currently cached in each slot (`None` = never filled).
    memo: Vec<Option<i32>>,
    sin: Vec<f32>,
    cos: Vec<f32>,
}

impl RopeTable {
    /// Table for `dh`-wide heads (`dh / 2` rotation pairs) with `slots`
    /// memo rows. `dh` may be odd only if the table is never used (a
    /// non-RoPE model constructing its stepper); rotation itself
    /// requires even `dh` like [`apply_rope_inplace`].
    pub fn new(dh: usize, slots: usize) -> Self {
        let half = dh / 2;
        Self {
            half,
            inv_freq: (0..half).map(|i| inv_freq(dh, i)).collect(),
            memo: vec![None; slots],
            sin: vec![0.0; slots * half],
            cos: vec![0.0; slots * half],
        }
    }

    /// Rotation pairs per head (`dh / 2`).
    pub fn half(&self) -> usize {
        self.half
    }

    /// Memo capacity in rows.
    pub fn slots(&self) -> usize {
        self.memo.len()
    }

    /// The sin/cos row for absolute position `pos`, memoized on `slot`.
    /// Computes (in place, allocation-free) only if the slot's cached
    /// position differs.
    pub fn row(&mut self, slot: usize, pos: i32) -> (&[f32], &[f32]) {
        let h = self.half;
        if self.memo[slot] != Some(pos) {
            let sin = &mut self.sin[slot * h..(slot + 1) * h];
            let cos = &mut self.cos[slot * h..(slot + 1) * h];
            for (i, f) in self.inv_freq.iter().enumerate() {
                let ang = pos as f32 * f;
                let (sv, cv) = ang.sin_cos();
                sin[i] = sv;
                cos[i] = cv;
            }
            self.memo[slot] = Some(pos);
        }
        (&self.sin[slot * h..(slot + 1) * h], &self.cos[slot * h..(slot + 1) * h])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pos_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        apply_rope_inplace(&mut x, 0);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rotation_preserves_pair_norms() {
        let mut x = vec![3.0, 4.0, 1.0, 2.0];
        apply_rope_inplace(&mut x, 17);
        assert!((x[0] * x[0] + x[1] * x[1] - 25.0).abs() < 1e-4);
        assert!((x[2] * x[2] + x[3] * x[3] - 5.0).abs() < 1e-4);
    }

    /// RoPE's defining property: <rot(q,p1), rot(k,p2)> depends only on
    /// p1 - p2 (this is what makes it circular / stream-safe, supp. §III).
    #[test]
    fn relative_property() {
        let q0 = vec![0.3, -1.2, 0.7, 0.5];
        let k0 = vec![1.0, 0.2, -0.4, 0.9];
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let mut q1 = q0.clone();
        let mut k1 = k0.clone();
        apply_rope_inplace(&mut q1, 5);
        apply_rope_inplace(&mut k1, 2);
        let mut q2 = q0.clone();
        let mut k2 = k0.clone();
        apply_rope_inplace(&mut q2, 105);
        apply_rope_inplace(&mut k2, 102);
        assert!((dot(&q1, &k1) - dot(&q2, &k2)).abs() < 1e-3);
    }

    #[test]
    fn table_rows_are_bitwise_transparent() {
        for dh in [2usize, 4, 6, 10, 16] {
            let mut tab = RopeTable::new(dh, 3);
            for &pos in &[0i32, 1, 7, 129, 100_000] {
                let mut want: Vec<f32> = (0..dh).map(|i| (i as f32 * 0.3) - 1.0).collect();
                let mut got = want.clone();
                apply_rope_inplace(&mut want, pos);
                let (sin, cos) = tab.row(1, pos);
                apply_rope_cached(&mut got, sin, cos);
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "dh {dh} pos {pos}");
                }
            }
        }
    }

    #[test]
    fn table_memo_hits_and_refills() {
        let mut tab = RopeTable::new(4, 2);
        let first: Vec<f32> = {
            let (s, c) = tab.row(0, 42);
            s.iter().chain(c).copied().collect()
        };
        // same slot, same pos: memo hit returns identical bits
        let again: Vec<f32> = {
            let (s, c) = tab.row(0, 42);
            s.iter().chain(c).copied().collect()
        };
        assert_eq!(first, again);
        // same slot, new pos: refilled; returning to the old pos
        // recomputes the exact original row
        tab.row(0, 43);
        let back: Vec<f32> = {
            let (s, c) = tab.row(0, 42);
            s.iter().chain(c).copied().collect()
        };
        assert_eq!(first, back);
        assert_eq!(tab.half(), 2);
        assert_eq!(tab.slots(), 2);
    }

    #[test]
    fn apply_rope_row_rotates_every_head_chunk() {
        let dh = 4;
        let mut tab = RopeTable::new(dh, 1);
        let row0: Vec<f32> = (0..8).map(|i| i as f32 * 0.25).collect();
        let mut per_head = row0.clone();
        apply_rope_inplace(&mut per_head[0..4], 9);
        apply_rope_inplace(&mut per_head[4..8], 9);
        let mut whole = row0;
        let (sin, cos) = tab.row(0, 9);
        apply_rope_row(&mut whole, dh, sin, cos);
        for (g, w) in whole.iter().zip(&per_head) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
