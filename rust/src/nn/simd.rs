//! Explicit-SIMD implementations of the `nn::kernels` hot path with
//! runtime CPU-feature dispatch resolved **once at startup**.
//!
//! PR 4's kernels are written so the autovectorizer *can* emit packed
//! arithmetic; this module stops hoping and writes the packed
//! arithmetic down: `target_feature`-gated AVX2 (x86_64) and NEON
//! (aarch64) versions of every hot kernel — `dot`, `sqdist`, `axpy`,
//! `add_assign`, the packed fused matmul+bias row sweep, the
//! two-segment ring-attention score/weighted-sum kernels, and the RoPE
//! rotate — std-only, no new dependencies.
//!
//! # Dispatch model
//!
//! A [`KernelOps`] is a table of plain function pointers, one static
//! table per path ([`DispatchPath`]: scalar / AVX2 / NEON). The table
//! is chosen **once** — [`KernelOps::resolve`] at
//! `ModelParams::pack` / stepper construction — and held by reference
//! (`&'static KernelOps`) in [`PackedLinear`](crate::nn::kernels::PackedLinear)
//! and [`BatchedScalarDeepCoT`](crate::nn::batched::BatchedScalarDeepCoT),
//! so the per-tick hot loop performs zero per-call-site feature
//! branching. Selection order:
//!
//! 1. an explicit [`DispatchChoice`] (`EngineConfig::kernel_dispatch`,
//!    `--kernel-dispatch`) wins; forcing a path the CPU/build does not
//!    support fails loudly rather than silently falling back;
//! 2. under [`DispatchChoice::Auto`], the `DEEPCOT_KERNEL_DISPATCH`
//!    env var (`scalar|avx2|neon|auto`) is consulted — the knob tests
//!    and CI use to exercise every path on any machine;
//! 3. otherwise the best native path: AVX2 when
//!    `is_x86_feature_detected!("avx2")`, NEON on aarch64, else the
//!    PR 4 scalar kernels. The detection result is cached in a
//!    `OnceLock` ([`KernelOps::native`]).
//!
//! The chosen path is observable end to end: `ClusterMetrics` /
//! `METRICS` report `dispatch=<path>`, and `bench_kernels --json`
//! records it next to the detected CPU features ([`cpu_features`]).
//!
//! # Bitwise determinism (the non-negotiable part)
//!
//! Every SIMD kernel reproduces the scalar kernels **bit for bit**
//! (pinned per kernel in `tests/simd_equiv.rs`), so all cluster pins —
//! 1-shard ≡ 4-shard, migration transparency, TCP-trace identity, lane
//! snapshot roundtrips — hold with SIMD active, and a stream can even
//! migrate between machines resolving *different* paths without its
//! bits diverging. The recipe:
//!
//! * the scalar kernels' 8 split accumulators map onto 8 f32 SIMD
//!   lanes (one AVX2 register; a NEON register pair with lanes 0..3
//!   in the low register and 4..7 in the high one), updated with plain
//!   packed mul-then-add — **never FMA**: a fused multiply-add rounds
//!   once where mul+add rounds twice, which would change bits;
//! * the vector accumulator is spilled to a `[f32; 8]` and reduced by
//!   the *scalar* fixed pairwise tree
//!   ([`kernels::reduce`](crate::nn::kernels::reduce)) — SIMD
//!   horizontal-add shuffles would impose a different tree shape;
//! * remainder elements (`len % 8`) run the exact scalar remainder
//!   code, folding into accumulator lanes `0..len % 8`;
//! * elementwise kernels (`axpy`, `add_assign`, RoPE) have no
//!   reduction, so lane widths can differ freely; each lane performs
//!   the identical mul/add/sub op sequence as its scalar twin. (The
//!   one licensed deviation: the AVX2 RoPE odd lane computes
//!   `o·cos + e·sin` where the scalar computes `e·sin + o·cos` — f32
//!   addition is commutative bitwise for the finite values the engine
//!   produces, and `tests/simd_equiv.rs` pins the equality.)

use std::fmt;
use std::sync::OnceLock;

use anyhow::Result;

use crate::nn::kernels;
use crate::nn::rope;

/// Environment knob consulted under [`DispatchChoice::Auto`]:
/// `DEEPCOT_KERNEL_DISPATCH=scalar|avx2|neon|auto`. An unparsable
/// value fails resolution loudly (a typo must not silently change the
/// measured path); an explicit non-`Auto` choice ignores the variable.
pub const DISPATCH_ENV: &str = "DEEPCOT_KERNEL_DISPATCH";

/// The kernel path a [`KernelOps`] table actually runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPath {
    /// The PR 4 autovectorizer-friendly scalar kernels.
    Scalar,
    /// Explicit 256-bit AVX2 intrinsics (x86_64).
    Avx2,
    /// Explicit 128-bit NEON intrinsics (aarch64).
    Neon,
}

impl DispatchPath {
    /// Lowercase path name ("scalar" / "avx2" / "neon") for metrics,
    /// logs, and bench JSON.
    pub fn as_str(self) -> &'static str {
        match self {
            DispatchPath::Scalar => "scalar",
            DispatchPath::Avx2 => "avx2",
            DispatchPath::Neon => "neon",
        }
    }
}

impl fmt::Display for DispatchPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What the caller *asked for* (config / CLI / env), as opposed to the
/// [`DispatchPath`] that resolution produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchChoice {
    /// Env var if set, else the best detected native path.
    #[default]
    Auto,
    /// Force the scalar kernels.
    Scalar,
    /// Force AVX2; resolution errors on non-x86_64 builds or CPUs
    /// without AVX2.
    Avx2,
    /// Force NEON; resolution errors on non-aarch64 builds.
    Neon,
}

impl std::str::FromStr for DispatchChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(Self::Auto),
            "scalar" => Ok(Self::Scalar),
            "avx2" => Ok(Self::Avx2),
            "neon" => Ok(Self::Neon),
            other => {
                anyhow::bail!("unknown kernel dispatch {other:?} (want auto|scalar|avx2|neon)")
            }
        }
    }
}

impl fmt::Display for DispatchChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DispatchChoice::Auto => "auto",
            DispatchChoice::Scalar => "scalar",
            DispatchChoice::Avx2 => "avx2",
            DispatchChoice::Neon => "neon",
        })
    }
}

/// One resolved kernel path: plain function pointers for every hot
/// kernel, resolved once and held as `&'static KernelOps` by the
/// packed weights and the batched stepper (no per-call-site feature
/// branching in the tick loop).
///
/// All entries obey the `nn::kernels` determinism policy and are
/// bitwise-interchangeable across tables (pinned in
/// `tests/simd_equiv.rs`); only their speed differs.
pub struct KernelOps {
    /// Which path this table runs (for metrics / logs / bench JSON).
    pub path: DispatchPath,
    /// Dot product, 8 split accumulators + fixed pairwise-tree reduce.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// Squared Euclidean distance, same accumulator discipline.
    pub sqdist: fn(&[f32], &[f32]) -> f32,
    /// `y += a * x`, elementwise.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// `y += x`, elementwise.
    pub add_assign: fn(&mut [f32], &[f32]),
    /// Fused matmul+bias row sweep over a packed (transposed) weight:
    /// `(x, wt, bias, out)` with `wt` laid out `out.len()` rows of
    /// `x.len()` contiguous weights; `out[j] = dot(x, wt_row_j) +
    /// bias[j]`. Monolithic on purpose — one indirect call per *row
    /// sweep*, not per output dot.
    pub linear_forward: fn(&[f32], &[f32], &[f32], &mut [f32]),
    /// Scaled dot scores of one query head over a two-segment K view.
    pub dot_scores_segments: fn(&[f32], &[f32], &[f32], f32, &mut [f32]),
    /// SOFT (Gaussian-kernel) scores over a two-segment K view.
    pub soft_scores_segments: fn(&[f32], &[f32], &[f32], f32, &mut [f32]),
    /// `out += Σ_j weights[j] * v_j` over a two-segment V view.
    pub weighted_sum_segments: fn(&[f32], &[f32], &[f32], &mut [f32]),
    /// RoPE-rotate every `dh`-wide head chunk of one stacked row with
    /// a precomputed sin/cos row: `(row, dh, sin, cos)`.
    pub rope_rotate_row: fn(&mut [f32], usize, &[f32], &[f32]),
}

impl fmt::Debug for KernelOps {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KernelOps").field("path", &self.path).finish_non_exhaustive()
    }
}

impl PartialEq for KernelOps {
    fn eq(&self, other: &Self) -> bool {
        self.path == other.path
    }
}

/// The PR 4 scalar kernels as a dispatch table (the fallback every
/// build has).
static SCALAR_OPS: KernelOps = KernelOps {
    path: DispatchPath::Scalar,
    dot: kernels::dot,
    sqdist: kernels::sqdist,
    axpy: kernels::axpy,
    add_assign: kernels::add_assign,
    linear_forward: linear_forward_scalar,
    dot_scores_segments: kernels::dot_scores_segments,
    soft_scores_segments: kernels::soft_scores_segments,
    weighted_sum_segments: kernels::weighted_sum_segments,
    rope_rotate_row: rope::apply_rope_row,
};

/// Scalar packed-linear row sweep: each output element one contiguous
/// 8-wide [`kernels::dot`] plus its bias (the op sequence
/// `PackedLinear` has always run).
fn linear_forward_scalar(x: &[f32], wt: &[f32], bias: &[f32], out: &mut [f32]) {
    let k = x.len().max(1);
    debug_assert_eq!(wt.len(), x.len() * out.len());
    debug_assert_eq!(bias.len(), out.len());
    for ((o, wrow), b) in out.iter_mut().zip(wt.chunks_exact(k)).zip(bias) {
        *o = kernels::dot(x, wrow) + b;
    }
}

impl KernelOps {
    /// The scalar table — always available, never consults the
    /// environment.
    pub fn scalar() -> &'static KernelOps {
        &SCALAR_OPS
    }

    /// The best path this CPU supports, detected once and cached
    /// (`OnceLock`). Ignores [`DISPATCH_ENV`] — this is raw hardware
    /// capability, not policy.
    pub fn native() -> &'static KernelOps {
        static NATIVE: OnceLock<&'static KernelOps> = OnceLock::new();
        *NATIVE.get_or_init(|| {
            #[cfg(target_arch = "x86_64")]
            if std::is_x86_feature_detected!("avx2") {
                return &avx2::OPS;
            }
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &neon::OPS;
            }
            &SCALAR_OPS
        })
    }

    /// Resolve a dispatch choice to a table. Explicit choices win and
    /// fail loudly when the build/CPU cannot honor them; `Auto`
    /// consults [`DISPATCH_ENV`] (whose value may itself force a path
    /// or fail parsing) and otherwise returns [`KernelOps::native`].
    pub fn resolve(choice: DispatchChoice) -> Result<&'static KernelOps> {
        let effective = match choice {
            DispatchChoice::Auto => match std::env::var(DISPATCH_ENV) {
                Ok(v) => v
                    .parse::<DispatchChoice>()
                    .map_err(|e| anyhow::anyhow!("${DISPATCH_ENV}: {e}"))?,
                Err(_) => DispatchChoice::Auto,
            },
            explicit => explicit,
        };
        match effective {
            DispatchChoice::Auto => Ok(Self::native()),
            DispatchChoice::Scalar => Ok(&SCALAR_OPS),
            DispatchChoice::Avx2 => resolve_avx2(),
            DispatchChoice::Neon => resolve_neon(),
        }
    }

    /// [`KernelOps::resolve`]`(Auto)` for infallible construction
    /// paths (`ModelParams::pack`, `BatchedScalarDeepCoT::with_lanes`).
    /// Panics with the resolution error when [`DISPATCH_ENV`] is set
    /// to garbage or forces an unsupported path — a misconfigured
    /// override must not silently run a different path than asked.
    pub fn auto() -> &'static KernelOps {
        Self::resolve(DispatchChoice::Auto).unwrap_or_else(|e| panic!("kernel dispatch: {e}"))
    }
}

fn resolve_avx2() -> Result<&'static KernelOps> {
    #[cfg(target_arch = "x86_64")]
    if std::is_x86_feature_detected!("avx2") {
        return Ok(&avx2::OPS);
    }
    anyhow::bail!(
        "kernel dispatch forced to avx2, but this build/CPU does not support it (arch {})",
        std::env::consts::ARCH
    )
}

fn resolve_neon() -> Result<&'static KernelOps> {
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return Ok(&neon::OPS);
    }
    anyhow::bail!(
        "kernel dispatch forced to neon, but this build/CPU does not support it (arch {})",
        std::env::consts::ARCH
    )
}

/// Human/JSON-friendly `arch/feat+feat+...` summary of the detected
/// CPU features relevant to dispatch — recorded next to every
/// `bench_kernels --json` row so a number is never divorced from the
/// hardware that produced it.
pub fn cpu_features() -> String {
    let mut feats: Vec<&'static str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("sse2") {
            feats.push("sse2");
        }
        if std::is_x86_feature_detected!("avx") {
            feats.push("avx");
        }
        if std::is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if std::is_x86_feature_detected!("fma") {
            feats.push("fma");
        }
        if std::is_x86_feature_detected!("avx512f") {
            feats.push("avx512f");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            feats.push("neon");
        }
    }
    if feats.is_empty() {
        feats.push("none-detected");
    }
    format!("{}/{}", std::env::consts::ARCH, feats.join("+"))
}

// ---------------------------------------------------------------------
// AVX2 (x86_64)

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! 256-bit AVX2 kernels. The 8 scalar split accumulators ARE the 8
    //! f32 lanes of one `__m256`; updates are `_mm256_add_ps ∘
    //! _mm256_mul_ps` (per-lane IEEE mul then add — exactly the scalar
    //! `acc[j] += x*y`, and deliberately not `_mm256_fmadd_ps`), the
    //! accumulator spills to a `[f32; 8]`, remainders run the scalar
    //! remainder code, and the reduction is the shared scalar pairwise
    //! tree. See the module docs for why each step is bitwise-forced.
    //!
    //! SAFETY: every `unsafe fn` here requires AVX2; the safe wrappers
    //! are reachable only through [`OPS`], which `KernelOps::resolve` /
    //! `native` hand out strictly behind
    //! `is_x86_feature_detected!("avx2")`.

    use core::arch::x86_64::*;

    use super::{DispatchPath, KernelOps};
    use crate::nn::kernels::{reduce, UNROLL};

    pub(super) static OPS: KernelOps = KernelOps {
        path: DispatchPath::Avx2,
        dot,
        sqdist,
        axpy,
        add_assign,
        linear_forward,
        dot_scores_segments,
        soft_scores_segments,
        weighted_sum_segments,
        rope_rotate_row,
    };

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl(a, b) }
    }

    fn sqdist(a: &[f32], b: &[f32]) -> f32 {
        unsafe { sqdist_impl(a, b) }
    }

    fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe { axpy_impl(a, x, y) }
    }

    fn add_assign(y: &mut [f32], x: &[f32]) {
        unsafe { add_assign_impl(y, x) }
    }

    fn linear_forward(x: &[f32], wt: &[f32], bias: &[f32], out: &mut [f32]) {
        unsafe { linear_forward_impl(x, wt, bias, out) }
    }

    fn dot_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
        unsafe { dot_scores_impl(q, seg_a, seg_b, scale, out) }
    }

    fn soft_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
        unsafe { soft_scores_impl(q, seg_a, seg_b, scale, out) }
    }

    fn weighted_sum_segments(weights: &[f32], seg_a: &[f32], seg_b: &[f32], out: &mut [f32]) {
        unsafe { weighted_sum_impl(weights, seg_a, seg_b, out) }
    }

    fn rope_rotate_row(row: &mut [f32], dh: usize, sin: &[f32], cos: &[f32]) {
        unsafe { rope_rotate_row_impl(row, dh, sin, cos) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / UNROLL;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * UNROLL));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * UNROLL));
            // mul then add — NOT fmadd (single rounding would change bits)
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; UNROLL];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for j in 0..n % UNROLL {
            lanes[j] += a[chunks * UNROLL + j] * b[chunks * UNROLL + j];
        }
        reduce(lanes)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn sqdist_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / UNROLL;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * UNROLL));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * UNROLL));
            let d = _mm256_sub_ps(va, vb);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
        }
        let mut lanes = [0.0f32; UNROLL];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for j in 0..n % UNROLL {
            let d = a[chunks * UNROLL + j] - b[chunks * UNROLL + j];
            lanes[j] += d * d;
        }
        reduce(lanes)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_impl(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / UNROLL;
        let va = _mm256_set1_ps(a);
        for i in 0..chunks {
            let p = y.as_mut_ptr().add(i * UNROLL);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i * UNROLL));
            let vy = _mm256_loadu_ps(p);
            _mm256_storeu_ps(p, _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
        }
        for j in chunks * UNROLL..n {
            y[j] += a * x[j];
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn add_assign_impl(y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / UNROLL;
        for i in 0..chunks {
            let p = y.as_mut_ptr().add(i * UNROLL);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i * UNROLL));
            let vy = _mm256_loadu_ps(p);
            _mm256_storeu_ps(p, _mm256_add_ps(vy, vx));
        }
        for j in chunks * UNROLL..n {
            y[j] += x[j];
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn linear_forward_impl(x: &[f32], wt: &[f32], bias: &[f32], out: &mut [f32]) {
        let k = x.len().max(1);
        debug_assert_eq!(wt.len(), x.len() * out.len());
        debug_assert_eq!(bias.len(), out.len());
        for ((o, wrow), b) in out.iter_mut().zip(wt.chunks_exact(k)).zip(bias) {
            *o = dot_impl(x, wrow) + b;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_scores_impl(
        q: &[f32],
        seg_a: &[f32],
        seg_b: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        let dh = q.len().max(1);
        debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for krow in seg.chunks_exact(dh) {
                out[idx] = dot_impl(q, krow) * scale;
                idx += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn soft_scores_impl(
        q: &[f32],
        seg_a: &[f32],
        seg_b: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        let dh = q.len().max(1);
        debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for krow in seg.chunks_exact(dh) {
                out[idx] = (-sqdist_impl(q, krow) * 0.5 * scale).exp();
                idx += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn weighted_sum_impl(weights: &[f32], seg_a: &[f32], seg_b: &[f32], out: &mut [f32]) {
        let dh = out.len().max(1);
        debug_assert_eq!(weights.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for vrow in seg.chunks_exact(dh) {
                axpy_impl(weights[idx], vrow, out);
                idx += 1;
            }
        }
    }

    /// Four interleaved (even, odd) pairs per 256-bit op. `sin`/`cos`
    /// hold one value per pair, so each 128-bit load of 4 values is
    /// expanded to `[c0,c0,c1,c1,c2,c2,c3,c3]` via a cross-lane
    /// permute. `_mm256_addsub_ps(t1, t2)` then yields
    /// `e·cos − o·sin` on even lanes (the exact scalar op order) and
    /// `o·cos + e·sin` on odd lanes (addition commuted vs the scalar
    /// `e·sin + o·cos` — bitwise-identical for finite f32).
    #[target_feature(enable = "avx2")]
    unsafe fn rope_rotate_row_impl(row: &mut [f32], dh: usize, sin: &[f32], cos: &[f32]) {
        let half = dh / 2;
        debug_assert_eq!(half * 2, dh);
        debug_assert!(sin.len() >= half && cos.len() >= half);
        let expand = _mm256_setr_epi32(0, 0, 1, 1, 2, 2, 3, 3);
        for chunk in row.chunks_exact_mut(dh) {
            let vec_pairs = half / 4;
            for i in 0..vec_pairs {
                let p = chunk.as_mut_ptr().add(i * 8);
                // x = [e0,o0,e1,o1,e2,o2,e3,o3]
                let x = _mm256_loadu_ps(p);
                let c4 = _mm_loadu_ps(cos.as_ptr().add(i * 4));
                let s4 = _mm_loadu_ps(sin.as_ptr().add(i * 4));
                let c = _mm256_permutevar8x32_ps(_mm256_set_m128(c4, c4), expand);
                let s = _mm256_permutevar8x32_ps(_mm256_set_m128(s4, s4), expand);
                // swapped = [o0,e0,o1,e1,...] (within-lane pair swap)
                let swapped = _mm256_permute_ps::<0b1011_0001>(x);
                let t1 = _mm256_mul_ps(x, c); // [e·c, o·c, ...]
                let t2 = _mm256_mul_ps(swapped, s); // [o·s, e·s, ...]
                _mm256_storeu_ps(p, _mm256_addsub_ps(t1, t2));
            }
            // remainder pairs (half % 4): the exact scalar op sequence
            for i in vec_pairs * 4..half {
                let e = chunk[2 * i];
                let o = chunk[2 * i + 1];
                chunk[2 * i] = e * cos[i] - o * sin[i];
                chunk[2 * i + 1] = e * sin[i] + o * cos[i];
            }
        }
    }
}

// ---------------------------------------------------------------------
// NEON (aarch64)

#[cfg(target_arch = "aarch64")]
mod neon {
    //! 128-bit NEON kernels. The 8 scalar split accumulators map onto
    //! a register pair — lanes 0..3 in `acc_lo`, lanes 4..7 in
    //! `acc_hi` — updated with `vaddq_f32 ∘ vmulq_f32` (never
    //! `vfmaq_f32`: fused rounding would change bits), spilled to a
    //! `[f32; 8]` and reduced by the shared scalar pairwise tree.
    //!
    //! SAFETY: the safe wrappers are reachable only through [`OPS`],
    //! which `KernelOps::resolve` / `native` hand out strictly behind
    //! `is_aarch64_feature_detected!("neon")`.

    use core::arch::aarch64::*;

    use super::{DispatchPath, KernelOps};
    use crate::nn::kernels::{reduce, UNROLL};

    pub(super) static OPS: KernelOps = KernelOps {
        path: DispatchPath::Neon,
        dot,
        sqdist,
        axpy,
        add_assign,
        linear_forward,
        dot_scores_segments,
        soft_scores_segments,
        weighted_sum_segments,
        rope_rotate_row,
    };

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl(a, b) }
    }

    fn sqdist(a: &[f32], b: &[f32]) -> f32 {
        unsafe { sqdist_impl(a, b) }
    }

    fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe { axpy_impl(a, x, y) }
    }

    fn add_assign(y: &mut [f32], x: &[f32]) {
        unsafe { add_assign_impl(y, x) }
    }

    fn linear_forward(x: &[f32], wt: &[f32], bias: &[f32], out: &mut [f32]) {
        unsafe { linear_forward_impl(x, wt, bias, out) }
    }

    fn dot_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
        unsafe { dot_scores_impl(q, seg_a, seg_b, scale, out) }
    }

    fn soft_scores_segments(q: &[f32], seg_a: &[f32], seg_b: &[f32], scale: f32, out: &mut [f32]) {
        unsafe { soft_scores_impl(q, seg_a, seg_b, scale, out) }
    }

    fn weighted_sum_segments(weights: &[f32], seg_a: &[f32], seg_b: &[f32], out: &mut [f32]) {
        unsafe { weighted_sum_impl(weights, seg_a, seg_b, out) }
    }

    fn rope_rotate_row(row: &mut [f32], dh: usize, sin: &[f32], cos: &[f32]) {
        unsafe { rope_rotate_row_impl(row, dh, sin, cos) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / UNROLL;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let pa = a.as_ptr().add(i * UNROLL);
            let pb = b.as_ptr().add(i * UNROLL);
            // mul then add — NOT vfmaq (single rounding would change bits)
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc_hi = vaddq_f32(acc_hi, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let mut lanes = [0.0f32; UNROLL];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        for j in 0..n % UNROLL {
            lanes[j] += a[chunks * UNROLL + j] * b[chunks * UNROLL + j];
        }
        reduce(lanes)
    }

    #[target_feature(enable = "neon")]
    unsafe fn sqdist_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / UNROLL;
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let pa = a.as_ptr().add(i * UNROLL);
            let pb = b.as_ptr().add(i * UNROLL);
            let d_lo = vsubq_f32(vld1q_f32(pa), vld1q_f32(pb));
            let d_hi = vsubq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4)));
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(d_lo, d_lo));
            acc_hi = vaddq_f32(acc_hi, vmulq_f32(d_hi, d_hi));
        }
        let mut lanes = [0.0f32; UNROLL];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        for j in 0..n % UNROLL {
            let d = a[chunks * UNROLL + j] - b[chunks * UNROLL + j];
            lanes[j] += d * d;
        }
        reduce(lanes)
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_impl(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4;
        let va = vdupq_n_f32(a);
        for i in 0..chunks {
            let p = y.as_mut_ptr().add(i * 4);
            let vx = vld1q_f32(x.as_ptr().add(i * 4));
            vst1q_f32(p, vaddq_f32(vld1q_f32(p), vmulq_f32(va, vx)));
        }
        for j in chunks * 4..n {
            y[j] += a * x[j];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn add_assign_impl(y: &mut [f32], x: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let chunks = n / 4;
        for i in 0..chunks {
            let p = y.as_mut_ptr().add(i * 4);
            vst1q_f32(p, vaddq_f32(vld1q_f32(p), vld1q_f32(x.as_ptr().add(i * 4))));
        }
        for j in chunks * 4..n {
            y[j] += x[j];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn linear_forward_impl(x: &[f32], wt: &[f32], bias: &[f32], out: &mut [f32]) {
        let k = x.len().max(1);
        debug_assert_eq!(wt.len(), x.len() * out.len());
        debug_assert_eq!(bias.len(), out.len());
        for ((o, wrow), b) in out.iter_mut().zip(wt.chunks_exact(k)).zip(bias) {
            *o = dot_impl(x, wrow) + b;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_scores_impl(
        q: &[f32],
        seg_a: &[f32],
        seg_b: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        let dh = q.len().max(1);
        debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for krow in seg.chunks_exact(dh) {
                out[idx] = dot_impl(q, krow) * scale;
                idx += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn soft_scores_impl(
        q: &[f32],
        seg_a: &[f32],
        seg_b: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        let dh = q.len().max(1);
        debug_assert_eq!(out.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for krow in seg.chunks_exact(dh) {
                out[idx] = (-sqdist_impl(q, krow) * 0.5 * scale).exp();
                idx += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn weighted_sum_impl(weights: &[f32], seg_a: &[f32], seg_b: &[f32], out: &mut [f32]) {
        let dh = out.len().max(1);
        debug_assert_eq!(weights.len() * dh, seg_a.len() + seg_b.len());
        let mut idx = 0;
        for seg in [seg_a, seg_b] {
            for vrow in seg.chunks_exact(dh) {
                axpy_impl(weights[idx], vrow, out);
                idx += 1;
            }
        }
    }

    /// Four (even, odd) pairs per op via `vld2q_f32` deinterleaving;
    /// both output lanes run the exact scalar operand order
    /// (`e·cos − o·sin`, `e·sin + o·cos`), re-interleaved with
    /// `vst2q_f32`. Remainder pairs run the scalar code.
    #[target_feature(enable = "neon")]
    unsafe fn rope_rotate_row_impl(row: &mut [f32], dh: usize, sin: &[f32], cos: &[f32]) {
        let half = dh / 2;
        debug_assert_eq!(half * 2, dh);
        debug_assert!(sin.len() >= half && cos.len() >= half);
        for chunk in row.chunks_exact_mut(dh) {
            let vec_pairs = half / 4;
            for i in 0..vec_pairs {
                let p = chunk.as_mut_ptr().add(i * 8);
                let eo = vld2q_f32(p); // .0 = evens, .1 = odds
                let c = vld1q_f32(cos.as_ptr().add(i * 4));
                let s = vld1q_f32(sin.as_ptr().add(i * 4));
                let e2 = vsubq_f32(vmulq_f32(eo.0, c), vmulq_f32(eo.1, s));
                let o2 = vaddq_f32(vmulq_f32(eo.0, s), vmulq_f32(eo.1, c));
                vst2q_f32(p, float32x4x2_t(e2, o2));
            }
            for i in vec_pairs * 4..half {
                let e = chunk[2 * i];
                let o = chunk[2 * i + 1];
                chunk[2 * i] = e * cos[i] - o * sin[i];
                chunk[2 * i + 1] = e * sin[i] + o * cos[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses() {
        assert_eq!("auto".parse::<DispatchChoice>().unwrap(), DispatchChoice::Auto);
        assert_eq!("scalar".parse::<DispatchChoice>().unwrap(), DispatchChoice::Scalar);
        assert_eq!("AVX2".parse::<DispatchChoice>().unwrap(), DispatchChoice::Avx2);
        assert_eq!(" neon ".parse::<DispatchChoice>().unwrap(), DispatchChoice::Neon);
        assert!("sse9".parse::<DispatchChoice>().is_err());
        assert_eq!(DispatchChoice::default(), DispatchChoice::Auto);
        assert_eq!(DispatchChoice::Avx2.to_string(), "avx2");
    }

    #[test]
    fn scalar_table_runs_the_scalar_kernels() {
        let ops = KernelOps::scalar();
        assert_eq!(ops.path, DispatchPath::Scalar);
        assert_eq!((ops.dot)(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        let mut y = vec![1.0f32; 5];
        (ops.axpy)(2.0, &[1.0, 1.0, 1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0; 5]);
    }

    #[test]
    fn native_is_cached_and_resolvable() {
        let a = KernelOps::native();
        let b = KernelOps::native();
        assert!(std::ptr::eq(a, b), "native detection must be cached");
        // whatever native is, resolving its own path explicitly succeeds
        let explicit = match a.path {
            DispatchPath::Scalar => DispatchChoice::Scalar,
            DispatchPath::Avx2 => DispatchChoice::Avx2,
            DispatchPath::Neon => DispatchChoice::Neon,
        };
        assert_eq!(KernelOps::resolve(explicit).unwrap().path, a.path);
    }

    #[test]
    fn explicit_scalar_always_resolves() {
        let ops = KernelOps::resolve(DispatchChoice::Scalar).unwrap();
        assert_eq!(ops.path, DispatchPath::Scalar);
    }

    #[test]
    fn foreign_arch_force_fails_loudly() {
        // at most one of these can be the host arch; the other(s) must
        // error instead of silently falling back to scalar
        #[cfg(not(target_arch = "x86_64"))]
        assert!(KernelOps::resolve(DispatchChoice::Avx2).is_err());
        #[cfg(not(target_arch = "aarch64"))]
        assert!(KernelOps::resolve(DispatchChoice::Neon).is_err());
    }

    #[test]
    fn cpu_features_names_the_arch() {
        let f = cpu_features();
        assert!(f.starts_with(std::env::consts::ARCH), "{f}");
        assert!(f.contains('/'), "{f}");
    }

    #[test]
    fn debug_prints_path_only() {
        let s = format!("{:?}", KernelOps::scalar());
        assert!(s.contains("Scalar"), "{s}");
    }
}
