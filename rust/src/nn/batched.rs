//! Batched multi-lane continual stepping with zero steady-state
//! allocation — the scalar engine's answer to the coordinator's slot
//! batching.
//!
//! [`BatchedScalarDeepCoT`] steps `lanes` independent streams at once:
//! lane token rows are stacked into one `(lanes·m x d)` matrix so every
//! Q/K/V/FFN projection is a single shared-weight matmul, while
//! attention and the per-lane [`KvRing`] memories stay lane-local.
//! All intermediates live in a [`Scratch`] workspace allocated once at
//! construction; a steady-state tick performs no heap allocation and no
//! memory roll (see `tests/zero_alloc.rs`).
//!
//! Lane semantics mirror `coordinator::slot_stepper`: a lane masked out
//! of a tick keeps its K/V memory untouched — its stacked rows are
//! still computed (fixed batch shape, like the batched PJRT executable)
//! but discarded.
//!
//! Positions: every lane carries its own position clock. [`tick_all`]
//! uses (and advances) the internal per-lane clocks; [`tick_lanes`]
//! takes the caller's per-lane `pos` slice instead — the coordinator
//! owns stream clocks — so a stream admitted mid-run starts at position
//! 0 and sees exactly the RoPE phases it would have seen serving alone.
//! That per-stream determinism is what makes sharded serving
//! bitwise-reproducible across cluster layouts. A masked lane's clock
//! does not advance: a paused stream resumes where it left off
//! (session-consistent positions rather than wall-clock-consistent).
//!
//! [`tick_all`]: BatchedScalarDeepCoT::tick_all
//! [`tick_lanes`]: BatchedScalarDeepCoT::tick_lanes

use anyhow::Result;

use crate::manifest::ModelConfig;
use crate::nn::kernels::{residual_fused, PackedParams};
use crate::nn::kv_ring::KvRing;
use crate::nn::params::{ModelParams, Norm};
use crate::nn::rope::RopeTable;
use crate::nn::simd::{DispatchPath, KernelOps};
use crate::nn::tensor::{softmax_inplace, Mat};

/// Preallocated per-tick workspace, sized once from the model geometry.
#[derive(Debug, Clone)]
struct Scratch {
    /// Activations (lanes·m x d_model); holds the final layer output
    /// after a tick.
    x: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    /// Per-head attention outputs gathered back to (lanes·m x d_model).
    attn: Mat,
    /// Sub-layer output (attention projection, then FFN output).
    proj: Mat,
    /// FFN hidden activations (lanes·m x d_ffn).
    hid: Mat,
    /// Attention scores over [memory; new tokens] (mem_len + m).
    scores: Vec<f32>,
    /// Per-lane logits (lanes x n_classes).
    logits: Mat,
    /// Which lanes advance this tick.
    live: Vec<bool>,
    /// Per-lane position of the first new token this tick.
    pos: Vec<i32>,
}

impl Scratch {
    fn new(cfg: &ModelConfig, lanes: usize) -> Self {
        let rows = lanes * cfg.m_tokens;
        let d = cfg.d_model;
        Self {
            x: Mat::zeros(rows, d),
            q: Mat::zeros(rows, d),
            k: Mat::zeros(rows, d),
            v: Mat::zeros(rows, d),
            attn: Mat::zeros(rows, d),
            proj: Mat::zeros(rows, d),
            hid: Mat::zeros(rows, cfg.d_ffn()),
            scores: vec![0.0; cfg.mem_len() + cfg.m_tokens],
            logits: Mat::zeros(lanes, cfg.n_classes),
            live: vec![true; lanes],
            pos: vec![0; lanes],
        }
    }
}

/// Borrowed per-tick outputs (valid until the next mutation).
pub struct StepOut<'a> {
    /// (lanes x n_classes)
    pub logits: &'a Mat,
    /// (lanes·m x d_model) final-layer activations, lane-major.
    pub out: &'a Mat,
}

/// Multi-lane continual DeepCoT stepper over ring-buffer K/V memories.
///
/// The tick runs on the `nn::kernels` suite: all projections go through
/// packed fused matmul+bias ([`PackedParams`], packed once at
/// construction), attention iterates the rings' two-segment contiguous
/// views with 8-wide unrolled kernels, RoPE rows come from a memoized
/// [`RopeTable`], and the residual/norm epilogues are fused row sweeps.
/// Every kernel uses a fixed summation order independent of lane count
/// and ring alignment (see `nn::kernels` docs), so a lane's outputs
/// stay a pure bitwise function of its own stream history — the
/// invariant the sharded cluster and migration tests pin.
pub struct BatchedScalarDeepCoT {
    cfg: ModelConfig,
    /// Per-layer residual-norm parameters — the only piece of the
    /// source [`ModelParams`] the tick still reads. The naive-layout
    /// weight matrices are dropped after packing so a stepper holds
    /// each weight exactly once.
    norms: Vec<Norm>,
    /// Transposed, bias-fused projections (the load-time packing pass).
    packed: PackedParams,
    /// Memoized per-position RoPE sin/cos rows, one slot per stacked
    /// token row.
    rope: RopeTable,
    lanes: usize,
    /// Ring per (lane, layer, head): index `(lane·L + layer)·H + head`.
    kmem: Vec<KvRing>,
    vmem: Vec<KvRing>,
    scratch: Scratch,
    /// Internal per-lane position clocks, used and advanced by
    /// [`Self::tick_all`] only; `tick_lanes` callers own their clocks.
    lane_pos: Vec<i32>,
    /// Kernel path resolved once at construction; every hot-tick kernel
    /// routes through this table (no per-call-site feature branching).
    ops: &'static KernelOps,
}

impl BatchedScalarDeepCoT {
    /// One lane per configured batch slot.
    pub fn new(cfg: ModelConfig, p: ModelParams) -> Self {
        let lanes = cfg.batch.max(1);
        Self::with_lanes(cfg, p, lanes)
    }

    /// [`Self::with_lanes_ops`] under
    /// [`DispatchChoice::Auto`](crate::nn::simd::DispatchChoice) (env
    /// override, else the best native path).
    pub fn with_lanes(cfg: ModelConfig, p: ModelParams, lanes: usize) -> Self {
        Self::with_lanes_ops(cfg, p, lanes, KernelOps::auto())
    }

    /// Construct on an explicit, already-resolved kernel path. Dispatch
    /// is bitwise-invisible (every path satisfies the `nn::kernels`
    /// fixed-summation-order policy), so instances built on different
    /// paths are freely interchangeable — snapshots migrate between
    /// them without perturbing stream bits.
    pub fn with_lanes_ops(
        cfg: ModelConfig,
        p: ModelParams,
        lanes: usize,
        ops: &'static KernelOps,
    ) -> Self {
        assert!(lanes > 0, "need at least one lane");
        let (l, h, mlen, dh) = (cfg.n_layers, cfg.n_heads, cfg.mem_len(), cfg.d_head());
        let n = lanes * l * h;
        let kmem = (0..n).map(|_| KvRing::new(mlen, dh)).collect();
        let vmem = (0..n).map(|_| KvRing::new(mlen, dh)).collect();
        let scratch = Scratch::new(&cfg, lanes);
        // load-time packing + rope-row memo storage: both sized once
        // here so steady-state ticks never allocate. Only the norm
        // parameters survive from the naive layout — the packed copy
        // is the single resident set of projection weights.
        let packed = p.pack_with(ops);
        let norms = p.layers.iter().map(|lp| lp.norm.clone()).collect();
        let rope = RopeTable::new(dh, lanes * cfg.m_tokens);
        Self {
            cfg,
            norms,
            packed,
            rope,
            lanes,
            kmem,
            vmem,
            scratch,
            lane_pos: vec![0; lanes],
            ops,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The kernel path this stepper's tick runs on.
    pub fn dispatch(&self) -> DispatchPath {
        self.ops.path
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Cold-start every lane and rewind every clock.
    pub fn reset(&mut self) {
        for r in self.kmem.iter_mut().chain(self.vmem.iter_mut()) {
            r.reset();
        }
        self.lane_pos.fill(0);
    }

    /// Cold-start one lane (slot released / new stream admitted): its
    /// K/V memory and its position clock restart from zero; other lanes
    /// are untouched.
    pub fn reset_lane(&mut self, lane: usize) {
        assert!(lane < self.lanes);
        let per_lane = self.cfg.n_layers * self.cfg.n_heads;
        for i in lane * per_lane..(lane + 1) * per_lane {
            self.kmem[i].reset();
            self.vmem[i].reset();
        }
        self.lane_pos[lane] = 0;
    }

    /// Position clock of one lane (the RoPE phase its next token gets
    /// under [`Self::tick_all`]).
    pub fn lane_pos(&self, lane: usize) -> i32 {
        self.lane_pos[lane]
    }

    /// Rings per lane snapshot (K rings + V rings, one per layer/head).
    pub fn rings_per_lane(&self) -> usize {
        2 * self.cfg.n_layers * self.cfg.n_heads
    }

    /// f32 elements in one lane's full K/V snapshot.
    pub fn floats_per_lane(&self) -> usize {
        self.rings_per_lane() * self.cfg.mem_len() * self.cfg.d_head()
    }

    /// Copy one lane's K/V memory into flat snapshot buffers: `data`
    /// receives the raw ring storage (all K rings in layer-major
    /// `(layer, head)` order, then all V rings) and `heads` the
    /// per-ring write-head indices. Both buffers are cleared and
    /// refilled — reusing them across exports performs no heap
    /// allocation once their capacity is established, so a migration
    /// path can snapshot lanes without perturbing the zero-alloc
    /// steady state.
    pub fn export_lane(&self, lane: usize, data: &mut Vec<f32>, heads: &mut Vec<usize>) {
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        data.clear();
        heads.clear();
        let per_lane = self.cfg.n_layers * self.cfg.n_heads;
        let lo = lane * per_lane;
        for ring in self.kmem[lo..lo + per_lane].iter().chain(&self.vmem[lo..lo + per_lane]) {
            data.extend_from_slice(ring.raw());
            heads.push(ring.head());
        }
    }

    /// Restore one lane's K/V memory from an [`Self::export_lane`]
    /// snapshot (possibly taken on a different instance with the same
    /// geometry). The restored lane ticks bit-for-bit identically to
    /// the exported one. Errors on a geometry mismatch; the lane is
    /// untouched in that case.
    pub fn import_lane(&mut self, lane: usize, data: &[f32], heads: &[usize]) -> Result<()> {
        anyhow::ensure!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        anyhow::ensure!(
            heads.len() == self.rings_per_lane(),
            "snapshot has {} rings, lane expects {}",
            heads.len(),
            self.rings_per_lane()
        );
        anyhow::ensure!(
            data.len() == self.floats_per_lane(),
            "snapshot has {} floats, lane expects {}",
            data.len(),
            self.floats_per_lane()
        );
        let rows = self.cfg.mem_len();
        for (i, &head) in heads.iter().enumerate() {
            anyhow::ensure!(
                head < rows || (rows == 0 && head == 0),
                "snapshot ring {i} head {head} out of range ({rows} rows)"
            );
        }
        let ring_elems = rows * self.cfg.d_head();
        let per_lane = self.cfg.n_layers * self.cfg.n_heads;
        let lo = lane * per_lane;
        let rings = self.kmem[lo..lo + per_lane]
            .iter_mut()
            .chain(&mut self.vmem[lo..lo + per_lane]);
        for (i, ring) in rings.enumerate() {
            ring.restore(&data[i * ring_elems..(i + 1) * ring_elems], heads[i]);
        }
        Ok(())
    }

    fn check_tokens(&self, tokens: &Mat) -> Result<()> {
        anyhow::ensure!(
            tokens.rows == self.lanes * self.cfg.m_tokens && tokens.cols == self.cfg.d_in,
            "tokens ({} x {}) != (lanes*m = {} x d_in = {})",
            tokens.rows,
            tokens.cols,
            self.lanes * self.cfg.m_tokens,
            self.cfg.d_in
        );
        Ok(())
    }

    /// Step every lane on the internal per-lane clocks (each advances
    /// by m_tokens). `tokens` is (lanes·m x d_in), lane-major.
    pub fn tick_all(&mut self, tokens: &Mat) -> Result<StepOut<'_>> {
        self.check_tokens(tokens)?;
        self.scratch.live.fill(true);
        self.scratch.pos.copy_from_slice(&self.lane_pos);
        let m = self.cfg.m_tokens as i32;
        for p in self.lane_pos.iter_mut() {
            *p += m;
        }
        self.step(tokens)
    }

    /// Step with a lane mask and caller-owned per-lane position clocks:
    /// `pos[lane]` is the position of that lane's first new token this
    /// tick. Masked lanes keep their K/V memory and their outputs are
    /// garbage (callers drop them) — the scalar twin of the slot
    /// stepper's masked-lane semantics. The internal clocks are not
    /// consulted or advanced; the caller advances `pos[lane]` by
    /// m_tokens for each lane it ticked live.
    pub fn tick_lanes(&mut self, tokens: &Mat, live: &[bool], pos: &[i32]) -> Result<StepOut<'_>> {
        self.check_tokens(tokens)?;
        anyhow::ensure!(
            live.len() == self.lanes,
            "live mask {} != lanes {}",
            live.len(),
            self.lanes
        );
        anyhow::ensure!(
            pos.len() == self.lanes,
            "pos clocks {} != lanes {}",
            pos.len(),
            self.lanes
        );
        self.scratch.live.copy_from_slice(live);
        self.scratch.pos.copy_from_slice(pos);
        self.step(tokens)
    }

    fn step(&mut self, tokens: &Mat) -> Result<StepOut<'_>> {
        let lanes = self.lanes;
        let (m, h, dh, mlen) =
            (self.cfg.m_tokens, self.cfg.n_heads, self.cfg.d_head(), self.cfg.mem_len());
        let use_rope = self.cfg.pos == "rope";
        let softmax = self.cfg.activation == "softmax";
        let gelu_act = self.cfg.ffn_act == "gelu";
        let n_layers = self.norms.len();
        let norms = &self.norms;
        let pk = &self.packed;
        let ops = self.ops;
        let Scratch { x, q, k, v, attn, proj, hid, scores, logits, live, pos } = &mut self.scratch;

        pk.w_in.forward_into(tokens, x);
        let scale = 1.0 / (dh as f32).sqrt();
        let n_ctx = mlen + m;
        for (li, (norm, pl)) in norms.iter().zip(&pk.layers).enumerate() {
            pl.wq.forward_into(x, q);
            pl.wk.forward_into(x, k);
            pl.wv.forward_into(x, v);
            if use_rope {
                for row in 0..lanes * m {
                    let pp = pos[row / m] + (row % m) as i32;
                    // one memoized sin/cos row per token, shared by Q
                    // and K across every head; layers 1.. hit the memo
                    // (position unchanged within a tick), as do masked
                    // lanes across ticks (their clocks don't advance)
                    let (sin, cos) = self.rope.row(row, pp);
                    (ops.rope_rotate_row)(q.row_mut(row), dh, sin, cos);
                    (ops.rope_rotate_row)(k.row_mut(row), dh, sin, cos);
                }
            }
            attn.fill(0.0);
            for lane in 0..lanes {
                if !live[lane] {
                    continue;
                }
                for hh in 0..h {
                    let ridx = (lane * n_layers + li) * h + hh;
                    // two-segment contiguous views: attention becomes
                    // tight loops over at most two flat slices instead
                    // of per-row iterator dispatch
                    let (ka, kb) = self.kmem[ridx].as_segments();
                    let (va, vb) = self.vmem[ridx].as_segments();
                    for t in 0..m {
                        let row = lane * m + t;
                        let s = &mut scores[..n_ctx];
                        let qh = &q.row(row)[hh * dh..(hh + 1) * dh];
                        // scores over [memory oldest..newest; new rows],
                        // the exact logical order of the old
                        // [memory; new] concatenation
                        if softmax {
                            (ops.dot_scores_segments)(qh, ka, kb, scale, &mut s[..mlen]);
                            for j in 0..m {
                                let kh = &k.row(lane * m + j)[hh * dh..(hh + 1) * dh];
                                s[mlen + j] = (ops.dot)(qh, kh) * scale;
                            }
                            softmax_inplace(s);
                        } else {
                            // SOFT (paper Eq. 4): unnormalized Gaussian
                            (ops.soft_scores_segments)(qh, ka, kb, scale, &mut s[..mlen]);
                            for j in 0..m {
                                let kh = &k.row(lane * m + j)[hh * dh..(hh + 1) * dh];
                                s[mlen + j] = (-(ops.sqdist)(qh, kh) * 0.5 * scale).exp();
                            }
                        }
                        let orow = &mut attn.row_mut(row)[hh * dh..(hh + 1) * dh];
                        (ops.weighted_sum_segments)(&s[..mlen], va, vb, orow);
                        for j in 0..m {
                            let vrow = &v.row(lane * m + j)[hh * dh..(hh + 1) * dh];
                            (ops.axpy)(s[mlen + j], vrow, orow);
                        }
                    }
                    // advance the ring: the m new rows overwrite the m
                    // oldest — no copy_within, no reallocation
                    let kring = &mut self.kmem[ridx];
                    for t in 0..m {
                        kring.push(&k.row(lane * m + t)[hh * dh..(hh + 1) * dh]);
                    }
                    let vring = &mut self.vmem[ridx];
                    for t in 0..m {
                        vring.push(&v.row(lane * m + t)[hh * dh..(hh + 1) * dh]);
                    }
                }
            }
            pl.wo.forward_into(attn, proj);
            residual_fused(ops, norm, x, proj, 0);
            // FFN up-projection with the GELU applied in-row
            if gelu_act {
                pl.w1.forward_gelu_into(x, hid);
            } else {
                pl.w1.forward_into(x, hid);
            }
            pl.w2.forward_into(hid, proj);
            residual_fused(ops, norm, x, proj, 1);
        }
        // classifier head on each lane's newest token (bias added after
        // the completed product sum, like the naive matmul + add_row)
        for lane in 0..lanes {
            pk.w_cls.forward_row_into(x.row(lane * m + m - 1), logits.row_mut(lane));
        }
        Ok(StepOut { logits, out: x })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A lane exported mid-history and imported into a different lane
    /// of a fresh instance must keep producing bitwise-identical
    /// outputs — the property live migration is built on.
    #[test]
    fn lane_snapshot_roundtrips_bitwise() {
        let cfg = ModelConfig::synthetic(16, 2, 2, 6);
        let p = ModelParams::synthetic(&cfg, &mut Rng::new(7));
        let d_in = cfg.d_in;
        let mut a = BatchedScalarDeepCoT::with_lanes(cfg.clone(), p.clone(), 2);
        let mut rng = Rng::new(99);
        for _ in 0..5 {
            let toks = Mat::from_vec(2, d_in, rng.normal_vec(2 * d_in, 1.0));
            a.tick_all(&toks).unwrap();
        }
        let (mut data, mut heads) = (Vec::new(), Vec::new());
        a.export_lane(1, &mut data, &mut heads);
        assert_eq!(heads.len(), a.rings_per_lane());
        assert_eq!(data.len(), a.floats_per_lane());
        let pos = a.lane_pos(1);
        let mut b = BatchedScalarDeepCoT::with_lanes(cfg.clone(), p, 2);
        b.import_lane(0, &data, &heads).unwrap();
        // the same next token on A lane 1 and B lane 0 must agree bitwise
        let tok = rng.normal_vec(d_in, 1.0);
        let mut atoks = Mat::zeros(2, d_in);
        atoks.row_mut(1).copy_from_slice(&tok);
        let mut btoks = Mat::zeros(2, d_in);
        btoks.row_mut(0).copy_from_slice(&tok);
        let (la, oa) = {
            let s = a.tick_lanes(&atoks, &[false, true], &[0, pos]).unwrap();
            (s.logits.row(1).to_vec(), s.out.row(1).to_vec())
        };
        let (lb, ob) = {
            let s = b.tick_lanes(&btoks, &[true, false], &[pos, 0]).unwrap();
            (s.logits.row(0).to_vec(), s.out.row(0).to_vec())
        };
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&la), bits(&lb), "logits diverged after snapshot import");
        assert_eq!(bits(&oa), bits(&ob), "activations diverged after snapshot import");
    }

    #[test]
    fn import_rejects_geometry_mismatch() {
        let cfg = ModelConfig::synthetic(16, 2, 2, 6);
        let p = ModelParams::synthetic(&cfg, &mut Rng::new(7));
        let mut m = BatchedScalarDeepCoT::with_lanes(cfg, p, 1);
        let (mut data, mut heads) = (Vec::new(), Vec::new());
        m.export_lane(0, &mut data, &mut heads);
        assert!(m.import_lane(0, &data[1..], &heads).is_err(), "short data must fail");
        assert!(m.import_lane(0, &data, &heads[1..]).is_err(), "short heads must fail");
        let mut bad = heads.clone();
        bad[0] = 999;
        assert!(m.import_lane(0, &data, &bad).is_err(), "head out of range must fail");
        m.import_lane(0, &data, &heads).unwrap();
    }
}
