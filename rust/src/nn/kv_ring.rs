//! Circular Key/Value memory with fixed backing storage.
//!
//! The continual stepper keeps, per layer per head (per lane), the last
//! `mem_len` K and V rows. The pre-refactor implementation stored them
//! flat and advanced time with `copy_within` (an O(mem_len · d_head)
//! shuffle per head per layer per tick) plus a fresh `[memory; new]`
//! concatenation for attention. [`KvRing`] replaces both: storage never
//! moves, a head index advances instead, and attention iterates the
//! ring in logical (oldest → newest) order via [`KvRing::iter_rows`] —
//! the same circular-buffer design the Continual Transformers line of
//! work uses for stateful KV caches.
//!
//! Semantics match the engine's cold-start convention: the ring is born
//! logically *full of zero rows* (a cold memory attends over zeros,
//! exactly like the zero-initialized flat memory it replaces), and each
//! [`KvRing::push`] overwrites the oldest row with the newest.

/// Fixed-capacity circular buffer of `rows` vectors of width `dh`.
#[derive(Debug, Clone)]
pub struct KvRing {
    rows: usize,
    dh: usize,
    /// Physical index of the oldest logical row (== next write slot).
    head: usize,
    data: Vec<f32>,
}

impl KvRing {
    pub fn new(rows: usize, dh: usize) -> Self {
        Self { rows, dh, head: 0, data: vec![0.0; rows * dh] }
    }

    /// Logical capacity in rows (always full; zeros stand in for
    /// not-yet-written history).
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dh(&self) -> usize {
        self.dh
    }

    /// Back to a cold memory: all-zero rows, head reset.
    pub fn reset(&mut self) {
        self.data.fill(0.0);
        self.head = 0;
    }

    /// Logical row `i` (0 = oldest, `rows - 1` = newest). Panics on an
    /// out-of-range index — including ANY index at zero capacity, where
    /// the bare `%` would otherwise abort with a divide-by-zero.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(i < self.rows, "KvRing::row: index {i} >= capacity {}", self.rows);
        let p = (self.head + i) % self.rows;
        &self.data[p * self.dh..(p + 1) * self.dh]
    }

    /// Append the newest row, dropping the oldest. No memory moves
    /// beyond the single `dh`-wide write. No-op at zero capacity
    /// (window == m_tokens: no carried memory).
    pub fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dh);
        if self.rows == 0 {
            return;
        }
        let p = self.head;
        self.data[p * self.dh..(p + 1) * self.dh].copy_from_slice(row);
        self.head = (self.head + 1) % self.rows;
    }

    /// The raw physical backing storage (NOT logical order; pair with
    /// [`Self::head`] to reconstruct). This is the portable-snapshot
    /// surface: exporting a ring is a memcpy of this slice plus the
    /// head index, with no rotation into logical order.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Physical index of the oldest logical row (the next write slot) —
    /// the companion of [`Self::raw`] in a snapshot.
    pub fn head(&self) -> usize {
        self.head
    }

    /// Restore the ring from a `(raw storage, head)` snapshot taken via
    /// [`Self::raw`] / [`Self::head`]. The restored ring iterates its
    /// rows bit-for-bit identically to the snapshotted one. Panics on a
    /// geometry mismatch (callers validate snapshot shapes upstream).
    pub fn restore(&mut self, raw: &[f32], head: usize) {
        assert_eq!(raw.len(), self.data.len(), "KvRing::restore: storage size mismatch");
        assert!(
            head < self.rows || (self.rows == 0 && head == 0),
            "KvRing::restore: head {head} out of range for {} rows",
            self.rows
        );
        self.data.copy_from_slice(raw);
        self.head = head;
    }

    /// The ring contents as (older, newer) contiguous segments, logical
    /// order preserved across the pair. The split always lands on a row
    /// boundary (`head * dh`), so every logical row is contiguous
    /// within exactly one segment — the two-segment view the
    /// `nn::kernels` attention primitives iterate as tight loops over
    /// (at most) two flat slices. Either segment may be empty (a cold
    /// or exactly-wrapped ring yields one full segment plus an empty
    /// one).
    pub fn as_segments(&self) -> (&[f32], &[f32]) {
        let split = self.head * self.dh;
        (&self.data[split..], &self.data[..split])
    }

    /// Iterate logical rows oldest → newest without materializing a
    /// concatenated copy.
    #[inline]
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> + '_ {
        let (a, b) = self.as_segments();
        a.chunks_exact(self.dh).chain(b.chunks_exact(self.dh))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rowv(ring: &KvRing) -> Vec<f32> {
        ring.iter_rows().map(|r| r[0]).collect()
    }

    #[test]
    fn born_full_of_zeros() {
        let r = KvRing::new(3, 2);
        assert_eq!(r.rows(), 3);
        assert_eq!(rowv(&r), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn push_drops_oldest_in_logical_order() {
        let mut r = KvRing::new(3, 1);
        r.push(&[1.0]);
        assert_eq!(rowv(&r), vec![0.0, 0.0, 1.0]);
        r.push(&[2.0]);
        r.push(&[3.0]);
        assert_eq!(rowv(&r), vec![1.0, 2.0, 3.0]);
        r.push(&[4.0]);
        assert_eq!(rowv(&r), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn wraparound_many_times_preserves_order() {
        // fill far beyond capacity: 13 pushes through a 5-row ring wraps
        // twice and lands mid-buffer; logical order must stay exact
        let mut r = KvRing::new(5, 2);
        for i in 0..13 {
            r.push(&[i as f32, -(i as f32)]);
        }
        for (j, row) in r.iter_rows().enumerate() {
            let want = (8 + j) as f32;
            assert_eq!(row, &[want, -want]);
            assert_eq!(r.row(j), &[want, -want]);
        }
        let (a, b) = r.as_segments();
        assert_eq!(a.len() + b.len(), 5 * 2);
        // mid-wrap: both segments non-empty, split on a row boundary
        assert!(!a.is_empty() && !b.is_empty());
        assert_eq!(a.len() % 2, 0);
        let concat: Vec<f32> = a.iter().chain(b).copied().collect();
        let logical: Vec<f32> = r.iter_rows().flatten().copied().collect();
        assert_eq!(concat, logical);
    }

    #[test]
    fn row_and_iter_agree_after_partial_wrap() {
        let mut r = KvRing::new(4, 1);
        for i in 0..6 {
            r.push(&[i as f32]);
        }
        let via_iter = rowv(&r);
        let via_rows: Vec<f32> = (0..4).map(|i| r.row(i)[0]).collect();
        assert_eq!(via_iter, via_rows);
        assert_eq!(via_iter, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn reset_restores_cold_zero_memory() {
        let mut r = KvRing::new(3, 1);
        r.push(&[7.0]);
        r.push(&[8.0]);
        r.reset();
        assert_eq!(rowv(&r), vec![0.0, 0.0, 0.0]);
        r.push(&[1.0]);
        assert_eq!(rowv(&r), vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn zero_capacity_is_a_noop() {
        let mut r = KvRing::new(0, 4);
        r.push(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.iter_rows().count(), 0);
    }

    #[test]
    fn snapshot_roundtrip_preserves_logical_order() {
        let mut a = KvRing::new(4, 2);
        for i in 0..7 {
            a.push(&[i as f32, i as f32 + 0.5]);
        }
        // restore into a ring with a different head position
        let mut b = KvRing::new(4, 2);
        b.push(&[9.0, 9.0]);
        b.restore(a.raw(), a.head());
        let rows_a: Vec<Vec<f32>> = a.iter_rows().map(|r| r.to_vec()).collect();
        let rows_b: Vec<Vec<f32>> = b.iter_rows().map(|r| r.to_vec()).collect();
        assert_eq!(rows_a, rows_b);
        // and the restored ring keeps advancing identically
        a.push(&[42.0, 43.0]);
        b.push(&[42.0, 43.0]);
        assert_eq!(rowv(&a), rowv(&b));
    }

    #[test]
    fn zero_capacity_snapshot_roundtrip() {
        let a = KvRing::new(0, 3);
        let mut b = KvRing::new(0, 3);
        b.restore(a.raw(), a.head());
        assert_eq!(b.iter_rows().count(), 0);
    }
}
