//! Pure-Rust scalar reference engine.
//!
//! Three roles (DESIGN.md §3):
//! 1. an oracle independent of JAX *and* PJRT — golden tests triangulate
//!    all three implementations;
//! 2. the "standard implementation" CPU baseline for runtime tables;
//! 3. the numeric core for the probe trainer (ridge solve).

pub mod encoder;
pub mod linalg;
pub mod params;
pub mod rope;
pub mod tensor;
