//! Pure-Rust scalar reference engine.
//!
//! Three roles (DESIGN.md §3):
//! 1. an oracle independent of JAX *and* PJRT — golden tests triangulate
//!    all three implementations;
//! 2. the "standard implementation" CPU baseline for runtime tables;
//! 3. the numeric core for the probe trainer (ridge solve).
//!
//! Layout after the ring-buffer + kernel-suite refactors:
//! - [`tensor`]  — dense `Mat` math with in-place `_into` primitives and
//!   row-range views; deliberately sequential/naive inner loops (the
//!   oracle + baseline substrate — the hot path runs on [`kernels`]).
//! - [`kernels`] — the SIMD-friendly kernel suite: 8-wide unrolled
//!   `dot`/`sqdist`/`axpy`, packed fused matmul+bias
//!   ([`kernels::PackedLinear`], weights transposed once at load time),
//!   two-segment ring attention, and fused residual/norm sweeps — all
//!   under a fixed-summation-order determinism policy (module docs).
//! - [`kv_ring`] — fixed-storage circular K/V memory ([`kv_ring::KvRing`]):
//!   no `copy_within` roll, no `[memory; new]` concatenation; exposes
//!   the two-segment contiguous view ([`kv_ring::KvRing::as_segments`])
//!   the attention kernels iterate.
//! - [`batched`] — [`batched::BatchedScalarDeepCoT`], the multi-lane
//!   stepper: lane rows stacked into single shared-weight packed
//!   matmuls, all intermediates in a preallocated scratch workspace
//!   (steady-state ticks allocate nothing). Backs both the single-lane
//!   CPU baseline and the coordinator's scalar slot backend.
//! - [`encoder`] — the full-window oracle (`encoder_forward`) and the
//!   single-lane [`encoder::ScalarDeepCoT`] wrapper.
//! - [`naive`]   — the pre-refactor stepper, frozen as the benchmark
//!   baseline and refactor-equivalence oracle (`bench_kernels` measures
//!   the kernel suite against it).
//! - [`params`]  — weight loading from artifacts, synthetic parameters
//!   for hermetic tests/benches, and the load-time packing pass
//!   (`ModelParams::pack`).
//! - [`rope`]    — RoPE: the per-call reference path and the memoized
//!   [`rope::RopeTable`] (bitwise-transparent precomputation).
//! - [`simd`]    — explicit AVX2/NEON versions of the hot kernels with
//!   runtime CPU-feature dispatch resolved once at startup
//!   ([`simd::KernelOps`]); bitwise-pinned against [`kernels`] so
//!   dispatch choice is invisible to every cluster invariant.
//! - [`linalg`]  — the probe trainer's Cholesky/ridge, row-sweep
//!   (cache-friendly) solves built on the [`kernels`] primitives.

pub mod batched;
pub mod encoder;
pub mod kernels;
pub mod kv_ring;
pub mod linalg;
pub mod naive;
pub mod params;
pub mod rope;
pub mod simd;
pub mod tensor;
