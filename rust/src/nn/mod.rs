//! Pure-Rust scalar reference engine.
//!
//! Three roles (DESIGN.md §3):
//! 1. an oracle independent of JAX *and* PJRT — golden tests triangulate
//!    all three implementations;
//! 2. the "standard implementation" CPU baseline for runtime tables;
//! 3. the numeric core for the probe trainer (ridge solve).
//!
//! Layout after the ring-buffer refactor:
//! - [`tensor`]  — dense `Mat` math with in-place `_into` primitives and
//!   row-range views; branch-free inner loops so timings track FLOPs.
//! - [`kv_ring`] — fixed-storage circular K/V memory ([`kv_ring::KvRing`]):
//!   no `copy_within` roll, no `[memory; new]` concatenation.
//! - [`batched`] — [`batched::BatchedScalarDeepCoT`], the multi-lane
//!   stepper: lane rows stacked into single shared-weight matmuls, all
//!   intermediates in a preallocated scratch workspace (steady-state
//!   ticks allocate nothing). Backs both the single-lane CPU baseline
//!   and the coordinator's scalar slot backend.
//! - [`encoder`] — the full-window oracle (`encoder_forward`) and the
//!   single-lane [`encoder::ScalarDeepCoT`] wrapper.
//! - [`naive`]   — the pre-refactor stepper, frozen as the benchmark
//!   baseline and refactor-equivalence oracle.
//! - [`params`]  — weight loading from artifacts, plus synthetic
//!   parameters for hermetic tests/benches.
//! - [`rope`], [`linalg`] — RoPE and the probe trainer's Cholesky/ridge.

pub mod batched;
pub mod encoder;
pub mod kv_ring;
pub mod linalg;
pub mod naive;
pub mod params;
pub mod rope;
pub mod tensor;
