//! Scalar (pure-Rust) encoder forward passes — an oracle independent of
//! both JAX and PJRT, plus the "standard implementation" CPU baseline
//! used in EXPERIMENTS.md runtime comparisons.
//!
//! Mirrors `python/compile/model.py` numerics exactly: post-norm
//! residuals (LayerNorm or ReZero), tanh-GELU or linear FFN, softmax or
//! SOFT attention, interleaved RoPE.
//!
//! [`ScalarDeepCoT`] is the single-lane continual stepper. Since the
//! ring-buffer refactor it is a thin wrapper over
//! [`BatchedScalarDeepCoT`](crate::nn::batched::BatchedScalarDeepCoT)
//! with one lane: K/V memories live in [`crate::nn::kv_ring::KvRing`]s
//! (no per-tick memory roll) and all intermediates in a preallocated
//! scratch workspace, so a steady-state [`ScalarDeepCoT::tick`]
//! performs zero heap allocations. The pre-refactor implementation is
//! preserved as [`crate::nn::naive::NaiveScalarDeepCoT`] for
//! benchmarking and refactor-equivalence tests.

use anyhow::Result;

use crate::manifest::ModelConfig;
use crate::nn::batched::BatchedScalarDeepCoT;
use crate::nn::params::{LayerParams, ModelParams, Norm};
use crate::nn::rope::apply_rope_inplace;
use crate::nn::tensor::{dot, gelu, layer_norm_inplace, softmax_inplace, sqdist, Mat};

/// x (T x d) -> q/k/v (T x d) with bias.
pub(crate) fn project(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    let mut out = x.matmul(w);
    out.add_row(b);
    out
}

/// Split row-major (T x d) into per-head (T x dh) slices on the fly.
#[inline]
pub(crate) fn head_slice(m: &Mat, t: usize, h: usize, dh: usize) -> &[f32] {
    &m.row(t)[h * dh..(h + 1) * dh]
}

/// Post-norm residual over every row of `x`: `x += sub` (scaled for
/// ReZero), then the sub-layer's norm. `idx` selects the attention (0)
/// or FFN (1) parameter set.
pub(crate) fn residual(lp: &LayerParams, x: &mut Mat, sub: &Mat, idx: usize) {
    match (&lp.norm, idx) {
        (Norm::LayerNorm { g1, be1, .. }, 0) => {
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += sub.at(t, c);
                }
                layer_norm_inplace(x.row_mut(t), g1, be1);
            }
        }
        (Norm::LayerNorm { g2, be2, .. }, _) => {
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += sub.at(t, c);
                }
                layer_norm_inplace(x.row_mut(t), g2, be2);
            }
        }
        (Norm::ReZero { a1, a2 }, _) => {
            let a = if idx == 0 { *a1 } else { *a2 };
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += a * sub.at(t, c);
                }
            }
        }
    }
}

pub(crate) fn ffn(cfg: &ModelConfig, lp: &LayerParams, x: &Mat) -> Mat {
    let mut h = project(x, &lp.w1, &lp.b1);
    if cfg.ffn_act == "gelu" {
        for v in h.data.iter_mut() {
            *v = gelu(*v);
        }
    }
    project(&h, &lp.w2, &lp.b2)
}

/// Attention weights of one query row against a K matrix (rows x dh).
pub(crate) fn attn_weights(cfg: &ModelConfig, q: &[f32], keys: &Mat) -> Vec<f32> {
    let dh = q.len() as f32;
    let scale = 1.0 / dh.sqrt();
    let mut s: Vec<f32> = (0..keys.rows).map(|j| dot(q, keys.row(j)) * scale).collect();
    if cfg.activation == "softmax" {
        softmax_inplace(&mut s);
    } else {
        // SOFT (paper Eq. 4): unnormalized Gaussian kernel
        for (j, v) in s.iter_mut().enumerate() {
            *v = (-sqdist(q, keys.row(j)) * 0.5 * scale).exp();
        }
    }
    s
}

/// One lane of a full-window encoder forward. `window`: (n x d_in),
/// `pos0`: absolute position of the first window slot.
/// Returns (logits, out (n x d)).
pub fn encoder_forward(
    cfg: &ModelConfig,
    p: &ModelParams,
    window: &Mat,
    pos0: i32,
) -> Result<(Vec<f32>, Mat)> {
    let (n, dh, h) = (cfg.window, cfg.d_head(), cfg.n_heads);
    let mut x = project(window, &p.w_in, &p.b_in);
    for lp in &p.layers {
        let mut q = project(&x, &lp.wq, &lp.bq);
        let mut k = project(&x, &lp.wk, &lp.bk);
        let v = project(&x, &lp.wv, &lp.bv);
        if cfg.pos == "rope" {
            for t in 0..n {
                for hh in 0..h {
                    apply_rope_inplace(&mut q.row_mut(t)[hh * dh..(hh + 1) * dh], pos0 + t as i32);
                    apply_rope_inplace(&mut k.row_mut(t)[hh * dh..(hh + 1) * dh], pos0 + t as i32);
                }
            }
        }
        // attention per head; keys gathered into a (n x dh) temp per head
        let mut attn_out = Mat::zeros(n, cfg.d_model);
        let mut keys = Mat::zeros(n, dh);
        let mut vals = Mat::zeros(n, dh);
        for hh in 0..h {
            for t in 0..n {
                keys.row_mut(t).copy_from_slice(head_slice(&k, t, hh, dh));
                vals.row_mut(t).copy_from_slice(head_slice(&v, t, hh, dh));
            }
            for t in 0..n {
                let w = attn_weights(cfg, head_slice(&q, t, hh, dh), &keys);
                let orow = &mut attn_out.row_mut(t)[hh * dh..(hh + 1) * dh];
                for (j, &wj) in w.iter().enumerate() {
                    for (o, &vv) in orow.iter_mut().zip(vals.row(j)) {
                        *o += wj * vv;
                    }
                }
            }
        }
        let a = project(&attn_out, &lp.wo, &lp.bo);
        residual(lp, &mut x, &a, 0);
        let f = ffn(cfg, lp, &x);
        residual(lp, &mut x, &f, 1);
    }
    let last = Mat::from_vec(1, cfg.d_model, x.row(n - 1).to_vec());
    let mut logits = last.matmul(&p.w_cls);
    logits.add_row(&p.b_cls);
    Ok((logits.data, x))
}

/// Continual DeepCoT stepper, one lane (B handled by the caller or by
/// [`BatchedScalarDeepCoT`] directly).
///
/// Steady-state guarantee: after construction, [`ScalarDeepCoT::tick`]
/// performs zero heap allocations — K/V memories are fixed-storage
/// rings and every intermediate lives in the preallocated scratch
/// workspace. The returned slices borrow that workspace and are valid
/// until the next tick. Since the kernel-suite refactor the tick runs
/// on `nn::kernels` (packed fused matmul+bias, two-segment ring
/// attention, memoized RoPE rows); the full-window
/// [`encoder_forward`] above intentionally stays on the naive
/// `tensor` primitives as the independent oracle.
pub struct ScalarDeepCoT {
    inner: BatchedScalarDeepCoT,
}

impl ScalarDeepCoT {
    pub fn new(cfg: ModelConfig, p: ModelParams) -> Self {
        Self { inner: BatchedScalarDeepCoT::with_lanes(cfg, p, 1) }
    }

    pub fn cfg(&self) -> &ModelConfig {
        self.inner.cfg()
    }

    /// Absolute position of the next incoming token.
    pub fn pos(&self) -> i32 {
        self.inner.lane_pos(0)
    }

    pub fn reset(&mut self) {
        self.inner.reset();
    }

    /// One tick: `tokens` (m x d_in) -> (logits, out (m x d)), both
    /// borrowed from the internal workspace.
    pub fn tick(&mut self, tokens: &Mat) -> Result<(&[f32], &Mat)> {
        let out = self.inner.tick_all(tokens)?;
        Ok((out.logits.row(0), out.out))
    }
}
