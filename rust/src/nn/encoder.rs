//! Scalar (pure-Rust) encoder forward passes — an oracle independent of
//! both JAX and PJRT, plus the "standard implementation" CPU baseline
//! used in EXPERIMENTS.md runtime comparisons.
//!
//! Mirrors `python/compile/model.py` numerics exactly: post-norm
//! residuals (LayerNorm or ReZero), tanh-GELU or linear FFN, softmax or
//! SOFT attention, interleaved RoPE.

use anyhow::Result;

use crate::manifest::ModelConfig;
use crate::nn::params::{LayerParams, ModelParams, Norm};
use crate::nn::rope::apply_rope_inplace;
use crate::nn::tensor::{dot, gelu, layer_norm_inplace, softmax_inplace, sqdist, Mat};

/// x (T x d) -> q/k/v (T x d) with bias.
fn project(x: &Mat, w: &Mat, b: &[f32]) -> Mat {
    let mut out = x.matmul(w);
    out.add_row(b);
    out
}

/// Split row-major (T x d) into per-head (T x dh) slices on the fly.
#[inline]
fn head_slice(m: &Mat, t: usize, h: usize, dh: usize) -> &[f32] {
    &m.row(t)[h * dh..(h + 1) * dh]
}

fn residual(cfg: &ModelConfig, lp: &LayerParams, x: &mut Mat, sub: &Mat, idx: usize) {
    match (&lp.norm, idx) {
        (Norm::LayerNorm { g1, be1, .. }, 0) => {
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += sub.at(t, c);
                }
                layer_norm_inplace(x.row_mut(t), g1, be1);
            }
        }
        (Norm::LayerNorm { g2, be2, .. }, _) => {
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += sub.at(t, c);
                }
                layer_norm_inplace(x.row_mut(t), g2, be2);
            }
        }
        (Norm::ReZero { a1, a2 }, _) => {
            let a = if idx == 0 { *a1 } else { *a2 };
            for t in 0..x.rows {
                for c in 0..x.cols {
                    *x.at_mut(t, c) += a * sub.at(t, c);
                }
            }
        }
    }
    let _ = cfg;
}

fn ffn(cfg: &ModelConfig, lp: &LayerParams, x: &Mat) -> Mat {
    let mut h = project(x, &lp.w1, &lp.b1);
    if cfg.ffn_act == "gelu" {
        for v in h.data.iter_mut() {
            *v = gelu(*v);
        }
    }
    project(&h, &lp.w2, &lp.b2)
}

/// Attention weights of one query row against a K matrix (rows x dh).
fn attn_weights(cfg: &ModelConfig, q: &[f32], keys: &Mat) -> Vec<f32> {
    let dh = q.len() as f32;
    let scale = 1.0 / dh.sqrt();
    let mut s: Vec<f32> = (0..keys.rows).map(|j| dot(q, keys.row(j)) * scale).collect();
    if cfg.activation == "softmax" {
        softmax_inplace(&mut s);
    } else {
        // SOFT (paper Eq. 4): unnormalized Gaussian kernel
        for (j, v) in s.iter_mut().enumerate() {
            *v = (-sqdist(q, keys.row(j)) * 0.5 * scale).exp();
        }
    }
    s
}

/// One lane of a full-window encoder forward. `window`: (n x d_in),
/// `pos0`: absolute position of the first window slot.
/// Returns (logits, out (n x d)).
pub fn encoder_forward(
    cfg: &ModelConfig,
    p: &ModelParams,
    window: &Mat,
    pos0: i32,
) -> Result<(Vec<f32>, Mat)> {
    let (n, dh, h) = (cfg.window, cfg.d_head(), cfg.n_heads);
    let mut x = project(window, &p.w_in, &p.b_in);
    for lp in &p.layers {
        let mut q = project(&x, &lp.wq, &lp.bq);
        let mut k = project(&x, &lp.wk, &lp.bk);
        let v = project(&x, &lp.wv, &lp.bv);
        if cfg.pos == "rope" {
            for t in 0..n {
                for hh in 0..h {
                    apply_rope_inplace(&mut q.row_mut(t)[hh * dh..(hh + 1) * dh], pos0 + t as i32);
                    apply_rope_inplace(&mut k.row_mut(t)[hh * dh..(hh + 1) * dh], pos0 + t as i32);
                }
            }
        }
        // attention per head; keys gathered into a (n x dh) temp per head
        let mut attn_out = Mat::zeros(n, cfg.d_model);
        let mut keys = Mat::zeros(n, dh);
        let mut vals = Mat::zeros(n, dh);
        for hh in 0..h {
            for t in 0..n {
                keys.row_mut(t).copy_from_slice(head_slice(&k, t, hh, dh));
                vals.row_mut(t).copy_from_slice(head_slice(&v, t, hh, dh));
            }
            for t in 0..n {
                let w = attn_weights(cfg, head_slice(&q, t, hh, dh), &keys);
                let orow = &mut attn_out.row_mut(t)[hh * dh..(hh + 1) * dh];
                for (j, &wj) in w.iter().enumerate() {
                    for (o, &vv) in orow.iter_mut().zip(vals.row(j)) {
                        *o += wj * vv;
                    }
                }
            }
        }
        let a = project(&attn_out, &lp.wo, &lp.bo);
        residual(cfg, lp, &mut x, &a, 0);
        let f = ffn(cfg, lp, &x);
        residual(cfg, lp, &mut x, &f, 1);
    }
    let last = Mat::from_vec(1, cfg.d_model, x.row(n - 1).to_vec());
    let mut logits = last.matmul(&p.w_cls);
    logits.add_row(&p.b_cls);
    Ok((logits.data, x))
}

/// Continual DeepCoT stepper, one lane (B handled by the caller).
/// Per-layer K/V memories are (mem_len x dh) per head.
pub struct ScalarDeepCoT {
    pub cfg: ModelConfig,
    p: ModelParams,
    /// kmem[layer][head]: (mem_len x dh)
    kmem: Vec<Vec<Mat>>,
    vmem: Vec<Vec<Mat>>,
    pub pos: i32,
}

impl ScalarDeepCoT {
    pub fn new(cfg: ModelConfig, p: ModelParams) -> Self {
        let (l, h, mlen, dh) = (cfg.n_layers, cfg.n_heads, cfg.mem_len(), cfg.d_head());
        let zmem = || vec![vec![Mat::zeros(mlen, dh); h]; l];
        Self { cfg, p, kmem: zmem(), vmem: zmem(), pos: 0 }
    }

    pub fn reset(&mut self) {
        for lm in self.kmem.iter_mut().chain(self.vmem.iter_mut()) {
            for m in lm {
                m.data.iter_mut().for_each(|v| *v = 0.0);
            }
        }
        self.pos = 0;
    }

    /// One tick: `tokens` (m x d_in) -> (logits, out (m x d)).
    pub fn tick(&mut self, tokens: &Mat) -> Result<(Vec<f32>, Mat)> {
        let cfg = self.cfg.clone();
        let (m, h, dh, mlen) = (cfg.m_tokens, cfg.n_heads, cfg.d_head(), cfg.mem_len());
        anyhow::ensure!(tokens.rows == m && tokens.cols == cfg.d_in);
        let mut x = project(tokens, &self.p.w_in, &self.p.b_in);
        for (li, lp) in self.p.layers.iter().enumerate() {
            let mut q = project(&x, &lp.wq, &lp.bq);
            let mut k = project(&x, &lp.wk, &lp.bk);
            let v = project(&x, &lp.wv, &lp.bv);
            if cfg.pos == "rope" {
                for t in 0..m {
                    for hh in 0..h {
                        let pp = self.pos + t as i32;
                        apply_rope_inplace(&mut q.row_mut(t)[hh * dh..(hh + 1) * dh], pp);
                        apply_rope_inplace(&mut k.row_mut(t)[hh * dh..(hh + 1) * dh], pp);
                    }
                }
            }
            let mut attn_out = Mat::zeros(m, cfg.d_model);
            for hh in 0..h {
                // kcat = [memory; new keys]  (n x dh)
                let mut kcat = Mat::zeros(mlen + m, dh);
                let mut vcat = Mat::zeros(mlen + m, dh);
                for j in 0..mlen {
                    kcat.row_mut(j).copy_from_slice(self.kmem[li][hh].row(j));
                    vcat.row_mut(j).copy_from_slice(self.vmem[li][hh].row(j));
                }
                for t in 0..m {
                    kcat.row_mut(mlen + t).copy_from_slice(head_slice(&k, t, hh, dh));
                    vcat.row_mut(mlen + t).copy_from_slice(head_slice(&v, t, hh, dh));
                }
                for t in 0..m {
                    let w = attn_weights(&cfg, head_slice(&q, t, hh, dh), &kcat);
                    let orow = &mut attn_out.row_mut(t)[hh * dh..(hh + 1) * dh];
                    for (j, &wj) in w.iter().enumerate() {
                        for (o, &vv) in orow.iter_mut().zip(vcat.row(j)) {
                            *o += wj * vv;
                        }
                    }
                }
                // roll memory: drop oldest m rows, append the new ones
                let km = &mut self.kmem[li][hh];
                let vm = &mut self.vmem[li][hh];
                km.data.copy_within(m * dh.., 0);
                vm.data.copy_within(m * dh.., 0);
                for t in 0..m {
                    let dst = (mlen - m + t) * dh;
                    km.data[dst..dst + dh].copy_from_slice(head_slice(&k, t, hh, dh));
                    vm.data[dst..dst + dh].copy_from_slice(head_slice(&v, t, hh, dh));
                }
            }
            let a = project(&attn_out, &lp.wo, &lp.bo);
            residual(&cfg, lp, &mut x, &a, 0);
            let f = ffn(&cfg, lp, &x);
            residual(&cfg, lp, &mut x, &f, 1);
        }
        self.pos += m as i32;
        let last = Mat::from_vec(1, cfg.d_model, x.row(m - 1).to_vec());
        let mut logits = last.matmul(&self.p.w_cls);
        logits.add_row(&self.p.b_cls);
        Ok((logits.data, x))
    }
}
