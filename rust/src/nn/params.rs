//! Named parameter store for the scalar engine, built from the same
//! `weights/*.bin` + manifest spec the PJRT runtime consumes — so both
//! paths share byte-identical weights (the paper's equivalence protocol).

use anyhow::{bail, Context, Result};

use crate::manifest::{ModelConfig, VariantEntry};
use crate::nn::tensor::Mat;
use crate::runtime::weights::load_weights;
use crate::util::rng::Rng;

/// Per-layer residual-norm parameters.
#[derive(Debug, Clone)]
pub enum Norm {
    LayerNorm { g1: Vec<f32>, be1: Vec<f32>, g2: Vec<f32>, be2: Vec<f32> },
    ReZero { a1: f32, a2: f32 },
}

#[derive(Debug, Clone)]
pub struct LayerParams {
    pub wq: Mat,
    pub bq: Vec<f32>,
    pub wk: Mat,
    pub bk: Vec<f32>,
    pub wv: Mat,
    pub bv: Vec<f32>,
    pub wo: Mat,
    pub bo: Vec<f32>,
    pub w1: Mat,
    pub b1: Vec<f32>,
    pub w2: Mat,
    pub b2: Vec<f32>,
    pub norm: Norm,
    /// TransformerXL biases (H x dh), present only for xl families.
    pub u: Option<Mat>,
    pub vb: Option<Mat>,
}

#[derive(Debug, Clone)]
pub struct ModelParams {
    pub w_in: Mat,
    pub b_in: Vec<f32>,
    pub layers: Vec<LayerParams>,
    pub w_cls: Mat,
    pub b_cls: Vec<f32>,
}

impl ModelParams {
    /// One-time weight-packing pass for the kernel-suite hot path:
    /// every projection transposed + bias-fused into
    /// [`crate::nn::kernels::PackedParams`]. Done at stepper
    /// construction so steady-state ticks stay zero-alloc; the batched
    /// stepper then clones the per-layer [`Norm`]s out and drops the
    /// naive-layout `self`, so each weight is resident exactly once
    /// (the naive/oracle paths keep their own `ModelParams`).
    pub fn pack(&self) -> crate::nn::kernels::PackedParams {
        crate::nn::kernels::PackedParams::pack(self)
    }

    /// [`Self::pack`] onto an explicit, already-resolved kernel path
    /// (see [`crate::nn::simd::KernelOps::resolve`]) — the entry point
    /// stepper construction uses once the `EngineConfig` /
    /// `--kernel-dispatch` choice is resolved.
    pub fn pack_with(
        &self,
        ops: &'static crate::nn::simd::KernelOps,
    ) -> crate::nn::kernels::PackedParams {
        crate::nn::kernels::PackedParams::pack_with(self, ops)
    }

    /// Load from the variant's weight file (artifacts dir relative).
    pub fn load(artifacts_dir: &std::path::Path, entry: &VariantEntry) -> Result<Self> {
        let tensors = load_weights(&artifacts_dir.join(&entry.weights), &entry.params)?;
        let cfg = &entry.config;
        let mut by_name: std::collections::HashMap<&str, crate::runtime::HostTensor> =
            std::collections::HashMap::new();
        for (spec, t) in entry.params.iter().zip(tensors) {
            by_name.insert(spec.name.as_str(), t);
        }
        let mat = |name: &str| -> Result<Mat> {
            let t = by_name.get(name).with_context(|| format!("missing param {name}"))?;
            if t.shape.len() != 2 {
                bail!("param {name} is not rank-2");
            }
            Ok(Mat::from_vec(t.shape[0], t.shape[1], t.data.clone()))
        };
        let vec = |name: &str| -> Result<Vec<f32>> {
            Ok(by_name
                .get(name)
                .with_context(|| format!("missing param {name}"))?
                .data
                .clone())
        };
        let scalar = |name: &str| -> Result<f32> {
            let v = vec(name)?;
            if v.len() != 1 {
                bail!("param {name} is not scalar");
            }
            Ok(v[0])
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("l{i}.{s}");
            let norm = if cfg.norm == "layernorm" {
                Norm::LayerNorm {
                    g1: vec(&p("g1"))?,
                    be1: vec(&p("be1"))?,
                    g2: vec(&p("g2"))?,
                    be2: vec(&p("be2"))?,
                }
            } else {
                Norm::ReZero { a1: scalar(&p("a1"))?, a2: scalar(&p("a2"))? }
            };
            let (u, vb) = if by_name.contains_key(p("u").as_str()) {
                let g = |nm: &str| -> Result<Mat> {
                    let t = &by_name[p(nm).as_str()];
                    Ok(Mat::from_vec(t.shape[0], t.shape[1], t.data.clone()))
                };
                (Some(g("u")?), Some(g("vb")?))
            } else {
                (None, None)
            };
            layers.push(LayerParams {
                wq: mat(&p("wq"))?,
                bq: vec(&p("bq"))?,
                wk: mat(&p("wk"))?,
                bk: vec(&p("bk"))?,
                wv: mat(&p("wv"))?,
                bv: vec(&p("bv"))?,
                wo: mat(&p("wo"))?,
                bo: vec(&p("bo"))?,
                w1: mat(&p("w1"))?,
                b1: vec(&p("b1"))?,
                w2: mat(&p("w2"))?,
                b2: vec(&p("b2"))?,
                norm,
                u,
                vb,
            });
        }
        Ok(ModelParams {
            w_in: mat("w_in")?,
            b_in: vec("b_in")?,
            layers,
            w_cls: mat("w_cls")?,
            b_cls: vec("b_cls")?,
        })
    }

    /// Random small-scale parameters for the given geometry — hermetic
    /// substitute for `weights/*.bin` in tests and scalar benchmarks
    /// that must run without `make artifacts`. Fan-in scaling keeps
    /// activations O(1) through deep stacks; biases are small but
    /// nonzero so bias-handling bugs can't hide.
    pub fn synthetic(cfg: &ModelConfig, rng: &mut Rng) -> ModelParams {
        let (d, f, din, c) = (cfg.d_model, cfg.d_ffn(), cfg.d_in, cfg.n_classes);
        let mat = |r: usize, cc: usize, rng: &mut Rng| {
            let s = 1.0 / (r as f32).sqrt();
            Mat::from_vec(r, cc, rng.normal_vec(r * cc, s))
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            let norm = if cfg.norm == "layernorm" {
                Norm::LayerNorm {
                    g1: (0..d).map(|_| 1.0 + 0.05 * rng.normal_f32()).collect(),
                    be1: rng.normal_vec(d, 0.02),
                    g2: (0..d).map(|_| 1.0 + 0.05 * rng.normal_f32()).collect(),
                    be2: rng.normal_vec(d, 0.02),
                }
            } else {
                Norm::ReZero { a1: 0.5, a2: 0.5 }
            };
            layers.push(LayerParams {
                wq: mat(d, d, &mut *rng),
                bq: rng.normal_vec(d, 0.02),
                wk: mat(d, d, &mut *rng),
                bk: rng.normal_vec(d, 0.02),
                wv: mat(d, d, &mut *rng),
                bv: rng.normal_vec(d, 0.02),
                wo: mat(d, d, &mut *rng),
                bo: rng.normal_vec(d, 0.02),
                w1: mat(d, f, &mut *rng),
                b1: rng.normal_vec(f, 0.02),
                w2: mat(f, d, &mut *rng),
                b2: rng.normal_vec(d, 0.02),
                norm,
                u: None,
                vb: None,
            });
        }
        ModelParams {
            w_in: mat(din, d, &mut *rng),
            b_in: rng.normal_vec(d, 0.02),
            layers,
            w_cls: mat(d, c, &mut *rng),
            b_cls: rng.normal_vec(c, 0.02),
        }
    }
}
