//! Length-prefixed binary wire protocol for the TCP serving front door.
//!
//! Every frame on the wire is `[len: u32 LE][opcode: u8][body: len-1
//! bytes]`; `len` counts the opcode byte plus the body, so a zero
//! length is malformed by construction and a reader always knows
//! exactly how many bytes to consume before interpreting anything.
//! Integers are little-endian, token/activation payloads are raw f32
//! LE arrays, strings are UTF-8.
//!
//! Request frames (client → server): [`Frame::Open`], [`Frame::Push`],
//! [`Frame::Close`], [`Frame::Metrics`], [`Frame::MetricsProm`],
//! [`Frame::Shutdown`]. Reply
//! frames (server → client): [`Frame::Opened`], [`Frame::PushOk`],
//! [`Frame::Closed`], [`Frame::Tick`], [`Frame::MetricsReport`],
//! [`Frame::ShutdownOk`], and [`Frame::Error`] — whose [`WireError`]
//! payload mirrors every [`EngineError`] variant (code + stream id +
//! numeric aux + detail string), so typed backpressure / saturation /
//! shutdown semantics survive the network hop instead of collapsing
//! into a dropped connection.
//!
//! Robustness contract: decoding NEVER panics on malformed input —
//! every bad length, unknown opcode, truncated body, misaligned f32
//! payload, bad error code, or invalid UTF-8 surfaces as a typed
//! [`ProtoError`] (pinned by the fuzz loop in `tests/proto.rs` and
//! `tests/net.rs`). Frame lengths are capped at [`MAX_FRAME_LEN`] so a
//! hostile length prefix cannot drive a huge allocation.
//!
//! Allocation contract: the hot-path frames (PUSH and TICK) have
//! dedicated writers ([`write_push`], [`write_tick`]) and borrowed
//! readers ([`RawFrame::push_fields_into`],
//! [`RawFrame::tick_fields_into`]) that work entirely in caller-owned
//! reusable buffers — after warmup, a steady-state PUSH → TICK reply
//! loop performs zero codec allocations (pinned in
//! `tests/zero_alloc.rs`).

use std::fmt;
use std::io::{self, Read, Write};

use crate::coordinator::session::EngineError;
use crate::coordinator::slots::StreamId;

/// Upper bound on the length prefix: caps what a hostile or corrupt
/// prefix can make the reader allocate (16 MiB — orders of magnitude
/// above any real token vector).
pub const MAX_FRAME_LEN: usize = 1 << 24;

// Opcodes. Requests have the high bit clear, replies set — purely a
// readability convention; the decoder treats them all uniformly.
const OP_OPEN: u8 = 0x01;
const OP_PUSH: u8 = 0x02;
const OP_CLOSE: u8 = 0x03;
const OP_METRICS: u8 = 0x04;
const OP_SHUTDOWN: u8 = 0x05;
const OP_METRICS_PROM: u8 = 0x06;
const OP_OPENED: u8 = 0x81;
const OP_PUSH_OK: u8 = 0x82;
const OP_CLOSED: u8 = 0x83;
const OP_TICK: u8 = 0x84;
const OP_METRICS_REPORT: u8 = 0x85;
const OP_SHUTDOWN_OK: u8 = 0x86;
const OP_ERROR: u8 = 0xEE;

/// Typed decode failure: what exactly was malformed. Never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Length prefix of zero or beyond [`MAX_FRAME_LEN`].
    BadLength(u32),
    /// Opcode byte not assigned by this protocol version.
    BadOpcode(u8),
    /// Body shorter than the opcode's fixed fields require.
    Truncated {
        /// The frame's opcode (0 for an empty frame).
        op: u8,
        /// Bytes the opcode's layout needs.
        want: usize,
        /// Bytes actually present.
        got: usize,
    },
    /// Variable payload malformed (misaligned f32 data, trailing
    /// garbage after a fixed-size frame, logits length out of range).
    BadPayload(&'static str),
    /// Error frame carrying an unassigned error code.
    BadErrorCode(u8),
    /// A string field is not valid UTF-8.
    BadUtf8,
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::BadLength(n) => {
                write!(f, "bad frame length {n} (1..={MAX_FRAME_LEN} allowed)")
            }
            ProtoError::BadOpcode(op) => write!(f, "unknown opcode {op:#04x}"),
            ProtoError::Truncated { op, want, got } => {
                write!(f, "truncated frame (op {op:#04x}): need {want} body bytes, got {got}")
            }
            ProtoError::BadPayload(m) => write!(f, "bad frame payload: {m}"),
            ProtoError::BadErrorCode(c) => write!(f, "unknown error code {c}"),
            ProtoError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Wire error codes, one per [`EngineError`] variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// [`EngineError::Saturated`] — aux carries the capacity.
    Saturated,
    /// [`EngineError::StreamClosed`] — stream carries the id.
    StreamClosed,
    /// [`EngineError::Backpressure`] — stream carries the id.
    Backpressure,
    /// [`EngineError::ShuttingDown`].
    ShuttingDown,
    /// [`EngineError::Timeout`].
    Timeout,
    /// [`EngineError::InvalidRequest`] — detail carries the message.
    InvalidRequest,
    /// [`EngineError::Unsupported`] — detail carries the message.
    Unsupported,
    /// [`EngineError::Internal`] — detail carries the message.
    Internal,
    /// [`EngineError::Hibernated`] — stream carries the id. Distinct
    /// from [`ErrCode::StreamClosed`] so clients can tell "stream
    /// unknown" from "stream hibernated with no live owner: send OPEN
    /// with a resume id to reattach".
    Hibernated,
    /// [`EngineError::ShardFailed`] — aux carries the retryable flag
    /// (1 = the supervisor is re-homing the shard's streams; retry).
    ShardFailed,
}

impl ErrCode {
    fn to_u8(self) -> u8 {
        match self {
            ErrCode::Saturated => 1,
            ErrCode::StreamClosed => 2,
            ErrCode::Backpressure => 3,
            ErrCode::ShuttingDown => 4,
            ErrCode::Timeout => 5,
            ErrCode::InvalidRequest => 6,
            ErrCode::Unsupported => 7,
            ErrCode::Internal => 8,
            ErrCode::Hibernated => 9,
            ErrCode::ShardFailed => 10,
        }
    }

    fn from_u8(v: u8) -> Result<Self, ProtoError> {
        Ok(match v {
            1 => ErrCode::Saturated,
            2 => ErrCode::StreamClosed,
            3 => ErrCode::Backpressure,
            4 => ErrCode::ShuttingDown,
            5 => ErrCode::Timeout,
            6 => ErrCode::InvalidRequest,
            7 => ErrCode::Unsupported,
            8 => ErrCode::Internal,
            9 => ErrCode::Hibernated,
            10 => ErrCode::ShardFailed,
            other => return Err(ProtoError::BadErrorCode(other)),
        })
    }
}

/// A typed error reply: the wire form of an [`EngineError`], plus the
/// stream it concerns (0 = connection-level, no particular stream).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Stream the error concerns (0 when none).
    pub stream: u64,
    /// Which [`EngineError`] variant this mirrors.
    pub code: ErrCode,
    /// Numeric payload (the capacity for `Saturated`, else 0).
    pub aux: u32,
    /// Human-readable payload (message for `InvalidRequest` /
    /// `Unsupported` / `Internal`, else empty).
    pub detail: String,
}

impl WireError {
    /// Encode an [`EngineError`] for the wire. `stream` is the request
    /// context; variants that carry their own id override it.
    pub fn from_engine(stream: u64, e: &EngineError) -> Self {
        match e {
            EngineError::Saturated { capacity } => Self {
                stream,
                code: ErrCode::Saturated,
                aux: (*capacity).min(u32::MAX as usize) as u32,
                detail: String::new(),
            },
            EngineError::StreamClosed(id) => {
                Self { stream: id.0, code: ErrCode::StreamClosed, aux: 0, detail: String::new() }
            }
            EngineError::Backpressure(id) => {
                Self { stream: id.0, code: ErrCode::Backpressure, aux: 0, detail: String::new() }
            }
            EngineError::ShuttingDown => {
                Self { stream, code: ErrCode::ShuttingDown, aux: 0, detail: String::new() }
            }
            EngineError::Timeout => {
                Self { stream, code: ErrCode::Timeout, aux: 0, detail: String::new() }
            }
            EngineError::InvalidRequest(m) => {
                Self { stream, code: ErrCode::InvalidRequest, aux: 0, detail: m.clone() }
            }
            EngineError::Unsupported(m) => {
                Self { stream, code: ErrCode::Unsupported, aux: 0, detail: m.clone() }
            }
            EngineError::Internal(m) => {
                Self { stream, code: ErrCode::Internal, aux: 0, detail: m.clone() }
            }
            EngineError::Hibernated(id) => {
                Self { stream: id.0, code: ErrCode::Hibernated, aux: 0, detail: String::new() }
            }
            EngineError::ShardFailed { retryable } => Self {
                stream,
                code: ErrCode::ShardFailed,
                aux: u32::from(*retryable),
                detail: String::new(),
            },
        }
    }

    /// Reconstruct the typed [`EngineError`] on the client side —
    /// faithful for every variant (pinned in `tests/proto.rs`).
    pub fn to_engine(&self) -> EngineError {
        match self.code {
            ErrCode::Saturated => EngineError::Saturated { capacity: self.aux as usize },
            ErrCode::StreamClosed => EngineError::StreamClosed(StreamId(self.stream)),
            ErrCode::Backpressure => EngineError::Backpressure(StreamId(self.stream)),
            ErrCode::ShuttingDown => EngineError::ShuttingDown,
            ErrCode::Timeout => EngineError::Timeout,
            ErrCode::InvalidRequest => EngineError::InvalidRequest(self.detail.clone()),
            ErrCode::Unsupported => EngineError::Unsupported(self.detail.clone()),
            ErrCode::Internal => EngineError::Internal(self.detail.clone()),
            ErrCode::Hibernated => EngineError::Hibernated(StreamId(self.stream)),
            ErrCode::ShardFailed => EngineError::ShardFailed { retryable: self.aux != 0 },
        }
    }
}

/// One decoded protocol frame (owned form; the server hot path uses
/// [`RawFrame`] + the `write_*` helpers instead to stay allocation-free).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Open a stream on the engine: fresh (`resume: None`), or resume
    /// a hibernated stream by id (after a server restart recovered it
    /// from the state store). A fresh OPEN encodes with an empty body —
    /// byte-identical to the pre-resume protocol — and a resume adds an
    /// 8-byte id body, so older peers and captures stay compatible.
    Open {
        /// Hibernated stream to resume, or `None` for a fresh open.
        resume: Option<u64>,
    },
    /// [`Frame::Open`] carrying the server's shared-secret token —
    /// required as a connection's first request when the server is
    /// started with an auth token, accepted (token ignored) otherwise.
    /// Encodes as `OP_OPEN` with a body of `[resume u64 LE (0 =
    /// fresh)][token UTF-8, non-empty]` — strictly longer than 8
    /// bytes, so the fresh (empty) and resume (8-byte) forms of the
    /// original protocol are untouched and every older capture still
    /// decodes identically. An empty token must use [`Frame::Open`]
    /// (an empty-token `OpenAuth` would be indistinguishable from a
    /// plain resume on the wire).
    OpenAuth {
        /// Hibernated stream to resume, or `None` for a fresh open
        /// (encoded as resume id 0).
        resume: Option<u64>,
        /// The shared secret (non-empty).
        token: String,
    },
    /// Push the next token vector for a stream.
    Push {
        /// Target stream id (from [`Frame::Opened`]).
        stream: u64,
        /// `m_tokens * d_in` f32s.
        tokens: Vec<f32>,
    },
    /// Close a stream (the wire analogue of dropping the `Session`).
    Close {
        /// Stream to close.
        stream: u64,
    },
    /// Request the server's cluster + net metrics report.
    Metrics,
    /// Request the full Prometheus text exposition (the same document
    /// the HTTP `/metrics` endpoint serves); answered with
    /// [`Frame::MetricsReport`].
    MetricsProm,
    /// Ask the server to shut down gracefully (drain + terminal
    /// errors to every other live stream).
    Shutdown,
    /// Reply to [`Frame::Open`]: the engine-assigned stream id.
    Opened {
        /// Cluster-unique stream id (also valid for `EngineHandle`
        /// calls in-process, e.g. migration in tests/benches).
        stream: u64,
    },
    /// Reply to [`Frame::Push`]: the token vector was accepted.
    PushOk {
        /// Stream the push targeted.
        stream: u64,
    },
    /// Reply to [`Frame::Close`]: the stream is closed.
    Closed {
        /// Stream that closed.
        stream: u64,
    },
    /// One tick result, delivered asynchronously per accepted push.
    Tick {
        /// Stream the result belongs to.
        stream: u64,
        /// Per-stream tick ordinal (1-based, survives migration).
        tick: u64,
        /// Classifier logits for the newest token.
        logits: Vec<f32>,
        /// Final-layer activations for the new tokens.
        out: Vec<f32>,
    },
    /// Reply to [`Frame::Metrics`]: the operator report text.
    MetricsReport {
        /// `ClusterMetrics::report()` plus the net layer's counters.
        /// Includes the engine's resolved kernel path as
        /// `dispatch=<scalar|avx2|neon>` — no wire change was needed;
        /// the field rides in the report string like every other
        /// engine counter.
        report: String,
    },
    /// Reply to [`Frame::Shutdown`]: drain is underway; expect EOF.
    ShutdownOk,
    /// Typed failure reply (any request, or an async stream teardown).
    Error(WireError),
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_u32(b: &[u8], at: usize, op: u8) -> Result<u32, ProtoError> {
    match b.get(at..at + 4) {
        Some(s) => Ok(u32::from_le_bytes(s.try_into().unwrap())),
        None => Err(ProtoError::Truncated { op, want: at + 4, got: b.len() }),
    }
}

fn get_u64(b: &[u8], at: usize, op: u8) -> Result<u64, ProtoError> {
    match b.get(at..at + 8) {
        Some(s) => Ok(u64::from_le_bytes(s.try_into().unwrap())),
        None => Err(ProtoError::Truncated { op, want: at + 8, got: b.len() }),
    }
}

/// Copy an f32 LE payload into a reusable vector (cleared first).
/// Rejects misaligned lengths; allocates only to grow capacity.
fn get_f32s_into(b: &[u8], dst: &mut Vec<f32>) -> Result<(), ProtoError> {
    if b.len() % 4 != 0 {
        return Err(ProtoError::BadPayload("f32 payload length not a multiple of 4"));
    }
    dst.clear();
    dst.reserve(b.len() / 4);
    for chunk in b.chunks_exact(4) {
        dst.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(())
}

fn expect_exact(b: &[u8], want: usize, _op: u8) -> Result<(), ProtoError> {
    if b.len() != want {
        return Err(ProtoError::BadPayload("trailing bytes after a fixed-size frame"));
    }
    Ok(())
}

/// Encode a PUSH frame (length prefix included) into a reusable buffer
/// — the client hot path. The buffer is cleared, never shrunk.
pub fn write_push(out: &mut Vec<u8>, stream: u64, tokens: &[f32]) {
    out.clear();
    put_u32(out, (1 + 8 + 4 * tokens.len()) as u32);
    out.push(OP_PUSH);
    put_u64(out, stream);
    put_f32s(out, tokens);
}

/// Encode a TICK frame (length prefix included) into a reusable buffer
/// — the server writer-thread hot path.
pub fn write_tick(out: &mut Vec<u8>, stream: u64, tick: u64, logits: &[f32], acts: &[f32]) {
    out.clear();
    put_u32(out, (1 + 8 + 8 + 4 + 4 * (logits.len() + acts.len())) as u32);
    out.push(OP_TICK);
    put_u64(out, stream);
    put_u64(out, tick);
    put_u32(out, logits.len() as u32);
    put_f32s(out, logits);
    put_f32s(out, acts);
}

/// A borrowed, length-validated frame: opcode + body slice. The
/// zero-copy decode entry point used by the server's reader thread.
#[derive(Debug, Clone, Copy)]
pub struct RawFrame<'a> {
    /// The frame's opcode byte.
    pub op: u8,
    /// Everything after the opcode.
    pub body: &'a [u8],
}

impl<'a> RawFrame<'a> {
    /// Split a received frame (the bytes after the length prefix) into
    /// opcode + body. An empty frame is malformed.
    pub fn parse(frame: &'a [u8]) -> Result<Self, ProtoError> {
        match frame.split_first() {
            Some((&op, body)) => Ok(Self { op, body }),
            None => Err(ProtoError::Truncated { op: 0, want: 1, got: 0 }),
        }
    }

    /// Decode PUSH fields without allocating: returns the stream id and
    /// copies the tokens into `tokens` (cleared, capacity reused).
    pub fn push_fields_into(&self, tokens: &mut Vec<f32>) -> Result<u64, ProtoError> {
        if self.op != OP_PUSH {
            return Err(ProtoError::BadOpcode(self.op));
        }
        let stream = get_u64(self.body, 0, self.op)?;
        get_f32s_into(&self.body[8..], tokens)?;
        Ok(stream)
    }

    /// Decode TICK fields without allocating: returns `(stream, tick)`
    /// and copies logits/activations into the reusable vectors.
    pub fn tick_fields_into(
        &self,
        logits: &mut Vec<f32>,
        acts: &mut Vec<f32>,
    ) -> Result<(u64, u64), ProtoError> {
        if self.op != OP_TICK {
            return Err(ProtoError::BadOpcode(self.op));
        }
        let stream = get_u64(self.body, 0, self.op)?;
        let tick = get_u64(self.body, 8, self.op)?;
        let n_logits = get_u32(self.body, 16, self.op)? as usize;
        let rest = &self.body[20..];
        let Some(split) = n_logits.checked_mul(4).filter(|&b| b <= rest.len()) else {
            return Err(ProtoError::BadPayload("logits length exceeds frame body"));
        };
        get_f32s_into(&rest[..split], logits)?;
        get_f32s_into(&rest[split..], acts)?;
        Ok((stream, tick))
    }

    /// Full owned decode (the convenient non-hot-path form).
    pub fn to_frame(&self) -> Result<Frame, ProtoError> {
        let b = self.body;
        Ok(match self.op {
            OP_OPEN => match b.len() {
                0 => Frame::Open { resume: None },
                8 => Frame::Open { resume: Some(get_u64(b, 0, self.op)?) },
                n if n > 8 => {
                    // authenticated open: resume id (0 = fresh) + token
                    let id = get_u64(b, 0, self.op)?;
                    let token =
                        std::str::from_utf8(&b[8..]).map_err(|_| ProtoError::BadUtf8)?.to_string();
                    Frame::OpenAuth { resume: if id == 0 { None } else { Some(id) }, token }
                }
                _ => {
                    return Err(ProtoError::BadPayload(
                        "OPEN body must be empty (fresh) or an 8-byte resume id",
                    ))
                }
            },
            OP_METRICS => {
                expect_exact(b, 0, self.op)?;
                Frame::Metrics
            }
            OP_METRICS_PROM => {
                expect_exact(b, 0, self.op)?;
                Frame::MetricsProm
            }
            OP_SHUTDOWN => {
                expect_exact(b, 0, self.op)?;
                Frame::Shutdown
            }
            OP_SHUTDOWN_OK => {
                expect_exact(b, 0, self.op)?;
                Frame::ShutdownOk
            }
            OP_PUSH => {
                let mut tokens = Vec::new();
                let stream = self.push_fields_into(&mut tokens)?;
                Frame::Push { stream, tokens }
            }
            OP_CLOSE => {
                expect_exact(b, 8, self.op)?;
                Frame::Close { stream: get_u64(b, 0, self.op)? }
            }
            OP_OPENED => {
                expect_exact(b, 8, self.op)?;
                Frame::Opened { stream: get_u64(b, 0, self.op)? }
            }
            OP_PUSH_OK => {
                expect_exact(b, 8, self.op)?;
                Frame::PushOk { stream: get_u64(b, 0, self.op)? }
            }
            OP_CLOSED => {
                expect_exact(b, 8, self.op)?;
                Frame::Closed { stream: get_u64(b, 0, self.op)? }
            }
            OP_TICK => {
                let (mut logits, mut out) = (Vec::new(), Vec::new());
                let (stream, tick) = self.tick_fields_into(&mut logits, &mut out)?;
                Frame::Tick { stream, tick, logits, out }
            }
            OP_METRICS_REPORT => {
                let report =
                    std::str::from_utf8(b).map_err(|_| ProtoError::BadUtf8)?.to_string();
                Frame::MetricsReport { report }
            }
            OP_ERROR => {
                let stream = get_u64(b, 0, self.op)?;
                let code = match b.get(8) {
                    Some(&c) => ErrCode::from_u8(c)?,
                    None => return Err(ProtoError::Truncated { op: self.op, want: 9, got: 8 }),
                };
                let aux = get_u32(b, 9, self.op)?;
                let detail =
                    std::str::from_utf8(&b[13..]).map_err(|_| ProtoError::BadUtf8)?.to_string();
                Frame::Error(WireError { stream, code, aux, detail })
            }
            other => return Err(ProtoError::BadOpcode(other)),
        })
    }
}

impl Frame {
    /// Encode into a reusable buffer (cleared first), length prefix
    /// included — ready for one `write_all`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Frame::Push { stream, tokens } => return write_push(out, *stream, tokens),
            Frame::Tick { stream, tick, logits, out: acts } => {
                return write_tick(out, *stream, *tick, logits, acts)
            }
            _ => {}
        }
        out.clear();
        // reserve the prefix, fill the body, then patch the length in
        put_u32(out, 0);
        match self {
            Frame::Open { resume } => {
                out.push(OP_OPEN);
                if let Some(id) = resume {
                    put_u64(out, *id);
                }
            }
            Frame::OpenAuth { resume, token } => {
                debug_assert!(!token.is_empty(), "empty token: use Frame::Open");
                out.push(OP_OPEN);
                put_u64(out, resume.unwrap_or(0));
                out.extend_from_slice(token.as_bytes());
            }
            Frame::Metrics => out.push(OP_METRICS),
            Frame::MetricsProm => out.push(OP_METRICS_PROM),
            Frame::Shutdown => out.push(OP_SHUTDOWN),
            Frame::ShutdownOk => out.push(OP_SHUTDOWN_OK),
            Frame::Close { stream } => {
                out.push(OP_CLOSE);
                put_u64(out, *stream);
            }
            Frame::Opened { stream } => {
                out.push(OP_OPENED);
                put_u64(out, *stream);
            }
            Frame::PushOk { stream } => {
                out.push(OP_PUSH_OK);
                put_u64(out, *stream);
            }
            Frame::Closed { stream } => {
                out.push(OP_CLOSED);
                put_u64(out, *stream);
            }
            Frame::MetricsReport { report } => {
                out.push(OP_METRICS_REPORT);
                out.extend_from_slice(report.as_bytes());
            }
            Frame::Error(e) => {
                out.push(OP_ERROR);
                put_u64(out, e.stream);
                out.push(e.code.to_u8());
                put_u32(out, e.aux);
                out.extend_from_slice(e.detail.as_bytes());
            }
            Frame::Push { .. } | Frame::Tick { .. } => unreachable!("handled above"),
        }
        let len = (out.len() - 4) as u32;
        out[..4].copy_from_slice(&len.to_le_bytes());
    }

    /// Encode into a fresh buffer (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Decode a received frame (the bytes after the length prefix).
    pub fn decode(frame: &[u8]) -> Result<Frame, ProtoError> {
        RawFrame::parse(frame)?.to_frame()
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn desync() -> io::Error {
    io::Error::new(
        io::ErrorKind::UnexpectedEof,
        "read timed out mid-frame: the byte stream is desynchronized",
    )
}

/// Read one frame into `buf` (cleared; capacity reused): length prefix
/// first, then exactly that many bytes. Returns `Ok(false)` on a clean
/// EOF at a frame boundary (peer closed), `Err` on a torn frame, a bad
/// length, or any transport error. Malformed lengths surface as
/// `io::ErrorKind::InvalidData` wrapping the [`ProtoError`].
///
/// Read-timeout discipline: a timeout with ZERO bytes consumed (a
/// clean frame boundary) is returned as-is — the caller may safely
/// retry the read later. A timeout after bytes of this frame were
/// consumed is promoted to `io::ErrorKind::UnexpectedEof`: partial
/// reads are not resumable, so retrying would misinterpret mid-frame
/// bytes as a new length prefix. Callers must treat it as terminal.
pub fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> io::Result<bool> {
    let mut prefix = [0u8; 4];
    // a clean EOF (or retryable timeout) is only clean before the
    // first prefix byte
    let mut got = 0;
    while got < 4 {
        match r.read(&mut prefix[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "EOF inside a frame length prefix",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if got > 0 && is_timeout(&e) => return Err(desync()),
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(prefix);
    if len == 0 || len as usize > MAX_FRAME_LEN {
        return Err(io::Error::new(io::ErrorKind::InvalidData, ProtoError::BadLength(len)));
    }
    buf.clear();
    buf.resize(len as usize, 0);
    let mut read = 0;
    while read < buf.len() {
        match r.read(&mut buf[read..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "EOF inside a frame body",
                ))
            }
            Ok(n) => read += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => return Err(desync()),
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Write one already-encoded frame (length prefix included).
pub fn write_frame<W: Write>(w: &mut W, encoded: &[u8]) -> io::Result<()> {
    w.write_all(encoded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_frames_round_trip() {
        for f in [
            Frame::Open { resume: None },
            Frame::Metrics,
            Frame::MetricsProm,
            Frame::Shutdown,
            Frame::ShutdownOk,
        ] {
            let enc = f.encode();
            assert_eq!(Frame::decode(&enc[4..]).unwrap(), f);
        }
    }

    #[test]
    fn open_resume_round_trips_and_stays_wire_compatible() {
        // a fresh OPEN is the legacy 1-byte frame, byte for byte
        let fresh = Frame::Open { resume: None };
        assert_eq!(fresh.encode(), vec![1, 0, 0, 0, OP_OPEN]);
        let res = Frame::Open { resume: Some(42) };
        let enc = res.encode();
        assert_eq!(enc.len(), 4 + 1 + 8);
        assert_eq!(Frame::decode(&enc[4..]).unwrap(), res);
        // any other body size is malformed, never a panic
        assert!(matches!(
            Frame::decode(&[OP_OPEN, 1, 2, 3]),
            Err(ProtoError::BadPayload(_))
        ));
    }

    #[test]
    fn push_and_tick_round_trip() {
        let p = Frame::Push { stream: 7, tokens: vec![1.0, -2.5, 3.25] };
        let enc = p.encode();
        assert_eq!(u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize, enc.len() - 4);
        assert_eq!(Frame::decode(&enc[4..]).unwrap(), p);
        let t = Frame::Tick { stream: 9, tick: 42, logits: vec![0.5; 4], out: vec![-1.0; 16] };
        let enc = t.encode();
        assert_eq!(Frame::decode(&enc[4..]).unwrap(), t);
        // empty logits/out are legal frames
        let t0 = Frame::Tick { stream: 1, tick: 1, logits: vec![], out: vec![] };
        assert_eq!(Frame::decode(&t0.encode()[4..]).unwrap(), t0);
    }

    #[test]
    fn errors_round_trip_typed() {
        use crate::coordinator::session::EngineError as E;
        let cases = [
            E::Saturated { capacity: 4 },
            E::StreamClosed(StreamId(3)),
            E::Backpressure(StreamId(8)),
            E::ShuttingDown,
            E::Timeout,
            E::InvalidRequest("bad length".into()),
            E::Unsupported("snapshot export on PJRT".into()),
            E::Internal("boom".into()),
            E::Hibernated(StreamId(6)),
            E::ShardFailed { retryable: true },
            E::ShardFailed { retryable: false },
        ];
        for e in cases {
            let w = WireError::from_engine(5, &e);
            let enc = Frame::Error(w.clone()).encode();
            let Frame::Error(back) = Frame::decode(&enc[4..]).unwrap() else {
                panic!("not an error frame");
            };
            assert_eq!(back, w);
            assert_eq!(back.to_engine(), e, "typed error must survive the wire");
        }
        // Hibernated and StreamClosed must stay distinguishable codes
        assert_ne!(
            WireError::from_engine(0, &E::Hibernated(StreamId(1))).code,
            WireError::from_engine(0, &E::StreamClosed(StreamId(1))).code,
        );
    }

    #[test]
    fn malformed_frames_reject_cleanly() {
        assert!(matches!(Frame::decode(&[]), Err(ProtoError::Truncated { .. })));
        assert!(matches!(Frame::decode(&[0x7f]), Err(ProtoError::BadOpcode(0x7f))));
        // truncated CLOSE (needs 8 body bytes)
        assert!(Frame::decode(&[OP_CLOSE, 1, 2]).is_err());
        // trailing garbage after a fixed-size frame
        assert!(Frame::decode(&[OP_OPEN, 0]).is_err());
        // misaligned f32 payload
        let mut push = vec![OP_PUSH];
        push.extend_from_slice(&7u64.to_le_bytes());
        push.extend_from_slice(&[1, 2, 3]);
        assert!(matches!(Frame::decode(&push), Err(ProtoError::BadPayload(_))));
        // tick whose logits length exceeds the body
        let mut tick = vec![OP_TICK];
        tick.extend_from_slice(&1u64.to_le_bytes());
        tick.extend_from_slice(&1u64.to_le_bytes());
        tick.extend_from_slice(&100u32.to_le_bytes());
        assert!(matches!(Frame::decode(&tick), Err(ProtoError::BadPayload(_))));
        // error frame with an unknown code
        let mut err = vec![OP_ERROR];
        err.extend_from_slice(&0u64.to_le_bytes());
        err.push(99);
        err.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(Frame::decode(&err), Err(ProtoError::BadErrorCode(99))));
        // invalid UTF-8 detail
        let mut err = vec![OP_ERROR];
        err.extend_from_slice(&0u64.to_le_bytes());
        err.push(8);
        err.extend_from_slice(&0u32.to_le_bytes());
        err.extend_from_slice(&[0xff, 0xfe]);
        assert_eq!(Frame::decode(&err), Err(ProtoError::BadUtf8));
    }

    #[test]
    fn read_frame_handles_eof_and_bad_lengths() {
        let mut buf = Vec::new();
        // clean EOF at a boundary
        let mut empty: &[u8] = &[];
        assert!(!read_frame(&mut empty, &mut buf).unwrap());
        // EOF inside the prefix
        let mut torn: &[u8] = &[1, 0];
        assert!(read_frame(&mut torn, &mut buf).is_err());
        // zero length
        let mut zero: &[u8] = &[0, 0, 0, 0];
        assert!(read_frame(&mut zero, &mut buf).is_err());
        // insane length
        let mut huge: &[u8] = &[0xff, 0xff, 0xff, 0xff];
        assert!(read_frame(&mut huge, &mut buf).is_err());
        // EOF inside the body
        let mut body: &[u8] = &[5, 0, 0, 0, OP_OPEN];
        assert!(read_frame(&mut body, &mut buf).is_err());
        // a whole valid frame
        let enc = Frame::Opened { stream: 3 }.encode();
        let mut ok: &[u8] = &enc;
        assert!(read_frame(&mut ok, &mut buf).unwrap());
        assert_eq!(Frame::decode(&buf).unwrap(), Frame::Opened { stream: 3 });
    }

    #[test]
    fn open_auth_round_trips_and_leaves_plain_open_untouched() {
        // fresh authenticated open: resume id 0 on the wire
        let f = Frame::OpenAuth { resume: None, token: "s3cret".into() };
        let enc = f.encode();
        assert_eq!(enc[4], OP_OPEN, "OpenAuth shares the OPEN opcode");
        assert_eq!(Frame::decode(&enc[4..]).unwrap(), f);
        // authenticated resume
        let f = Frame::OpenAuth { resume: Some(42), token: "s3cret".into() };
        let enc = f.encode();
        assert_eq!(Frame::decode(&enc[4..]).unwrap(), f);
        // plain opens are byte-identical to the pre-auth protocol
        assert_eq!(Frame::Open { resume: None }.encode(), vec![1, 0, 0, 0, OP_OPEN]);
        let resumed = Frame::Open { resume: Some(7) }.encode();
        assert_eq!(resumed.len(), 4 + 1 + 8);
        assert_eq!(Frame::decode(&resumed[4..]).unwrap(), Frame::Open { resume: Some(7) });
        // 1..=7 byte OPEN bodies stay rejected (auth needs > 8)
        for n in 1..=7 {
            let mut b = vec![OP_OPEN];
            b.resize(1 + n, 0);
            assert!(Frame::decode(&b).is_err(), "{n}-byte OPEN body must stay invalid");
        }
        // non-UTF-8 token bytes reject cleanly
        let mut bad = vec![OP_OPEN];
        bad.extend_from_slice(&0u64.to_le_bytes());
        bad.extend_from_slice(&[0xff, 0xfe]);
        assert_eq!(Frame::decode(&bad), Err(ProtoError::BadUtf8));
    }
}
