//! Blocking, pipelined TCP client for the `net::proto` wire protocol —
//! used by tests, benches, and `deepcot_serve --smoke`.
//!
//! One [`NetClient`] owns one connection and may multiplex several
//! streams over it. PUSH is pipelined: [`NetClient::push_nowait`]
//! writes the frame and returns without waiting for the ack, so up to
//! [`NetClient::set_max_inflight`] requests ride the wire back to
//! back and one load-generator process can saturate a server. Acks
//! are matched strictly FIFO (the server serializes each connection's
//! requests, so reply order is request order); [`NetClient::flush_acks`]
//! drains them, and every synchronous call drains outstanding acks
//! before issuing its own request, so the classic one-at-a-time API
//! ([`NetClient::push`] and friends) behaves exactly as before.
//!
//! TICK frames arrive asynchronously relative to request acks, so
//! every receive path demultiplexes: frames that answer the current
//! request return immediately, tick results and per-stream terminal
//! errors for *other* streams are parked in a **bounded** inbox
//! (default 4096 frames, [`NetClient::set_inbox_cap`]) and handed out
//! by the matching [`NetClient::recv_tick`] call. Overflowing the
//! inbox drops the frame, counts it ([`NetClient::inbox_dropped`]),
//! and surfaces as the typed [`ClientError::InboxOverflow`] instead
//! of growing memory without bound.
//!
//! Typed errors survive the hop: a server-side [`EngineError`] comes
//! back as [`ClientError::Engine`] with the same variant an in-process
//! `Session` call would have returned (`Backpressure`, `Saturated`,
//! `ShuttingDown`, …), so callers can keep branching on semantics
//! rather than parsing messages. For servers started with a shared
//! auth token, [`NetClient::set_auth_token`] makes every subsequent
//! open carry it ([`Frame::OpenAuth`]); the wire protocol is otherwise
//! unchanged.

use std::collections::VecDeque;
use std::fmt;
use std::io::{self, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::coordinator::session::EngineError;
use crate::net::proto::{self, Frame, ProtoError};

/// Why a client call failed: a typed engine error relayed by the
/// server, a transport failure, or a protocol violation.
#[derive(Debug)]
pub enum ClientError {
    /// The server replied with a typed engine error.
    Engine(EngineError),
    /// The transport failed (connect, read, or write).
    Io(io::Error),
    /// The server sent a frame this protocol version cannot decode.
    Proto(ProtoError),
    /// The server closed the connection while a reply was expected
    /// (e.g. hard kill mid-request) — a terminal condition.
    Disconnected,
    /// The server sent a well-formed frame that does not answer the
    /// outstanding request (a protocol-state violation).
    Unexpected(String),
    /// The parked-frame inbox hit its cap and a frame was dropped —
    /// the caller is receiving ticks for one stream far slower than
    /// the server produces them for others. Raise the cap
    /// ([`NetClient::set_inbox_cap`]) or drain the lagging streams.
    InboxOverflow {
        /// The configured inbox capacity that was exceeded.
        capacity: usize,
    },
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Engine(e) => write!(f, "engine error over the wire: {e}"),
            ClientError::Io(e) => write!(f, "transport error: {e}"),
            ClientError::Proto(e) => write!(f, "protocol error: {e}"),
            ClientError::Disconnected => write!(f, "server closed the connection"),
            ClientError::Unexpected(m) => write!(f, "unexpected reply: {m}"),
            ClientError::InboxOverflow { capacity } => {
                write!(f, "parked-frame inbox overflowed its cap of {capacity}")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        match e.kind() {
            // a socket read timeout at a frame boundary is the wire
            // form of recv_timeout running out: retryable, surfaced as
            // the same typed error. proto::read_frame only lets a
            // timeout through when zero bytes of the frame were
            // consumed — a mid-frame timeout arrives as UnexpectedEof
            // (the stream is desynchronized) and lands in `Io`, which
            // is terminal for the connection.
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                ClientError::Engine(EngineError::Timeout)
            }
            _ => ClientError::Io(e),
        }
    }
}

impl From<ProtoError> for ClientError {
    fn from(e: ProtoError) -> Self {
        ClientError::Proto(e)
    }
}

/// Backoff policy for re-dialing a serving front door that dropped the
/// connection (server restart, reaped socket, injected transport
/// fault): exponential delay growth from `base_delay`, capped at
/// `max_delay`, with deterministic ±25% jitter derived from `seed` so
/// a fleet of clients knocked over together doesn't re-dial in
/// lockstep. Exhausting `max_attempts` surfaces as the typed
/// [`EngineError::Timeout`] — the same retryable error a slow read
/// yields — so callers keep one recovery branch.
#[derive(Debug, Clone)]
pub struct ReconnectPolicy {
    /// Dial attempts before giving up (clamped to at least 1).
    pub max_attempts: u32,
    /// Delay before the second attempt; doubles per attempt after.
    pub base_delay: Duration,
    /// Upper bound on any single backoff delay.
    pub max_delay: Duration,
    /// Jitter seed; two clients with different seeds spread their
    /// retries, equal seeds retry identically (deterministic tests).
    pub seed: u64,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 5,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_secs(2),
            seed: 0x5EED,
        }
    }
}

/// SplitMix64: the jitter stream (deterministic, seed-keyed).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl ReconnectPolicy {
    /// The delay taken after failed attempt `attempt` (0-based):
    /// `base_delay << attempt`, capped, then jittered to 75–125%.
    pub fn delay(&self, attempt: u32) -> Duration {
        let exp = self.base_delay.saturating_mul(1u32 << attempt.min(20));
        let capped = exp.min(self.max_delay);
        let z = splitmix64(self.seed.wrapping_add(u64::from(attempt)));
        let frac = (z >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
        capped.mul_f64(0.75 + 0.5 * frac).min(self.max_delay)
    }
}

/// One tick result received over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct WireTick {
    /// Stream the result belongs to.
    pub stream: u64,
    /// Per-stream tick ordinal (1-based; survives migration).
    pub tick: u64,
    /// Classifier logits for the newest token.
    pub logits: Vec<f32>,
    /// Final-layer activations for the new tokens.
    pub out: Vec<f32>,
}

/// What the inbox parks for a stream while other calls are in flight.
enum Parked {
    Tick(WireTick),
    /// Terminal per-stream error (eviction / shutdown announcement).
    Dead(EngineError),
}

/// A blocking client connection to a [`NetServer`].
///
/// [`NetServer`]: crate::net::server::NetServer
pub struct NetClient {
    sock: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    inbox: VecDeque<(u64, Parked)>,
    /// Streams with a pipelined PUSH awaiting its ack, oldest first.
    /// The server serializes each connection's requests, so acks come
    /// back in exactly this order.
    pending: VecDeque<u64>,
    max_inflight: usize,
    inbox_cap: usize,
    inbox_dropped: u64,
    auth_token: Option<String>,
    /// Failed dials retried by `connect_with_retry`/`reconnect_resume`
    /// over this client's lifetime (survives the socket swap).
    reconnect_attempts: u64,
}

/// Default bound on pipelined PUSHes awaiting acks.
pub const DEFAULT_MAX_INFLIGHT: usize = 128;
/// Default bound on the parked-frame inbox.
pub const DEFAULT_INBOX_CAP: usize = 4096;

impl NetClient {
    /// Connect to a serving front door.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<NetClient> {
        let sock = TcpStream::connect(addr)?;
        let _ = sock.set_nodelay(true);
        Ok(NetClient {
            sock,
            rbuf: Vec::with_capacity(4096),
            wbuf: Vec::with_capacity(4096),
            inbox: VecDeque::new(),
            pending: VecDeque::new(),
            max_inflight: DEFAULT_MAX_INFLIGHT,
            inbox_cap: DEFAULT_INBOX_CAP,
            inbox_dropped: 0,
            auth_token: None,
            reconnect_attempts: 0,
        })
    }

    /// Connect with the policy's exponential backoff: each failed dial
    /// sleeps the (jittered) delay and tries again. Exhaustion is the
    /// typed retryable [`EngineError::Timeout`], never a hang.
    pub fn connect_with_retry<A: ToSocketAddrs>(
        addr: A,
        policy: &ReconnectPolicy,
    ) -> Result<NetClient, ClientError> {
        let mut retried = 0u64;
        for attempt in 0..policy.max_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(policy.delay(attempt - 1));
                retried += 1;
            }
            if let Ok(mut c) = NetClient::connect(&addr) {
                c.reconnect_attempts = retried;
                return Ok(c);
            }
        }
        Err(ClientError::Engine(EngineError::Timeout))
    }

    /// Recover from a dropped connection: re-dial with backoff, then
    /// reattach every id in `streams` via OPEN-resume — tick ordinals
    /// continue from each stream's last server-side checkpoint. On
    /// success the client's socket and buffers are replaced in place
    /// (parked inbox entries from the dead connection are discarded);
    /// on failure the client is left unusable for transport but the
    /// error is typed: [`EngineError::Timeout`] when every dial failed,
    /// or the per-stream engine error when a resume was refused.
    pub fn reconnect_resume<A: ToSocketAddrs>(
        &mut self,
        addr: A,
        policy: &ReconnectPolicy,
        streams: &[u64],
    ) -> Result<(), ClientError> {
        let mut fresh = NetClient::connect_with_retry(&addr, policy)?;
        // fold the dial count into self first so it survives even when
        // a resume below is refused and `fresh` is dropped
        self.reconnect_attempts += fresh.reconnect_attempts;
        fresh.reconnect_attempts = self.reconnect_attempts;
        // carry the knobs and credentials onto the new connection
        // (pipelined pushes in flight on the dead socket are lost,
        // like its parked inbox entries)
        fresh.max_inflight = self.max_inflight;
        fresh.inbox_cap = self.inbox_cap;
        fresh.inbox_dropped = self.inbox_dropped;
        fresh.auth_token = self.auth_token.clone();
        for &s in streams {
            fresh.open_resume(s)?;
        }
        *self = fresh;
        Ok(())
    }

    /// Failed dials this client retried across every
    /// `connect_with_retry`/`reconnect_resume` call.
    pub fn reconnect_attempts(&self) -> u64 {
        self.reconnect_attempts
    }

    /// Bound every blocking read (None = wait forever). A read that
    /// times out surfaces as [`EngineError::Timeout`].
    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.sock.set_read_timeout(d)
    }

    /// Carry `token` on every subsequent open ([`Frame::OpenAuth`]) —
    /// required when the server was started with a shared auth token.
    /// An empty token clears the setting (plain OPENs again).
    pub fn set_auth_token(&mut self, token: impl Into<String>) {
        let t = token.into();
        self.auth_token = if t.is_empty() { None } else { Some(t) };
    }

    /// Cap the parked-frame inbox (clamped to at least 1). Frames over
    /// the cap are dropped, counted, and surfaced as
    /// [`ClientError::InboxOverflow`].
    pub fn set_inbox_cap(&mut self, cap: usize) {
        self.inbox_cap = cap.max(1);
    }

    /// Bound on pipelined PUSHes awaiting acks (clamped to at least
    /// 1); [`NetClient::push_nowait`] blocks for one ack when full.
    pub fn set_max_inflight(&mut self, n: usize) {
        self.max_inflight = n.max(1);
    }

    /// Pipelined PUSHes currently awaiting their ack.
    pub fn inflight(&self) -> usize {
        self.pending.len()
    }

    /// Parked frames dropped to inbox overflow over this client's
    /// lifetime (survives `reconnect_resume`'s socket swap).
    pub fn inbox_dropped(&self) -> u64 {
        self.inbox_dropped
    }

    fn send(&mut self, f: &Frame) -> Result<(), ClientError> {
        f.encode_into(&mut self.wbuf);
        self.sock.write_all(&self.wbuf).map_err(ClientError::from)
    }

    /// Read and decode the next frame off the socket.
    fn read_one(&mut self) -> Result<Frame, ClientError> {
        if !proto::read_frame(&mut self.sock, &mut self.rbuf)? {
            return Err(ClientError::Disconnected);
        }
        Ok(Frame::decode(&self.rbuf)?)
    }

    /// Park an asynchronous frame that belongs to some stream's future
    /// `recv_tick`; anything else is a protocol-state violation. The
    /// inbox is bounded: a frame over the cap is dropped and counted,
    /// and the overflow surfaces as a typed error.
    fn park(&mut self, f: Frame) -> Result<(), ClientError> {
        let entry = match f {
            Frame::Tick { stream, tick, logits, out } => {
                (stream, Parked::Tick(WireTick { stream, tick, logits, out }))
            }
            Frame::Error(w) if w.stream != 0 => (w.stream, Parked::Dead(w.to_engine())),
            other => return Err(ClientError::Unexpected(format!("{other:?}"))),
        };
        if self.inbox.len() >= self.inbox_cap {
            self.inbox_dropped += 1;
            return Err(ClientError::InboxOverflow { capacity: self.inbox_cap });
        }
        self.inbox.push_back(entry);
        Ok(())
    }

    /// Block for the ack of the oldest pipelined PUSH. The server
    /// serializes each connection's requests, so the oldest pending
    /// stream's `PUSH_OK` (or its typed error) is the next request
    /// reply on the wire; anything else in between is parked.
    fn take_ack(&mut self) -> Result<(), ClientError> {
        let head =
            *self.pending.front().expect("take_ack is only called with a pipelined push pending");
        loop {
            match self.read_one()? {
                Frame::PushOk { stream } if stream == head => {
                    self.pending.pop_front();
                    return Ok(());
                }
                Frame::Error(w) if w.stream == head || w.stream == 0 => {
                    self.pending.pop_front();
                    return Err(ClientError::Engine(w.to_engine()));
                }
                other => self.park(other)?,
            }
        }
    }

    /// Drain every outstanding pipelined ack. Per-request engine
    /// errors keep draining (the first one is returned once the wire
    /// is quiet); transport and protocol errors abort immediately —
    /// the connection is desynchronized and no further ack can be
    /// trusted.
    pub fn flush_acks(&mut self) -> Result<(), ClientError> {
        let mut first: Option<ClientError> = None;
        while !self.pending.is_empty() {
            match self.take_ack() {
                Ok(()) => {}
                // a read timeout is the wire going quiet, not a
                // per-request verdict: no further ack is coming and
                // `pending` cannot shrink, so stop instead of spinning
                Err(e @ ClientError::Engine(EngineError::Timeout)) => {
                    return Err(first.unwrap_or(e));
                }
                Err(e @ (ClientError::Engine(_) | ClientError::InboxOverflow { .. })) => {
                    if first.is_none() {
                        first = Some(e);
                    }
                }
                Err(terminal) => return Err(terminal),
            }
        }
        match first {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// The OPEN frame for `resume`, carrying the auth token when set.
    fn open_request(&self, resume: Option<u64>) -> Frame {
        match &self.auth_token {
            Some(t) => Frame::OpenAuth { resume, token: t.clone() },
            None => Frame::Open { resume },
        }
    }

    /// Open a stream; returns its engine-assigned id.
    pub fn open(&mut self) -> Result<u64, ClientError> {
        let f = self.open_request(None);
        self.open_frame(f, 0)
    }

    /// Reattach to a hibernated stream the server recovered from its
    /// state store: same id, tick ordinals continue where the previous
    /// run's left off, outputs bitwise-identical to an uninterrupted
    /// run. Fails typed when the id is unknown ([`EngineError::StreamClosed`])
    /// or still has a live owner ([`EngineError::InvalidRequest`]).
    pub fn open_resume(&mut self, stream: u64) -> Result<u64, ClientError> {
        let f = self.open_request(Some(stream));
        self.open_frame(f, stream)
    }

    fn open_frame(&mut self, f: Frame, resume: u64) -> Result<u64, ClientError> {
        self.flush_acks()?;
        self.send(&f)?;
        loop {
            match self.read_one()? {
                Frame::Opened { stream } => return Ok(stream),
                // open errors are connection-scoped (stream 0); a
                // resume failure may also carry the requested id
                Frame::Error(w) if w.stream == 0 || (resume != 0 && w.stream == resume) => {
                    return Err(ClientError::Engine(w.to_engine()))
                }
                other => self.park(other)?,
            }
        }
    }

    /// Push the next token vector for a stream and wait for its ack
    /// (any pipelined acks still outstanding are drained first). A
    /// rejected push comes back as the same typed error an in-process
    /// `Session::push` returns (`Backpressure`, `StreamClosed`,
    /// `ShuttingDown`, …).
    pub fn push(&mut self, stream: u64, tokens: &[f32]) -> Result<(), ClientError> {
        self.flush_acks()?;
        self.push_nowait(stream, tokens)?;
        self.flush_acks()
    }

    /// Pipelined push: write the PUSH frame and return without waiting
    /// for its ack. Up to `max_inflight` pushes may be outstanding;
    /// when the window is full this blocks for exactly one ack first
    /// (surfacing that push's typed error, if any). Collect the
    /// remaining acks with [`NetClient::flush_acks`] — or let the next
    /// synchronous call do it.
    pub fn push_nowait(&mut self, stream: u64, tokens: &[f32]) -> Result<(), ClientError> {
        if self.pending.len() >= self.max_inflight {
            self.take_ack()?;
        }
        proto::write_push(&mut self.wbuf, stream, tokens);
        self.sock.write_all(&self.wbuf).map_err(ClientError::from)?;
        self.pending.push_back(stream);
        Ok(())
    }

    /// Block for the next tick result of a stream (parked results are
    /// returned first). A stream torn down server-side yields its
    /// terminal typed error.
    pub fn recv_tick(&mut self, stream: u64) -> Result<WireTick, ClientError> {
        self.flush_acks()?;
        if let Some(idx) = self.inbox.iter().position(|(s, _)| *s == stream) {
            let (_, parked) = self.inbox.remove(idx).expect("index just found");
            return match parked {
                Parked::Tick(t) => Ok(t),
                Parked::Dead(e) => Err(ClientError::Engine(e)),
            };
        }
        loop {
            match self.read_one()? {
                Frame::Tick { stream: s, tick, logits, out } if s == stream => {
                    return Ok(WireTick { stream: s, tick, logits, out })
                }
                Frame::Error(w) if w.stream == stream || w.stream == 0 => {
                    return Err(ClientError::Engine(w.to_engine()))
                }
                other => self.park(other)?,
            }
        }
    }

    /// Close a stream (the wire analogue of dropping a `Session`).
    /// Tick results still in flight for it are discarded.
    pub fn close(&mut self, stream: u64) -> Result<(), ClientError> {
        // drain pipelined acks before CLOSE: the server defers the
        // CLOSED reply until the stream's queued ticks have reached
        // the wire, which is only unobservable because no request is
        // ever pipelined past a CLOSE
        self.flush_acks()?;
        self.send(&Frame::Close { stream })?;
        let res = loop {
            match self.read_one()? {
                Frame::Closed { stream: s } if s == stream => break Ok(()),
                // in-flight results for the closing stream are stale
                Frame::Tick { stream: s, .. } if s == stream => {}
                Frame::Error(w) if w.stream == stream || w.stream == 0 => {
                    break Err(ClientError::Engine(w.to_engine()))
                }
                other => self.park(other)?,
            }
        };
        self.inbox.retain(|(s, _)| *s != stream);
        res
    }

    /// Fetch the server's operator report (cluster + net counters).
    pub fn metrics(&mut self) -> Result<String, ClientError> {
        self.flush_acks()?;
        self.send(&Frame::Metrics)?;
        loop {
            match self.read_one()? {
                Frame::MetricsReport { report } => return Ok(report),
                Frame::Error(w) if w.stream == 0 => return Err(ClientError::Engine(w.to_engine())),
                other => self.park(other)?,
            }
        }
    }

    /// Fetch the server's full Prometheus text exposition — the same
    /// document its HTTP `/metrics` endpoint serves.
    pub fn metrics_prometheus(&mut self) -> Result<String, ClientError> {
        self.flush_acks()?;
        self.send(&Frame::MetricsProm)?;
        loop {
            match self.read_one()? {
                Frame::MetricsReport { report } => return Ok(report),
                Frame::Error(w) if w.stream == 0 => return Err(ClientError::Engine(w.to_engine())),
                other => self.park(other)?,
            }
        }
    }

    /// Ask the server to shut down gracefully; returns once the server
    /// acknowledges (expect terminal errors / EOF afterwards).
    pub fn shutdown_server(&mut self) -> Result<(), ClientError> {
        self.flush_acks()?;
        self.send(&Frame::Shutdown)?;
        loop {
            match self.read_one()? {
                Frame::ShutdownOk => return Ok(()),
                Frame::Error(w) if w.stream == 0 => return Err(ClientError::Engine(w.to_engine())),
                other => self.park(other)?,
            }
        }
    }
}

impl fmt::Debug for NetClient {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "NetClient({:?})", self.sock.peer_addr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let p = ReconnectPolicy {
            max_attempts: 8,
            base_delay: Duration::from_millis(40),
            max_delay: Duration::from_millis(500),
            seed: 11,
        };
        for a in 0..8u32 {
            let d = p.delay(a);
            // ±25% jitter around base << a, hard-capped
            let nominal = p.base_delay.saturating_mul(1 << a).min(p.max_delay);
            assert!(d >= nominal.mul_f64(0.75), "attempt {a}: {d:?} under jitter floor");
            assert!(d <= p.max_delay, "attempt {a}: {d:?} over the cap");
        }
        // deep attempts saturate at the cap's jitter band, no overflow
        assert!(p.delay(40) <= p.max_delay);
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let a = ReconnectPolicy { seed: 7, ..Default::default() };
        let b = ReconnectPolicy { seed: 7, ..Default::default() };
        let c = ReconnectPolicy { seed: 8, ..Default::default() };
        assert_eq!(a.delay(3), b.delay(3), "equal seeds must retry identically");
        assert_ne!(a.delay(3), c.delay(3), "different seeds must spread retries");
    }

    /// Read one frame off a scripted test server's socket.
    fn read_req(sock: &mut TcpStream, buf: &mut Vec<u8>) -> Frame {
        assert!(proto::read_frame(sock, buf).unwrap(), "client hung up mid-script");
        Frame::decode(buf).unwrap()
    }

    #[test]
    fn pipelined_pushes_ack_fifo_and_drain_before_close() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            assert!(matches!(read_req(&mut sock, &mut buf), Frame::Open { resume: None }));
            sock.write_all(&Frame::Opened { stream: 7 }.encode()).unwrap();
            // three pipelined pushes arrive before any ack is written
            for _ in 0..3 {
                assert!(matches!(read_req(&mut sock, &mut buf), Frame::Push { stream: 7, .. }));
            }
            for _ in 0..3 {
                sock.write_all(&Frame::PushOk { stream: 7 }.encode()).unwrap();
            }
            // the CLOSE must not be pipelined past outstanding acks
            assert!(matches!(read_req(&mut sock, &mut buf), Frame::Close { stream: 7 }));
            sock.write_all(&Frame::Closed { stream: 7 }.encode()).unwrap();
        });
        let mut c = NetClient::connect(addr).unwrap();
        let s = c.open().unwrap();
        assert_eq!(s, 7);
        for _ in 0..3 {
            c.push_nowait(s, &[1.0, 2.0]).unwrap();
        }
        assert_eq!(c.inflight(), 3, "push_nowait must not wait for acks");
        c.close(s).unwrap();
        assert_eq!(c.inflight(), 0, "close must drain the ack window first");
        server.join().unwrap();
    }

    #[test]
    fn inbox_overflow_is_typed_and_counted() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            assert!(matches!(read_req(&mut sock, &mut buf), Frame::Open { .. }));
            sock.write_all(&Frame::Opened { stream: 1 }.encode()).unwrap();
            assert!(matches!(read_req(&mut sock, &mut buf), Frame::Push { stream: 1, .. }));
            // three ticks for a stream nobody is draining, then the ack
            for t in 1..=3u64 {
                let tick = Frame::Tick { stream: 2, tick: t, logits: vec![0.5], out: vec![] };
                sock.write_all(&tick.encode()).unwrap();
            }
            sock.write_all(&Frame::PushOk { stream: 1 }.encode()).unwrap();
            // hold the socket open until the client is done asserting
            let _ = proto::read_frame(&mut sock, &mut buf);
        });
        let mut c = NetClient::connect(addr).unwrap();
        c.set_inbox_cap(2);
        let s = c.open().unwrap();
        match c.push(s, &[1.0]) {
            Err(ClientError::InboxOverflow { capacity: 2 }) => {}
            other => panic!("expected typed inbox overflow, got {other:?}"),
        }
        assert_eq!(c.inbox_dropped(), 1, "exactly the over-cap frame is dropped");
        assert_eq!(c.inflight(), 0, "the ack is still consumed while reporting overflow");
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn auth_token_turns_opens_into_open_auth() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            match read_req(&mut sock, &mut buf) {
                Frame::OpenAuth { resume: None, token } => assert_eq!(token, "hunter2"),
                other => panic!("expected OpenAuth, got {other:?}"),
            }
            sock.write_all(&Frame::Opened { stream: 9 }.encode()).unwrap();
            match read_req(&mut sock, &mut buf) {
                Frame::OpenAuth { resume: Some(9), token } => assert_eq!(token, "hunter2"),
                other => panic!("expected OpenAuth resume, got {other:?}"),
            }
            sock.write_all(&Frame::Opened { stream: 9 }.encode()).unwrap();
            // clearing the token goes back to plain OPEN on the wire
            assert!(matches!(read_req(&mut sock, &mut buf), Frame::Open { resume: None }));
            sock.write_all(&Frame::Opened { stream: 10 }.encode()).unwrap();
        });
        let mut c = NetClient::connect(addr).unwrap();
        c.set_auth_token("hunter2");
        assert_eq!(c.open().unwrap(), 9);
        assert_eq!(c.open_resume(9).unwrap(), 9);
        c.set_auth_token("");
        assert_eq!(c.open().unwrap(), 10);
        server.join().unwrap();
    }

    #[test]
    fn exhausted_retry_is_typed_timeout() {
        // a port nothing listens on: every dial fails fast, and the
        // exhaustion error is the typed retryable Timeout
        let p = ReconnectPolicy {
            max_attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            seed: 1,
        };
        match NetClient::connect_with_retry("127.0.0.1:9", &p) {
            Err(ClientError::Engine(EngineError::Timeout)) => {}
            other => panic!("expected typed Timeout, got {other:?}"),
        }
    }
}
