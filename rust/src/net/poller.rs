//! A std-only readiness poller: the dependency-free epoll shim under
//! the front door's executor (`net::server`).
//!
//! Like the rest of `net/`, this module uses no crates — just raw
//! `extern "C"` syscall bindings over what the platform libc already
//! links. Three backends, picked at compile time:
//!
//! * **Linux** — `epoll` (level-triggered): O(ready) wakeups, the
//!   c10k-and-beyond path the executor is designed around.
//! * **Other Unix** — `poll(2)`: O(registered) per wait, fine for the
//!   fanouts tests exercise off-Linux.
//! * **Elsewhere** — a degenerate fallback that sleeps ≤1 ms and
//!   reports every registered token as maybe-ready. Correct (the
//!   executor treats readiness strictly as a hint over nonblocking
//!   sockets and tolerates `WouldBlock` everywhere), just not fast.
//!
//! Also here: the [`Waker`] (a nonblocking `UnixStream` pair the worker
//! pool uses to interrupt a parked `wait`), and [`raise_nofile`], the
//! `RLIMIT_NOFILE` helper the high-fanout tests and `bench_throughput
//! --conns` use to make thousands of loopback sockets admissible.

use std::io;
use std::time::Duration;

#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered under.
    pub token: u64,
    /// Reading would (probably) not block.
    pub readable: bool,
    /// Writing would (probably) not block.
    pub writable: bool,
    /// Peer hangup / error — the connection is over either way, but
    /// the executor still drains readable bytes first.
    pub hangup: bool,
}

/// Anything the poller can watch. On Unix this is everything with a
/// raw fd; elsewhere registration is token-only (degenerate backend).
pub trait Pollable {
    /// The raw handle to register (unused off-Unix).
    fn raw(&self) -> RawSource;
}

/// The platform's raw handle type.
#[cfg(unix)]
pub type RawSource = RawFd;
/// The platform's raw handle type (unused by the degenerate backend).
#[cfg(not(unix))]
pub type RawSource = u64;

#[cfg(unix)]
impl<T: AsRawFd> Pollable for T {
    fn raw(&self) -> RawSource {
        self.as_raw_fd()
    }
}

#[cfg(not(unix))]
impl<T> Pollable for T {
    fn raw(&self) -> RawSource {
        0
    }
}

fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        // round up so a 100µs request waits 1ms instead of busy-spinning
        Some(d) => d.as_millis().max(u128::from(u32::from(!d.is_zero()))).min(60_000) as i32,
    }
}

// ---------------------------------------------------------------- Linux epoll

#[cfg(target_os = "linux")]
mod sys {
    use super::*;

    // The kernel ABI: packed on x86_64 only (a 12-byte struct there;
    // naturally aligned 16 bytes everywhere else).
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: i32,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers involved.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            let mut events = EPOLLRDHUP;
            if read {
                events |= EPOLLIN;
            }
            if write {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: `ev` outlives the call; the kernel copies it.
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register<S: Pollable>(
            &mut self,
            src: &S,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, src.raw(), token, read, write)
        }

        pub fn modify<S: Pollable>(
            &mut self,
            src: &S,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, src.raw(), token, read, write)
        }

        pub fn deregister<S: Pollable>(&mut self, src: &S) -> io::Result<()> {
            // pre-2.6.9 kernels insist on a non-null event for DEL
            self.ctl(EPOLL_CTL_DEL, src.raw(), 0, false, false)
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            let n = loop {
                // SAFETY: buf is a valid writable array of 256 events.
                let rc = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms(timeout))
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
                // EINTR: retry with the same timeout (close enough)
            };
            for ev in buf.iter().take(n) {
                // copy out of the (possibly packed) struct before use
                let (events, data) = (ev.events, ev.data);
                out.push(PollEvent {
                    token: data,
                    readable: events & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: events & EPOLLOUT != 0,
                    hangup: events & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: epfd is a fd this struct owns.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ------------------------------------------------------- other Unix: poll(2)

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::*;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    // nfds_t: u32 on the BSD family + macOS (the platforms this
    // fallback realistically serves).
    type NFds = u32;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NFds, timeout: i32) -> i32;
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    /// Registration-list poller over poll(2): O(registered) per wait.
    pub struct Poller {
        regs: Vec<(RawFd, u64, bool, bool)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { regs: Vec::new() })
        }

        pub fn register<S: Pollable>(
            &mut self,
            src: &S,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.regs.push((src.raw(), token, read, write));
            Ok(())
        }

        pub fn modify<S: Pollable>(
            &mut self,
            src: &S,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            let fd = src.raw();
            for r in &mut self.regs {
                if r.0 == fd {
                    *r = (fd, token, read, write);
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn deregister<S: Pollable>(&mut self, src: &S) -> io::Result<()> {
            let fd = src.raw();
            self.regs.retain(|r| r.0 != fd);
            Ok(())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .regs
                .iter()
                .map(|&(fd, _, read, write)| PollFd {
                    fd,
                    events: if read { POLLIN } else { 0 } | if write { POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            let n = loop {
                // SAFETY: fds is a valid array of regs.len() entries.
                let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms(timeout)) };
                if rc >= 0 {
                    break rc;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            if n == 0 {
                return Ok(());
            }
            for (pfd, &(_, token, _, _)) in fds.iter().zip(&self.regs) {
                if pfd.revents != 0 {
                    out.push(PollEvent {
                        token,
                        readable: pfd.revents & POLLIN != 0,
                        writable: pfd.revents & POLLOUT != 0,
                        hangup: pfd.revents & (POLLERR | POLLHUP) != 0,
                    });
                }
            }
            Ok(())
        }
    }
}

// ------------------------------------------------- non-Unix: degenerate poll

#[cfg(not(unix))]
mod sys {
    use super::*;

    /// Sleeps ≤1 ms and reports every registered token as maybe-ready.
    /// The executor treats readiness purely as a hint over nonblocking
    /// sockets, so this is slow-but-correct.
    pub struct Poller {
        tokens: Vec<u64>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { tokens: Vec::new() })
        }

        pub fn register<S: Pollable>(
            &mut self,
            _src: &S,
            token: u64,
            _read: bool,
            _write: bool,
        ) -> io::Result<()> {
            self.tokens.push(token);
            Ok(())
        }

        pub fn modify<S: Pollable>(
            &mut self,
            _src: &S,
            _token: u64,
            _read: bool,
            _write: bool,
        ) -> io::Result<()> {
            Ok(())
        }

        pub fn deregister<S: Pollable>(&mut self, _src: &S) -> io::Result<()> {
            // token-keyed removal is impossible without the fd; the
            // executor tolerates stale maybe-ready hints for tokens it
            // no longer tracks, so over-reporting here is harmless —
            // but keep the list bounded by deduping on wait below.
            Ok(())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let nap = timeout.unwrap_or(Duration::from_millis(1)).min(Duration::from_millis(1));
            std::thread::sleep(nap);
            self.tokens.sort_unstable();
            self.tokens.dedup();
            for &token in &self.tokens {
                out.push(PollEvent { token, readable: true, writable: true, hangup: false });
            }
            Ok(())
        }
    }
}

pub use sys::Poller;

// ------------------------------------------------------------------- waker

/// The write half of the executor's wake channel. Worker threads call
/// [`Waker::wake`] after enqueueing a completion so a parked
/// [`Poller::wait`] returns immediately; `NetServer::shutdown` uses the
/// same channel to interrupt the loop.
#[cfg(unix)]
#[derive(Clone)]
pub struct Waker {
    tx: std::sync::Arc<std::os::unix::net::UnixStream>,
}

/// The read half: registered in the poller; drained on wake.
#[cfg(unix)]
pub struct WakeReader {
    rx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    /// Interrupt the poll loop. A full pipe means a wake is already
    /// pending — dropping the byte is exactly right.
    pub fn wake(&self) {
        use std::io::Write;
        let _ = (&*self.tx).write(&[1u8]);
    }
}

#[cfg(unix)]
impl WakeReader {
    /// Consume pending wake bytes (nonblocking).
    pub fn drain(&self) {
        use std::io::Read;
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

#[cfg(unix)]
impl Pollable for WakeReader {
    fn raw(&self) -> RawSource {
        self.rx.as_raw_fd()
    }
}

/// Build a connected waker pair (both halves nonblocking).
#[cfg(unix)]
pub fn waker_pair() -> io::Result<(Waker, WakeReader)> {
    let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { tx: std::sync::Arc::new(tx) }, WakeReader { rx }))
}

/// No-op waker for the degenerate backend (its `wait` sleeps ≤1 ms, so
/// nothing ever parks long enough to need interrupting).
#[cfg(not(unix))]
#[derive(Clone)]
pub struct Waker;

/// No-op wake reader for the degenerate backend.
#[cfg(not(unix))]
pub struct WakeReader;

#[cfg(not(unix))]
impl Waker {
    /// Interrupt the poll loop (no-op off-Unix).
    pub fn wake(&self) {}
}

#[cfg(not(unix))]
impl WakeReader {
    /// Consume pending wake bytes (no-op off-Unix).
    pub fn drain(&self) {}
}

/// Build a connected waker pair (no-op halves off-Unix).
#[cfg(not(unix))]
pub fn waker_pair() -> io::Result<(Waker, WakeReader)> {
    Ok((Waker, WakeReader))
}

// ------------------------------------------------------------------ rlimits

#[cfg(target_os = "linux")]
mod rlimit {
    use super::io;

    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }

    const RLIMIT_NOFILE: i32 = 7;

    pub fn raise_nofile(min: u64) -> io::Result<u64> {
        let mut lim = RLimit { cur: 0, max: 0 };
        // SAFETY: lim is a valid out-pointer for the syscall.
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return Err(io::Error::last_os_error());
        }
        if lim.cur >= min {
            return Ok(lim.cur);
        }
        let want = RLimit { cur: min.min(lim.max), max: lim.max };
        // SAFETY: want is a valid in-pointer for the syscall.
        if unsafe { setrlimit(RLIMIT_NOFILE, &want) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(want.cur)
    }
}

/// Raise the soft `RLIMIT_NOFILE` toward `min` (capped at the hard
/// limit) and return the resulting soft limit. The high-fanout tests
/// and `bench_throughput --conns` call this before opening thousands
/// of loopback sockets; on non-Linux platforms it is a no-op reporting
/// "unlimited".
pub fn raise_nofile(min: u64) -> io::Result<u64> {
    #[cfg(target_os = "linux")]
    {
        rlimit::raise_nofile(min)
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = min;
        Ok(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn poller_sees_accept_and_data_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(&listener, 0, true, false).unwrap();

        let mut events = Vec::new();
        // nothing pending: a short wait comes back empty (or, on the
        // degenerate backend, with hints that accept() then refutes)
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();

        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let served = loop {
            assert!(std::time::Instant::now() < deadline, "accept readiness never arrived");
            poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
            if let Ok((sock, _)) = listener.accept() {
                break sock;
            }
        };
        served.set_nonblocking(true).unwrap();
        poller.register(&served, 7, true, true).unwrap();

        client.write_all(b"ping").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            assert!(std::time::Instant::now() < deadline, "data readiness never arrived");
            poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
        }
        poller.deregister(&served).unwrap();
    }

    #[test]
    fn waker_interrupts_a_parked_wait() {
        let (waker, reader) = waker_pair().unwrap();
        let mut poller = Poller::new().unwrap();
        poller.register(&reader, 1, true, false).unwrap();
        let t0 = std::time::Instant::now();
        waker.wake();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        reader.drain();
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "wake must interrupt the wait well before the timeout"
        );
        // double wake is harmless (the pipe dedups by design)
        waker.wake();
        waker.wake();
    }

    #[test]
    fn raise_nofile_reports_a_usable_limit() {
        let got = raise_nofile(256).expect("raising toward a tiny floor must not fail");
        assert!(got >= 256 || cfg!(not(target_os = "linux")), "soft limit {got} below floor");
    }
}
