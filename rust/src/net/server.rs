//! The TCP front door: a readiness-loop executor exposing the serving
//! cluster over `net::proto` (the wire protocol is unchanged from the
//! thread-per-connection era — only the machinery under it moved).
//!
//! Thread layout — O(workers), never O(connections):
//!
//! ```text
//!   deepcot-net-poll ──── the executor: one thread, one poller
//!     │  accepts nonblocking sockets (connection limit, auth/quota
//!     │  config), reads length-prefixed frames into per-connection
//!     │  job queues, flushes per-connection write queues, pumps
//!     │  split TickReceivers into TICK frames, reaps idle
//!     │  connections, and tears finished connections down
//!     │
//!     ├──► deepcot-net-worker-0..N ── fixed pool (N from NetConfig):
//!     │      decode → engine dispatch → encode, one job in flight
//!     │      per connection (strict FIFO, so replies leave in
//!     │      request order — the pipelined client counts on it)
//!     │
//!     └──◄ completions return over a channel + waker wake-up
//! ```
//!
//! Error discipline: engine failures reply typed [`WireError`] frames
//! (backpressure, saturation, shutdown all reach the client as the
//! same [`EngineError`] variant an in-process caller would see); a
//! PUSH to a stream this connection doesn't own answers `Hibernated`
//! when the engine holds it in the state store (reattach with an OPEN
//! carrying the resume id) and `StreamClosed` when it is truly gone;
//! malformed-but-framed requests reply `InvalidRequest` and the
//! connection keeps serving (the length prefix kept the byte stream
//! aligned); an undecodable length prefix tears the connection down —
//! resynchronization is impossible. Nothing the client sends can panic
//! the server.
//!
//! Admission control: beyond [`NetConfig::max_conns`] the acceptor
//! answers a best-effort `Saturated` and drops the socket; OPEN beyond
//! [`NetConfig::max_streams_per_conn`] answers `Saturated` with the
//! quota as capacity; with [`NetConfig::auth_token`] configured every
//! frame is rejected (and the connection torn down) until the
//! connection's first OPEN carrying the matching token.
//!
//! Backpressure: a connection with [`JOB_QUEUE_CAP`] undispatched
//! frames stops being read (its socket buffer, then the client,
//! fills); a write queue past [`WRITE_QUEUE_CAP`] — a client that
//! stopped reading — is torn down and counted in
//! `write_overflows`. Idle connections with no open streams are
//! reaped after [`NetConfig::idle_timeout`] (slow-loris defense), as
//! before the rewrite.
//!
//! Shutdown discipline ([`NetServer::shutdown`]): stop accepting,
//! announce a terminal `ShuttingDown` error for every stream still
//! open (flushed before the socket closes), close the engine
//! sessions, give write queues a short drain grace, then close every
//! socket and join the pool. Clients mid-stream get a typed terminal
//! error followed by EOF, never a hang.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use crate::config::EngineConfig;
use crate::coordinator::cluster::EngineHandle;
use crate::coordinator::session::{EngineError, Session, TickReceiver};
use crate::fault::{FaultInjector, FaultSite};
use crate::net::poller::{waker_pair, PollEvent, Poller, WakeReader, Waker};
use crate::net::proto::{self, Frame, RawFrame, WireError};
use crate::obs::expo;
use crate::obs::journal::EventKind;
use crate::obs::span::{Stage, StageSpans};
use crate::obs::{ObsHandle, ObsLevel};

/// Undispatched frames a connection may queue before the executor
/// stops reading its socket (resumes at half this).
pub const JOB_QUEUE_CAP: usize = 1024;

/// Pending write-queue bytes past which a connection that stopped
/// reading is torn down instead of buffered forever.
pub const WRITE_QUEUE_CAP: u64 = 64 * 1024 * 1024;

/// Ticks relayed per stream per executor pass (fairness bound).
const PUMP_BATCH: usize = 64;

/// How long the drain phase of a graceful shutdown waits for write
/// queues to flush before force-closing sockets.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(1);

/// Shared atomic counters (per-connection accounting rolls up here),
/// plus the net layer's boot clocks and its decode/encode stage spans.
struct Counters {
    connections_accepted: AtomicU64,
    connections_active: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    protocol_errors: AtomicU64,
    streams_opened: AtomicU64,
    shutdown_requests: AtomicU64,
    idle_conns_reaped: AtomicU64,
    connections_rejected: AtomicU64,
    auth_failures: AtomicU64,
    quota_rejected: AtomicU64,
    write_overflows: AtomicU64,
    workers: AtomicU64,
    jobs_depth: AtomicU64,
    jobs_depth_peak: AtomicU64,
    write_queue_bytes: AtomicU64,
    write_queue_peak: AtomicU64,
    polls: AtomicU64,
    boot: Instant,
    boot_unix_ms: u64,
    level: ObsLevel,
    spans: Mutex<StageSpans>,
}

/// A point-in-time snapshot of the net layer's counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetMetrics {
    /// Connections accepted since start.
    pub connections_accepted: u64,
    /// Connections currently serving.
    pub connections_active: u64,
    /// Frames successfully read off sockets.
    pub frames_in: u64,
    /// Frames written to sockets.
    pub frames_out: u64,
    /// Malformed frames answered with `InvalidRequest`.
    pub protocol_errors: u64,
    /// Streams opened over the wire.
    pub streams_opened: u64,
    /// SHUTDOWN frames honored.
    pub shutdown_requests: u64,
    /// Idle connections with no open streams reaped by the executor's
    /// idle sweep (slow-loris defense; a connection holding streams is
    /// never reaped).
    pub idle_conns_reaped: u64,
    /// Connections refused: over the connection limit, or a socket
    /// option the server requires (nonblocking mode) failed.
    pub connections_rejected: u64,
    /// Frames rejected for a missing or wrong shared-secret token.
    pub auth_failures: u64,
    /// OPENs refused by the per-connection stream quota.
    pub quota_rejected: u64,
    /// Connections torn down for exceeding [`WRITE_QUEUE_CAP`].
    pub write_overflows: u64,
    /// Fixed worker-pool size serving this front door.
    pub workers: u64,
    /// Jobs queued or in flight at the last executor pass.
    pub jobs_depth: u64,
    /// High-water mark of `jobs_depth`.
    pub jobs_depth_peak: u64,
    /// Write-queue bytes pending at the last executor pass.
    pub write_queue_bytes: u64,
    /// High-water mark of `write_queue_bytes`.
    pub write_queue_peak: u64,
    /// Executor poll-loop passes since start.
    pub polls: u64,
    /// Time since the net front door started.
    pub uptime: Duration,
    /// Wall-clock start of the net front door, ms since the Unix epoch.
    pub boot_unix_ms: u64,
    /// Net-layer stage spans (frame decode / encode), recorded at
    /// `obs >= spans`; empty otherwise.
    pub spans: StageSpans,
}

impl NetMetrics {
    /// One-line operator summary.
    pub fn report(&self) -> String {
        format!(
            "net: conns={}/{} frames={}in/{}out proto_errors={} streams={} shutdown_reqs={} \
             idle_reaped={} rejected={} auth_failed={} quota_rejected={} write_overflows={} \
             workers={} jobs_depth={}/{} write_queue={}B/{}B polls={}",
            self.connections_active,
            self.connections_accepted,
            self.frames_in,
            self.frames_out,
            self.protocol_errors,
            self.streams_opened,
            self.shutdown_requests,
            self.idle_conns_reaped,
            self.connections_rejected,
            self.auth_failures,
            self.quota_rejected,
            self.write_overflows,
            self.workers,
            self.jobs_depth,
            self.jobs_depth_peak,
            self.write_queue_bytes,
            self.write_queue_peak,
            self.polls,
        )
    }
}

impl Counters {
    fn new(level: ObsLevel) -> Self {
        let boot_unix_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        Self {
            connections_accepted: AtomicU64::new(0),
            connections_active: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            streams_opened: AtomicU64::new(0),
            shutdown_requests: AtomicU64::new(0),
            idle_conns_reaped: AtomicU64::new(0),
            connections_rejected: AtomicU64::new(0),
            auth_failures: AtomicU64::new(0),
            quota_rejected: AtomicU64::new(0),
            write_overflows: AtomicU64::new(0),
            workers: AtomicU64::new(0),
            jobs_depth: AtomicU64::new(0),
            jobs_depth_peak: AtomicU64::new(0),
            write_queue_bytes: AtomicU64::new(0),
            write_queue_peak: AtomicU64::new(0),
            polls: AtomicU64::new(0),
            boot: Instant::now(),
            boot_unix_ms,
            level,
            spans: Mutex::new(StageSpans::new()),
        }
    }

    fn spans_on(&self) -> bool {
        self.level >= ObsLevel::Spans
    }

    fn record_span(&self, stage: Stage, d: Duration) {
        self.spans.lock().unwrap_or_else(|p| p.into_inner()).record(stage, d);
    }

    fn snapshot(&self) -> NetMetrics {
        NetMetrics {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_active: self.connections_active.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            streams_opened: self.streams_opened.load(Ordering::Relaxed),
            shutdown_requests: self.shutdown_requests.load(Ordering::Relaxed),
            idle_conns_reaped: self.idle_conns_reaped.load(Ordering::Relaxed),
            connections_rejected: self.connections_rejected.load(Ordering::Relaxed),
            auth_failures: self.auth_failures.load(Ordering::Relaxed),
            quota_rejected: self.quota_rejected.load(Ordering::Relaxed),
            write_overflows: self.write_overflows.load(Ordering::Relaxed),
            workers: self.workers.load(Ordering::Relaxed),
            jobs_depth: self.jobs_depth.load(Ordering::Relaxed),
            jobs_depth_peak: self.jobs_depth_peak.load(Ordering::Relaxed),
            write_queue_bytes: self.write_queue_bytes.load(Ordering::Relaxed),
            write_queue_peak: self.write_queue_peak.load(Ordering::Relaxed),
            polls: self.polls.load(Ordering::Relaxed),
            uptime: self.boot.elapsed(),
            boot_unix_ms: self.boot_unix_ms,
            spans: self.spans.lock().unwrap_or_else(|p| p.into_inner()).clone(),
        }
    }
}

/// Cloneable snapshot handle to the net layer's counters, detached
/// from the [`NetServer`]'s lifetime — the exposition endpoint's
/// render closure holds one without borrowing the server.
#[derive(Clone)]
pub struct NetMetricsHandle {
    counters: Arc<Counters>,
}

impl NetMetricsHandle {
    /// Snapshot of the net layer's counters.
    pub fn snapshot(&self) -> NetMetrics {
        self.counters.snapshot()
    }
}

/// How long a connection may sit with zero open streams and zero
/// inbound bytes before the server reaps it (slow-loris defense).
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// Tuning knobs for the executor front door. Build one with
/// [`NetConfig::from_engine`] (the `net_*` `EngineConfig` knobs) or
/// field-by-field from `Default`, and start with
/// [`NetServer::start_with`].
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Worker threads decoding frames and driving the engine. `0`
    /// sizes from `available_parallelism`, clamped to `2..=8`.
    pub workers: usize,
    /// Hard cap on concurrently served connections; beyond it the
    /// acceptor answers a best-effort `Saturated` and drops the
    /// socket.
    pub max_conns: usize,
    /// Per-connection open-stream quota; OPEN beyond it answers
    /// `Saturated` with this quota as the capacity.
    pub max_streams_per_conn: usize,
    /// Shared-secret OPEN token. `Some(_)` rejects every frame until
    /// the connection's first OPEN carrying the matching token;
    /// `None` serves unauthenticated (the default, wire-compatible
    /// with every prior client).
    pub auth_token: Option<String>,
    /// Idle-connection reap window (see [`DEFAULT_IDLE_TIMEOUT`]).
    pub idle_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            workers: 0,
            max_conns: 16_384,
            max_streams_per_conn: 1024,
            auth_token: None,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
        }
    }
}

impl NetConfig {
    /// Lift the `net_*` knobs out of an [`EngineConfig`] (an empty
    /// `net_auth_token` means no authentication).
    pub fn from_engine(cfg: &EngineConfig) -> NetConfig {
        NetConfig {
            workers: cfg.net_workers,
            max_conns: cfg.net_max_conns,
            max_streams_per_conn: cfg.net_max_streams_per_conn,
            auth_token: if cfg.net_auth_token.is_empty() {
                None
            } else {
                Some(cfg.net_auth_token.clone())
            },
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
        }
    }

    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8)
        }
    }
}

/// The running TCP front door. Start with [`NetServer::start`] (or
/// [`NetServer::start_with`] for tuned limits); stop with
/// [`NetServer::shutdown`] (graceful drain).
pub struct NetServer {
    addr: SocketAddr,
    shutting_down: Arc<AtomicBool>,
    waker: Waker,
    executor: Option<JoinHandle<()>>,
    counters: Arc<Counters>,
    shutdown_req_rx: Receiver<()>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting connections against the given engine front door, with
    /// default [`NetConfig`] limits. Connections idle past
    /// [`DEFAULT_IDLE_TIMEOUT`] with no open streams are reaped; use
    /// [`NetServer::start_with_idle_timeout`] to tune that window or
    /// [`NetServer::start_with`] for the full knob set.
    pub fn start<A: ToSocketAddrs>(addr: A, engine: EngineHandle) -> io::Result<NetServer> {
        Self::start_with(addr, engine, NetConfig::default())
    }

    /// [`NetServer::start`] with an explicit idle-connection timeout. A
    /// connection that has sent no bytes for `idle_timeout` AND holds
    /// no open streams is closed and counted in
    /// [`NetMetrics::idle_conns_reaped`] — a half-open or deliberately
    /// slow client cannot pin an fd forever. A connection with open
    /// streams is never reaped, however quiet (streaming clients
    /// legitimately sit idle between pushes).
    pub fn start_with_idle_timeout<A: ToSocketAddrs>(
        addr: A,
        engine: EngineHandle,
        idle_timeout: Duration,
    ) -> io::Result<NetServer> {
        Self::start_with(addr, engine, NetConfig { idle_timeout, ..NetConfig::default() })
    }

    /// Bind and serve with explicit [`NetConfig`] limits: worker-pool
    /// size, connection cap, per-connection stream quota, shared
    /// OPEN token, and the idle-reap window.
    pub fn start_with<A: ToSocketAddrs>(
        addr: A,
        engine: EngineHandle,
        cfg: NetConfig,
    ) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let mut poller = Poller::new()?;
        let (waker, wake_rx) = waker_pair()?;
        poller.register(&listener, TOKEN_LISTENER, true, false)?;
        poller.register(&wake_rx, TOKEN_WAKER, true, false)?;

        let shutting_down = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::new(engine.obs().level()));
        let workers_n = cfg.resolved_workers();
        counters.workers.store(workers_n as u64, Ordering::Relaxed);

        let (work_tx, work_rx) = mpsc::channel::<Job>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (comp_tx, comp_rx) = mpsc::channel::<Completion>();
        let (shutdown_req_tx, shutdown_req_rx) = mpsc::channel::<()>();

        let mut worker_handles = Vec::with_capacity(workers_n);
        for i in 0..workers_n {
            let cx = WorkerCtx {
                engine: engine.clone(),
                counters: Arc::clone(&counters),
                obs: engine.obs().clone(),
                comp_tx: comp_tx.clone(),
                shutdown_req_tx: shutdown_req_tx.clone(),
                waker: waker.clone(),
                auth_token: cfg.auth_token.clone(),
                max_streams_per_conn: cfg.max_streams_per_conn,
            };
            let rx = Arc::clone(&work_rx);
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("deepcot-net-worker-{i}"))
                    .spawn(move || worker_main(rx, cx))?,
            );
        }

        let sh = ExecShared {
            counters: Arc::clone(&counters),
            obs: engine.obs().clone(),
            inj: engine.fault(),
            cfg,
            shutting_down: Arc::clone(&shutting_down),
            work_tx,
        };
        let executor = std::thread::Builder::new()
            .name("deepcot-net-poll".into())
            .spawn(move || run_executor(listener, poller, wake_rx, comp_rx, worker_handles, sh))?;

        Ok(NetServer {
            addr,
            shutting_down,
            waker,
            executor: Some(executor),
            counters,
            shutdown_req_rx,
        })
    }

    /// The address the server actually listens on (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the net layer's counters.
    pub fn metrics(&self) -> NetMetrics {
        self.counters.snapshot()
    }

    /// A counters handle that outlives this server value (for the
    /// metrics endpoint's render closure).
    pub fn metrics_handle(&self) -> NetMetricsHandle {
        NetMetricsHandle { counters: Arc::clone(&self.counters) }
    }

    /// Block until some client sends a SHUTDOWN frame, or `timeout`
    /// passes (`true` = shutdown was requested). The server keeps
    /// serving either way — pair with [`NetServer::shutdown`]. A
    /// defunct worker pool (every request source gone) also reports
    /// `true`: there is nothing left to wait for but the drain.
    pub fn wait_shutdown_requested(&self, timeout: Duration) -> bool {
        match self.shutdown_req_rx.recv_timeout(timeout) {
            Ok(()) => true,
            Err(RecvTimeoutError::Disconnected) => true,
            Err(RecvTimeoutError::Timeout) => false,
        }
    }

    /// Graceful drain: stop accepting, announce terminal
    /// `ShuttingDown` errors for live streams and close their engine
    /// sessions, flush write queues (bounded grace), close every
    /// socket, and join the executor and worker pool. Engine shutdown
    /// is the caller's (the engine may outlive the front door).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        self.waker.wake();
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// One stream's engine session, as the worker pool sees it.
struct CoreEntry {
    sess: Session,
    /// Set before a deliberate close so the executor's pump drains the
    /// tail silently instead of reporting the disconnect as an error.
    closed: Arc<AtomicBool>,
}

/// The worker-facing half of a connection: its engine sessions and
/// auth state, behind one mutex a worker holds for a whole job (so
/// teardown serializes behind in-flight engine calls).
#[derive(Default)]
struct ConnCore {
    sessions: BTreeMap<u64, CoreEntry>,
    /// Torn down: jobs still in flight complete as no-ops.
    dead: bool,
    /// Passed the shared-token gate (always false until the first
    /// authenticated OPEN when a token is configured).
    authed: bool,
}

/// One inbound frame (opcode + body, prefix stripped) bound for the
/// worker pool.
struct Job {
    conn: u64,
    frame: Vec<u8>,
    core: Arc<Mutex<ConnCore>>,
}

/// Executor-side state changes a worker's job produced.
enum Effect {
    /// A new stream: pump its TickReceiver into this connection.
    StreamOpened { stream: u64, rx: TickReceiver, closed: Arc<AtomicBool> },
    /// A deliberate close: drain the pump's buffered tail (in order,
    /// ahead of the CLOSED reply) and drop it.
    StreamClosed { stream: u64 },
    /// Tear the connection down once the reply is flushed (auth
    /// failure).
    Teardown,
}

/// A worker's result: the encoded reply bytes (possibly empty) plus
/// side effects for the executor.
struct Completion {
    conn: u64,
    reply: Vec<u8>,
    effects: Vec<Effect>,
}

/// Executor-owned per-connection state.
struct Conn {
    sock: TcpStream,
    /// Unparsed inbound bytes (frames are extracted incrementally).
    rbuf: Vec<u8>,
    /// Pending outbound bytes; `out[out_off..]` is unwritten.
    out: Vec<u8>,
    out_off: usize,
    /// Extracted frames awaiting a worker, strict FIFO.
    jobs: VecDeque<Vec<u8>>,
    /// One job in flight at the pool (reply order == request order).
    busy: bool,
    core: Arc<Mutex<ConnCore>>,
    /// Live pump count for this connection (idle-reap gate).
    streams: usize,
    last_activity: Instant,
    /// Job queue at cap: socket reads suspended.
    paused: bool,
    read_closed: bool,
    /// Finish queued work, flush, then tear down.
    closing: bool,
    /// NetWrite fault fired: half a frame is on the queue; enqueue
    /// nothing more, flush, tear down (the client must detect the
    /// desync).
    poisoned: bool,
    /// Tear down now, no flush (write error / overflow).
    kill: bool,
    cur_r: bool,
    cur_w: bool,
}

impl Conn {
    fn new(sock: TcpStream) -> Conn {
        Conn {
            sock,
            rbuf: Vec::with_capacity(4096),
            out: Vec::with_capacity(4096),
            out_off: 0,
            jobs: VecDeque::new(),
            busy: false,
            core: Arc::new(Mutex::new(ConnCore::default())),
            streams: 0,
            last_activity: Instant::now(),
            paused: false,
            read_closed: false,
            closing: false,
            poisoned: false,
            kill: false,
            cur_r: true,
            cur_w: false,
        }
    }
}

/// A split TickReceiver the executor polls into its connection's
/// write queue (the forwarder-thread replacement).
struct Pump {
    conn: u64,
    rx: TickReceiver,
    closed: Arc<AtomicBool>,
    /// Encoded CLOSED reply held back until the stream's channel goes
    /// terminal, so every queued tick reaches the wire first — the
    /// order the old forwarder-join guaranteed. (The client never
    /// pipelines past a CLOSE, so the reply-order deviation is
    /// unobservable.)
    terminal: Option<Vec<u8>>,
}

/// Context shared by the executor's helper functions.
struct ExecShared {
    counters: Arc<Counters>,
    obs: ObsHandle,
    inj: FaultInjector,
    cfg: NetConfig,
    shutting_down: Arc<AtomicBool>,
    work_tx: Sender<Job>,
}

/// Incrementally maintained gauges (never recomputed O(conns)).
#[derive(Default)]
struct Totals {
    jobs: u64,
    wq: u64,
}

fn conn_finished(conn: &Conn) -> bool {
    conn.kill
        || (conn.closing && !conn.busy && conn.jobs.is_empty() && conn.out_off >= conn.out.len())
}

fn update_interest(poller: &mut Poller, token: u64, conn: &mut Conn) {
    let want_r = !conn.read_closed && !conn.paused;
    let want_w = conn.out_off < conn.out.len();
    if (want_r != conn.cur_r || want_w != conn.cur_w)
        && poller.modify(&conn.sock, token, want_r, want_w).is_ok()
    {
        conn.cur_r = want_r;
        conn.cur_w = want_w;
    }
}

/// Append one encoded frame to the connection's write queue, honoring
/// the NetWrite fault (half the frame, then poison) and the write
/// queue cap.
fn enqueue_bytes(conn: &mut Conn, bytes: &[u8], sh: &ExecShared, tot: &mut Totals) {
    if conn.poisoned || conn.kill {
        return;
    }
    if sh.inj.fire(FaultSite::NetWrite) {
        // injected partial write: flush half a frame then die, the
        // worst desync a crashing peer can leave on the wire — the
        // client's length prefix discipline must reject the tail
        let half = bytes.len() / 2;
        conn.out.extend_from_slice(&bytes[..half]);
        tot.wq += half as u64;
        conn.poisoned = true;
        conn.closing = true;
        conn.read_closed = true;
        tot.jobs = tot.jobs.saturating_sub(conn.jobs.len() as u64);
        conn.jobs.clear();
        return;
    }
    let queued = (conn.out.len() - conn.out_off) as u64;
    if queued + bytes.len() as u64 > WRITE_QUEUE_CAP {
        // the client stopped reading; buffering forever is the old
        // unbounded-growth bug in a new coat
        sh.counters.write_overflows.fetch_add(1, Ordering::Relaxed);
        sh.obs.event(EventKind::WriteOverflow, 0, -1, queued);
        conn.kill = true;
        return;
    }
    conn.out.extend_from_slice(bytes);
    tot.wq += bytes.len() as u64;
    sh.counters.frames_out.fetch_add(1, Ordering::Relaxed);
}

fn try_flush(conn: &mut Conn, tot: &mut Totals) {
    while conn.out_off < conn.out.len() {
        match (&conn.sock).write(&conn.out[conn.out_off..]) {
            Ok(0) => {
                conn.kill = true;
                break;
            }
            Ok(n) => {
                conn.out_off += n;
                tot.wq = tot.wq.saturating_sub(n as u64);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.kill = true;
                break;
            }
        }
    }
    if conn.out_off >= conn.out.len() {
        conn.out.clear();
        conn.out_off = 0;
    } else if conn.out_off > 512 * 1024 {
        conn.out.drain(..conn.out_off);
        conn.out_off = 0;
    }
}

fn maybe_dispatch(token: u64, conn: &mut Conn, sh: &ExecShared) {
    if conn.busy || conn.closing {
        return;
    }
    if let Some(frame) = conn.jobs.pop_front() {
        conn.busy = true;
        let _ = sh.work_tx.send(Job { conn: token, frame, core: Arc::clone(&conn.core) });
    }
}

/// Slice complete frames out of the connection's read buffer into its
/// job queue, stopping at the job cap (backpressure pause) and firing
/// the NetRead fault per extracted frame (injected read fault ==
/// silent teardown, exactly like a torn socket).
fn extract_frames(token: u64, conn: &mut Conn, sh: &ExecShared, tot: &mut Totals) {
    let mut pos = 0usize;
    while !conn.closing && conn.jobs.len() < JOB_QUEUE_CAP {
        let avail = conn.rbuf.len() - pos;
        if avail < 4 {
            break;
        }
        let b = &conn.rbuf[pos..pos + 4];
        let len = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
        if len == 0 || len > proto::MAX_FRAME_LEN {
            // undecodable prefix: resynchronization is impossible
            conn.closing = true;
            conn.read_closed = true;
            conn.rbuf.clear();
            pos = 0;
            break;
        }
        if avail < 4 + len {
            break;
        }
        let frame = conn.rbuf[pos + 4..pos + 4 + len].to_vec();
        pos += 4 + len;
        if sh.inj.fire(FaultSite::NetRead) {
            // injected transport fault: behave exactly like a socket
            // read error — silent teardown (clients must recover via
            // reconnect + resume)
            conn.closing = true;
            conn.read_closed = true;
            conn.rbuf.clear();
            pos = 0;
            break;
        }
        sh.counters.frames_in.fetch_add(1, Ordering::Relaxed);
        conn.jobs.push_back(frame);
        tot.jobs += 1;
    }
    if pos > 0 {
        conn.rbuf.drain(..pos);
    }
    if conn.jobs.len() >= JOB_QUEUE_CAP {
        conn.paused = true;
    }
    maybe_dispatch(token, conn, sh);
}

/// Drain a socket's readable bytes (bounded per pass; level-triggered
/// readiness re-reports the rest) and extract frames.
fn conn_read(token: u64, conn: &mut Conn, sh: &ExecShared, tot: &mut Totals, scratch: &mut [u8]) {
    if conn.read_closed || conn.paused {
        return;
    }
    let mut rounds = 0;
    loop {
        match (&conn.sock).read(scratch) {
            Ok(0) => {
                // clean client EOF: finish queued work, flush, close
                conn.read_closed = true;
                conn.closing = true;
                break;
            }
            Ok(n) => {
                conn.last_activity = Instant::now();
                conn.rbuf.extend_from_slice(&scratch[..n]);
                rounds += 1;
                if n < scratch.len() || rounds >= 8 {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                // torn socket: flush whatever replies are pending, then
                // tear down (sessions close silently)
                conn.read_closed = true;
                conn.closing = true;
                break;
            }
        }
    }
    extract_frames(token, conn, sh, tot);
}

/// Relay a terminal pump's buffered tail into its connection's write
/// queue (deliberate closes deliver queued ticks in order before the
/// CLOSED reply, as the forwarder threads used to).
fn drain_pump(stream: u64, pump: &Pump, conn: &mut Conn, sh: &ExecShared, tot: &mut Totals) {
    let mut buf = Vec::new();
    while let Ok(Some(r)) = pump.rx.try_recv() {
        let t = Instant::now();
        proto::write_tick(&mut buf, stream, r.tick, &r.logits, &r.out);
        if sh.counters.spans_on() {
            sh.counters.record_span(Stage::NetEncode, t.elapsed());
        }
        enqueue_bytes(conn, &buf, sh, tot);
    }
}

/// Close a connection now: mark its core dead (in-flight jobs become
/// no-ops), close its sessions, drop its pumps, deregister and drop
/// the socket (the client sees EOF).
fn teardown_conn(
    token: u64,
    conns: &mut HashMap<u64, Conn>,
    pumps: &mut HashMap<u64, Pump>,
    poller: &mut Poller,
    sh: &ExecShared,
    tot: &mut Totals,
) {
    let Some(conn) = conns.remove(&token) else { return };
    tot.jobs = tot.jobs.saturating_sub(conn.jobs.len() as u64);
    tot.wq = tot.wq.saturating_sub((conn.out.len() - conn.out_off) as u64);
    let _ = poller.deregister(&conn.sock);
    let sessions = {
        let mut core = conn.core.lock().unwrap_or_else(|p| p.into_inner());
        core.dead = true;
        std::mem::take(&mut core.sessions)
    };
    for (_, entry) in sessions {
        entry.closed.store(true, Ordering::SeqCst);
        entry.sess.close();
    }
    pumps.retain(|_, p| p.conn != token);
    sh.counters.connections_active.fetch_sub(1, Ordering::Relaxed);
    // conn (and its socket) drops here
}

/// Over the connection limit (or a required socket option failed):
/// count it, journal it, best-effort a typed `Saturated` goodbye.
fn reject_conn(sock: TcpStream, sh: &ExecShared) {
    sh.counters.connections_rejected.fetch_add(1, Ordering::Relaxed);
    sh.obs.event(EventKind::ConnRejected, 0, -1, sh.cfg.max_conns as u64);
    let mut buf = Vec::new();
    Frame::Error(WireError::from_engine(0, &EngineError::Saturated { capacity: sh.cfg.max_conns }))
        .encode_into(&mut buf);
    let _ = sock.set_nonblocking(true);
    let _ = (&sock).write(&buf);
}

fn accept_ready(
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    poller: &mut Poller,
    next_token: &mut u64,
    sh: &ExecShared,
) {
    loop {
        match listener.accept() {
            Ok((sock, _peer)) => {
                if sh.shutting_down.load(Ordering::SeqCst) {
                    continue; // drain the backlog; drop late arrivals
                }
                if conns.len() >= sh.cfg.max_conns {
                    reject_conn(sock, sh);
                    continue;
                }
                if sock.set_nonblocking(true).is_err() {
                    // a connection the poll loop can't drive would hang
                    // forever — reject it rather than serve it broken
                    sh.obs.event(EventKind::SockOptFailed, 0, -1, 0);
                    sh.counters.connections_rejected.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                if sock.set_nodelay(true).is_err() {
                    // latency hint only: journal it and keep serving
                    sh.obs.event(EventKind::SockOptFailed, 0, -1, 1);
                }
                let token = *next_token;
                *next_token += 1;
                let conn = Conn::new(sock);
                if poller.register(&conn.sock, token, true, false).is_err() {
                    continue; // conn drops, client sees EOF
                }
                sh.counters.connections_accepted.fetch_add(1, Ordering::Relaxed);
                sh.counters.connections_active.fetch_add(1, Ordering::Relaxed);
                conns.insert(token, conn);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            // WouldBlock (drained) or transient accept failure (EMFILE
            // etc.): return to the poll loop — its timeout paces
            // retries, no busy spin
            Err(_) => break,
        }
    }
}

/// The executor: one readiness loop owning every socket, write queue,
/// and tick pump. Exits (joining the worker pool) when the shutdown
/// flag is raised and the drain completes.
fn run_executor(
    listener: TcpListener,
    mut poller: Poller,
    wake_rx: WakeReader,
    comp_rx: Receiver<Completion>,
    worker_handles: Vec<JoinHandle<()>>,
    sh: ExecShared,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut pumps: HashMap<u64, Pump> = HashMap::new();
    let mut events: Vec<PollEvent> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut tick_buf: Vec<u8> = Vec::with_capacity(4096);
    let mut to_close: Vec<u64> = Vec::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut tot = Totals::default();
    let mut announced = false;
    let mut drain_deadline = Instant::now();
    let idle_sweep_every = (sh.cfg.idle_timeout / 4)
        .clamp(Duration::from_millis(10), Duration::from_secs(1));
    let mut last_idle_sweep = Instant::now();

    loop {
        // ticks arrive from engine shards with no waker of their own:
        // poll tightly while pumps exist, lazily when none do
        let timeout = if pumps.is_empty() && !announced {
            Duration::from_millis(50)
        } else {
            Duration::from_millis(1)
        };
        if poller.wait(&mut events, Some(timeout)).is_err() {
            // a broken poller cannot serve; treat as shutdown
            sh.shutting_down.store(true, Ordering::SeqCst);
        }
        sh.counters.polls.fetch_add(1, Ordering::Relaxed);
        to_close.clear();

        // 1. socket readiness
        for &ev in &events {
            if ev.token == TOKEN_LISTENER {
                accept_ready(&listener, &mut conns, &mut poller, &mut next_token, &sh);
            } else if ev.token == TOKEN_WAKER {
                wake_rx.drain();
            } else if let Some(conn) = conns.get_mut(&ev.token) {
                if ev.readable || ev.hangup {
                    conn_read(ev.token, conn, &sh, &mut tot, &mut scratch);
                }
                try_flush(conn, &mut tot);
                update_interest(&mut poller, ev.token, conn);
                if conn_finished(conn) {
                    to_close.push(ev.token);
                }
            }
        }

        // 2. worker completions
        loop {
            let Ok(mut c) = comp_rx.try_recv() else { break };
            tot.jobs = tot.jobs.saturating_sub(1);
            let Some(conn) = conns.get_mut(&c.conn) else { continue };
            conn.busy = false;
            // a deliberate close defers its CLOSED reply onto the pump:
            // the stream's remaining ticks reach the wire first, then
            // the reply — the order the forwarder-join used to force
            for eff in &c.effects {
                if let Effect::StreamClosed { stream } = eff {
                    if let Some(p) = pumps.get_mut(stream) {
                        if p.conn == c.conn {
                            p.terminal = Some(std::mem::take(&mut c.reply));
                        }
                    }
                }
            }
            if !c.reply.is_empty() {
                enqueue_bytes(conn, &c.reply, &sh, &mut tot);
            }
            for eff in c.effects {
                match eff {
                    Effect::StreamOpened { stream, rx, closed } => {
                        if let Some(old) = pumps.remove(&stream) {
                            // a resume re-homed a stream this connection
                            // already held: relay the zombie's tail
                            // silently (its session was forgotten, not
                            // closed), then replace it
                            if old.conn == c.conn {
                                drain_pump(stream, &old, conn, &sh, &mut tot);
                                conn.streams = conn.streams.saturating_sub(1);
                            }
                        }
                        pumps.insert(stream, Pump { conn: c.conn, rx, closed, terminal: None });
                        conn.streams += 1;
                    }
                    Effect::StreamClosed { .. } => {} // handled above
                    Effect::Teardown => {
                        conn.closing = true;
                        conn.read_closed = true;
                        tot.jobs = tot.jobs.saturating_sub(conn.jobs.len() as u64);
                        conn.jobs.clear();
                    }
                }
            }
            maybe_dispatch(c.conn, conn, &sh);
            if conn.paused && conn.jobs.len() <= JOB_QUEUE_CAP / 2 {
                conn.paused = false;
                // complete frames may be parked in rbuf from before the
                // pause; a quiet socket would never re-trigger extraction
                extract_frames(c.conn, conn, &sh, &mut tot);
            }
            try_flush(conn, &mut tot);
            update_interest(&mut poller, c.conn, conn);
            if conn_finished(conn) {
                to_close.push(c.conn);
            }
        }

        // 3. tick pumps (bounded per stream per pass for fairness)
        let mut dead_pumps: Vec<u64> = Vec::new();
        for (&stream, pump) in pumps.iter_mut() {
            let Some(conn) = conns.get_mut(&pump.conn) else {
                dead_pumps.push(stream);
                continue;
            };
            let mut relayed = 0;
            while relayed < PUMP_BATCH {
                match pump.rx.try_recv() {
                    Ok(Some(r)) => {
                        relayed += 1;
                        let t = Instant::now();
                        proto::write_tick(&mut tick_buf, stream, r.tick, &r.logits, &r.out);
                        if sh.counters.spans_on() {
                            sh.counters.record_span(Stage::NetEncode, t.elapsed());
                        }
                        enqueue_bytes(conn, &tick_buf, &sh, &mut tot);
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // stream torn down under the connection. A
                        // deliberate close (flag set) ends silently;
                        // anything else (eviction, engine or server
                        // shutdown) announces a terminal typed error.
                        if !pump.closed.load(Ordering::SeqCst) {
                            let e = if sh.shutting_down.load(Ordering::SeqCst) {
                                EngineError::ShuttingDown
                            } else {
                                e
                            };
                            let mut ebuf = Vec::new();
                            Frame::Error(WireError::from_engine(stream, &e))
                                .encode_into(&mut ebuf);
                            enqueue_bytes(conn, &ebuf, &sh, &mut tot);
                        }
                        if let Some(t) = pump.terminal.take() {
                            // the deferred CLOSED reply, after the tail
                            enqueue_bytes(conn, &t, &sh, &mut tot);
                        }
                        conn.streams = conn.streams.saturating_sub(1);
                        conn.last_activity = Instant::now();
                        dead_pumps.push(stream);
                        break;
                    }
                }
            }
            try_flush(conn, &mut tot);
            update_interest(&mut poller, pump.conn, conn);
            if conn_finished(conn) {
                to_close.push(pump.conn);
            }
        }
        for s in dead_pumps {
            pumps.remove(&s);
        }

        // 4. graceful shutdown: announce once, then drain with grace
        if sh.shutting_down.load(Ordering::SeqCst) && !announced {
            announced = true;
            drain_deadline = Instant::now() + SHUTDOWN_GRACE;
            for (&token, conn) in conns.iter_mut() {
                let sessions = {
                    let mut core = conn.core.lock().unwrap_or_else(|p| p.into_inner());
                    core.dead = true;
                    std::mem::take(&mut core.sessions)
                };
                for (stream, entry) in sessions {
                    entry.closed.store(true, Ordering::SeqCst);
                    let mut ebuf = Vec::new();
                    Frame::Error(WireError::from_engine(stream, &EngineError::ShuttingDown))
                        .encode_into(&mut ebuf);
                    enqueue_bytes(conn, &ebuf, &sh, &mut tot);
                    entry.sess.close();
                }
                tot.jobs = tot.jobs.saturating_sub(conn.jobs.len() as u64);
                conn.jobs.clear();
                conn.closing = true;
                conn.read_closed = true;
                try_flush(conn, &mut tot);
                update_interest(&mut poller, token, conn);
                if conn_finished(conn) {
                    to_close.push(token);
                }
            }
        }

        // 5. idle sweep (cheap, and only every few hundred passes)
        if !announced && last_idle_sweep.elapsed() >= idle_sweep_every {
            last_idle_sweep = Instant::now();
            for (&token, conn) in conns.iter_mut() {
                if conn.closing
                    || conn.busy
                    || conn.streams > 0
                    || !conn.jobs.is_empty()
                    || conn.out_off < conn.out.len()
                {
                    continue;
                }
                let idle = conn.last_activity.elapsed();
                if idle < sh.cfg.idle_timeout {
                    continue;
                }
                // double-check under the lock (the mirror can lag a
                // just-opened stream): never reap a streaming client
                let empty =
                    conn.core.lock().unwrap_or_else(|p| p.into_inner()).sessions.is_empty();
                if empty {
                    sh.counters.idle_conns_reaped.fetch_add(1, Ordering::Relaxed);
                    sh.obs.event(EventKind::ConnReaped, 0, -1, idle.as_millis() as u64);
                    to_close.push(token);
                }
            }
        }

        // 6. teardowns
        if !to_close.is_empty() {
            to_close.sort_unstable();
            to_close.dedup();
            for &t in &to_close {
                teardown_conn(t, &mut conns, &mut pumps, &mut poller, &sh, &mut tot);
            }
        }

        // 7. gauges + exit
        sh.counters.jobs_depth.store(tot.jobs, Ordering::Relaxed);
        sh.counters.jobs_depth_peak.fetch_max(tot.jobs, Ordering::Relaxed);
        sh.counters.write_queue_bytes.store(tot.wq, Ordering::Relaxed);
        sh.counters.write_queue_peak.fetch_max(tot.wq, Ordering::Relaxed);
        if announced && (conns.is_empty() || Instant::now() >= drain_deadline) {
            let rest: Vec<u64> = conns.keys().copied().collect();
            for t in rest {
                teardown_conn(t, &mut conns, &mut pumps, &mut poller, &sh, &mut tot);
            }
            break;
        }
    }

    let counters = Arc::clone(&sh.counters);
    drop(listener);
    drop(sh); // drops the last work sender: the pool drains and exits
    for w in worker_handles {
        let _ = w.join();
    }
    counters.jobs_depth.store(0, Ordering::Relaxed);
    counters.write_queue_bytes.store(0, Ordering::Relaxed);
}

/// Context a worker thread serves jobs with.
struct WorkerCtx {
    engine: EngineHandle,
    counters: Arc<Counters>,
    obs: ObsHandle,
    comp_tx: Sender<Completion>,
    shutdown_req_tx: Sender<()>,
    waker: Waker,
    auth_token: Option<String>,
    max_streams_per_conn: usize,
}

fn worker_main(rx: Arc<Mutex<Receiver<Job>>>, cx: WorkerCtx) {
    loop {
        let job = {
            let g = rx.lock().unwrap_or_else(|p| p.into_inner());
            g.recv()
        };
        let Ok(job) = job else { return };
        let (comp, notify_shutdown) = handle_job(job, &cx);
        let _ = cx.comp_tx.send(comp);
        cx.waker.wake();
        if notify_shutdown {
            // after the completion: the SHUTDOWN_OK ack reaches the
            // write queue before the owner can start the drain
            let _ = cx.shutdown_req_tx.send(());
        }
    }
}

fn encode_reply(frame: &Frame, buf: &mut Vec<u8>, counters: &Counters) {
    let t = Instant::now();
    frame.encode_into(buf);
    if counters.spans_on() {
        counters.record_span(Stage::NetEncode, t.elapsed());
    }
}

fn invalid(stream: u64, e: &proto::ProtoError) -> Frame {
    Frame::Error(WireError::from_engine(stream, &EngineError::InvalidRequest(e.to_string())))
}

fn auth_failure(conn: u64, cx: &WorkerCtx) -> Completion {
    cx.counters.auth_failures.fetch_add(1, Ordering::Relaxed);
    cx.obs.event(EventKind::AuthFailure, 0, -1, 0);
    let mut buf = Vec::new();
    encode_reply(
        &Frame::Error(WireError::from_engine(
            0,
            &EngineError::InvalidRequest(
                "authentication failed: this server requires an OPEN carrying the shared token"
                    .into(),
            ),
        )),
        &mut buf,
        &cx.counters,
    );
    Completion { conn, reply: buf, effects: vec![Effect::Teardown] }
}

/// Decode one frame, drive the engine, encode the reply. Holds the
/// connection's core lock for the whole job so teardown serializes
/// behind in-flight engine calls. Returns the completion and whether
/// a SHUTDOWN was requested.
fn handle_job(job: Job, cx: &WorkerCtx) -> (Completion, bool) {
    let mut core = job.core.lock().unwrap_or_else(|p| p.into_inner());
    let mut effects: Vec<Effect> = Vec::new();
    let mut reply_buf: Vec<u8> = Vec::new();
    let counters = &cx.counters;
    if core.dead {
        return (Completion { conn: job.conn, reply: reply_buf, effects }, false);
    }
    let spans_on = counters.spans_on();
    let t_decode = Instant::now();
    let raw = match RawFrame::parse(&job.frame) {
        Ok(raw) => raw,
        Err(e) => {
            counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            cx.obs.event(EventKind::ProtoError, 0, -1, 0);
            encode_reply(&invalid(0, &e), &mut reply_buf, counters);
            return (Completion { conn: job.conn, reply: reply_buf, effects }, false);
        }
    };

    // PUSH dominates steady state: decode it zero-copy off the frame
    // bytes before falling back to the owned decoder
    let mut tokens = Vec::new();
    if let Ok(stream) = raw.push_fields_into(&mut tokens) {
        if spans_on {
            counters.record_span(Stage::NetDecode, t_decode.elapsed());
        }
        if cx.auth_token.is_some() && !core.authed {
            return (auth_failure(job.conn, cx), false);
        }
        let reply = match core.sessions.get(&stream) {
            None => {
                let id = crate::coordinator::slots::StreamId(stream);
                // "hibernated" and "gone" must stay distinguishable: a
                // hibernated stream is reattachable via OPEN with a
                // resume id, a closed one is not
                let e = if cx.engine.is_hibernated(id) {
                    EngineError::Hibernated(id)
                } else {
                    EngineError::StreamClosed(id)
                };
                Frame::Error(WireError::from_engine(stream, &e))
            }
            Some(entry) => match entry.sess.push(tokens) {
                Ok(()) => Frame::PushOk { stream },
                Err(e) => Frame::Error(WireError::from_engine(stream, &e)),
            },
        };
        encode_reply(&reply, &mut reply_buf, counters);
        return (Completion { conn: job.conn, reply: reply_buf, effects }, false);
    }

    let decoded = raw.to_frame();
    if spans_on {
        counters.record_span(Stage::NetDecode, t_decode.elapsed());
    }

    // central auth gate: with a token configured, nothing but an OPEN
    // carrying that token is served until the connection authenticates
    if let Some(want) = cx.auth_token.as_deref() {
        let open_token = match &decoded {
            Ok(Frame::OpenAuth { token, .. }) => Some(token.as_str()),
            _ => None,
        };
        let pass = match open_token {
            Some(got) if got == want => {
                core.authed = true;
                true
            }
            Some(_) => false, // wrong token is always a failure
            None => core.authed,
        };
        if !pass {
            return (auth_failure(job.conn, cx), false);
        }
    }

    let mut notify_shutdown = false;
    let reply = match decoded {
        Ok(Frame::Open { resume }) | Ok(Frame::OpenAuth { resume, .. }) => {
            if core.sessions.len() >= cx.max_streams_per_conn {
                counters.quota_rejected.fetch_add(1, Ordering::Relaxed);
                Frame::Error(WireError::from_engine(
                    resume.unwrap_or(0),
                    &EngineError::Saturated { capacity: cx.max_streams_per_conn },
                ))
            } else {
                // fresh open, or reattach to a stream recovered from
                // the state store (same id, ticks continue where the
                // previous run left off)
                let opened = match resume {
                    None => cx.engine.open(),
                    Some(id) => cx.engine.resume(crate::coordinator::slots::StreamId(id)),
                };
                match opened {
                    Ok(mut sess) => {
                        let stream = sess.id().0;
                        // the receiving half goes to the executor's
                        // pump; the session half stays for push/close
                        let rx = sess.split_receiver().expect("fresh session has its receiver");
                        let closed = Arc::new(AtomicBool::new(false));
                        counters.streams_opened.fetch_add(1, Ordering::Relaxed);
                        if let Some(old) = core.sessions.remove(&stream) {
                            // a resume only succeeds when the stream
                            // lost its owner (shard crash re-home), so
                            // this entry is a zombie — defuse its RAII
                            // close or it would tear down the stream we
                            // just resumed
                            old.closed.store(true, Ordering::SeqCst);
                            old.sess.forget();
                        }
                        core.sessions
                            .insert(stream, CoreEntry { sess, closed: Arc::clone(&closed) });
                        effects.push(Effect::StreamOpened { stream, rx, closed });
                        Frame::Opened { stream }
                    }
                    Err(e) => Frame::Error(WireError::from_engine(resume.unwrap_or(0), &e)),
                }
            }
        }
        Ok(Frame::Close { stream }) => match core.sessions.remove(&stream) {
            Some(entry) => {
                entry.closed.store(true, Ordering::SeqCst);
                entry.sess.close();
                effects.push(Effect::StreamClosed { stream });
                Frame::Closed { stream }
            }
            None => Frame::Error(WireError::from_engine(
                stream,
                &EngineError::StreamClosed(crate::coordinator::slots::StreamId(stream)),
            )),
        },
        Ok(Frame::Metrics) => match cx.engine.metrics() {
            Ok(m) => Frame::MetricsReport {
                report: format!("{}\n  {}", m.report(), counters.snapshot().report()),
            },
            Err(e) => Frame::Error(WireError::from_engine(0, &e)),
        },
        Ok(Frame::MetricsProm) => {
            // the same document the HTTP /metrics endpoint serves,
            // carried in a MetricsReport frame
            match cx.engine.metrics() {
                Ok(m) => Frame::MetricsReport {
                    report: expo::render_prometheus(&cx.obs, &m, Some(&counters.snapshot())),
                },
                Err(e) => Frame::Error(WireError::from_engine(0, &e)),
            }
        }
        Ok(Frame::Shutdown) => {
            counters.shutdown_requests.fetch_add(1, Ordering::Relaxed);
            // the owner of the NetServer decides what shutdown means
            // (typically: drain the front door, then the engine); the
            // executor keeps serving until told
            notify_shutdown = true;
            Frame::ShutdownOk
        }
        // reply frames arriving at the server are client bugs, not
        // transport corruption: answer typed, keep serving
        Ok(_) => {
            counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            cx.obs.event(EventKind::ProtoError, 0, -1, u64::from(raw.op));
            Frame::Error(WireError::from_engine(
                0,
                &EngineError::InvalidRequest("reply opcode sent to the server".into()),
            ))
        }
        Err(e) => {
            counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
            cx.obs.event(EventKind::ProtoError, 0, -1, u64::from(raw.op));
            invalid(0, &e)
        }
    };
    encode_reply(&reply, &mut reply_buf, counters);
    (Completion { conn: job.conn, reply: reply_buf, effects }, notify_shutdown)
}
