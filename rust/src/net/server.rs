//! The TCP front door: a multi-threaded server exposing the serving
//! cluster over `net::proto`.
//!
//! Thread layout:
//!
//! ```text
//!   acceptor ──► one reader thread per connection
//!                  │ owns: the socket's read half, the connection's
//!                  │ engine Sessions (push/close halves), a reusable
//!                  │ frame buffer
//!                  │
//!                  ├─► writer thread (socket write half): serializes
//!                  │   every reply through one mpsc queue into one
//!                  │   reusable encode buffer
//!                  │
//!                  └─► one forwarder thread per open stream: blocks on
//!                      the split TickReceiver, relays TickResults to
//!                      the writer as TICK frames
//! ```
//!
//! Error discipline: engine failures reply typed [`WireError`] frames
//! (backpressure, saturation, shutdown all reach the client as the
//! same [`EngineError`] variant an in-process caller would see); a
//! PUSH to a stream this connection doesn't own answers `Hibernated`
//! when the engine holds it in the state store (reattach with an OPEN
//! carrying the resume id) and `StreamClosed` when it is truly gone;
//! malformed-but-framed requests reply `InvalidRequest` and the
//! connection keeps serving (the length prefix kept the byte stream
//! aligned); an undecodable length prefix tears the connection down —
//! resynchronization is impossible. Nothing the client sends can panic
//! the server.
//!
//! Allocation posture: frame decode and encode run in per-thread
//! reusable buffers (the codec's zero-alloc contract, pinned in
//! `tests/zero_alloc.rs`); the remaining steady-state allocations per
//! push are engine-API costs — the owned `Vec<f32>` a `Session::push`
//! consumes and the mpsc node per reply message — not codec work.
//!
//! Shutdown discipline ([`NetServer::shutdown`]): stop accepting, then
//! sever every connection's read half — each reader wakes, announces a
//! terminal `ShuttingDown` error for every stream still open on its
//! connection (flushed by its writer before the socket closes), closes
//! its sessions, and joins its helper threads. Clients mid-stream get
//! a typed terminal error followed by EOF, never a hang.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use crate::coordinator::cluster::EngineHandle;
use crate::coordinator::session::{EngineError, Session, TickReceiver};
use crate::coordinator::shard::TickResult;
use crate::fault::{FaultInjector, FaultSite};
use crate::net::proto::{self, Frame, RawFrame, WireError};
use crate::obs::expo;
use crate::obs::journal::EventKind;
use crate::obs::span::{Stage, StageSpans};
use crate::obs::{ObsHandle, ObsLevel};

/// Shared atomic counters (per-connection accounting rolls up here),
/// plus the net layer's boot clocks and its decode/encode stage spans.
struct Counters {
    connections_accepted: AtomicU64,
    connections_active: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    protocol_errors: AtomicU64,
    streams_opened: AtomicU64,
    shutdown_requests: AtomicU64,
    idle_conns_reaped: AtomicU64,
    boot: Instant,
    boot_unix_ms: u64,
    level: ObsLevel,
    spans: Mutex<StageSpans>,
}

/// A point-in-time snapshot of the net layer's counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetMetrics {
    /// Connections accepted since start.
    pub connections_accepted: u64,
    /// Connections currently serving.
    pub connections_active: u64,
    /// Frames successfully read off sockets.
    pub frames_in: u64,
    /// Frames written to sockets.
    pub frames_out: u64,
    /// Malformed frames answered with `InvalidRequest`.
    pub protocol_errors: u64,
    /// Streams opened over the wire.
    pub streams_opened: u64,
    /// SHUTDOWN frames honored.
    pub shutdown_requests: u64,
    /// Idle connections with no open streams reaped by the read-timeout
    /// sweep (slow-loris defense; a connection holding streams is never
    /// reaped).
    pub idle_conns_reaped: u64,
    /// Time since the net front door started.
    pub uptime: Duration,
    /// Wall-clock start of the net front door, ms since the Unix epoch.
    pub boot_unix_ms: u64,
    /// Net-layer stage spans (frame decode / encode), recorded at
    /// `obs >= spans`; empty otherwise.
    pub spans: StageSpans,
}

impl NetMetrics {
    /// One-line operator summary.
    pub fn report(&self) -> String {
        format!(
            "net: conns={}/{} frames={}in/{}out proto_errors={} streams={} shutdown_reqs={} \
             idle_reaped={}",
            self.connections_active,
            self.connections_accepted,
            self.frames_in,
            self.frames_out,
            self.protocol_errors,
            self.streams_opened,
            self.shutdown_requests,
            self.idle_conns_reaped,
        )
    }
}

impl Counters {
    fn new(level: ObsLevel) -> Self {
        let boot_unix_ms = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        Self {
            connections_accepted: AtomicU64::new(0),
            connections_active: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
            streams_opened: AtomicU64::new(0),
            shutdown_requests: AtomicU64::new(0),
            idle_conns_reaped: AtomicU64::new(0),
            boot: Instant::now(),
            boot_unix_ms,
            level,
            spans: Mutex::new(StageSpans::new()),
        }
    }

    fn spans_on(&self) -> bool {
        self.level >= ObsLevel::Spans
    }

    fn record_span(&self, stage: Stage, d: Duration) {
        self.spans.lock().unwrap_or_else(|p| p.into_inner()).record(stage, d);
    }

    fn snapshot(&self) -> NetMetrics {
        NetMetrics {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_active: self.connections_active.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            streams_opened: self.streams_opened.load(Ordering::Relaxed),
            shutdown_requests: self.shutdown_requests.load(Ordering::Relaxed),
            idle_conns_reaped: self.idle_conns_reaped.load(Ordering::Relaxed),
            uptime: self.boot.elapsed(),
            boot_unix_ms: self.boot_unix_ms,
            spans: self.spans.lock().unwrap_or_else(|p| p.into_inner()).clone(),
        }
    }
}

/// Cloneable snapshot handle to the net layer's counters, detached
/// from the [`NetServer`]'s lifetime — the exposition endpoint's
/// render closure holds one without borrowing the server.
#[derive(Clone)]
pub struct NetMetricsHandle {
    counters: Arc<Counters>,
}

impl NetMetricsHandle {
    /// Snapshot of the net layer's counters.
    pub fn snapshot(&self) -> NetMetrics {
        self.counters.snapshot()
    }
}

/// What travels to a connection's writer thread. Tick results ride as
/// their engine form and are serialized in the writer's one reusable
/// buffer (no intermediate encode per message).
enum Reply {
    Frame(Frame),
    Tick { stream: u64, result: TickResult },
}

struct StreamEntry {
    sess: Session,
    /// Set before a deliberate close so the forwarder exits silently
    /// instead of reporting the disconnect as an error.
    closed: Arc<AtomicBool>,
    forwarder: JoinHandle<()>,
}

/// Live connections: the accepted socket (kept for severing its read
/// half at shutdown) and the reader thread's join handle.
type ConnRegistry = Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>>;

/// The running TCP front door. Start with [`NetServer::start`]; stop
/// with [`NetServer::shutdown`] (graceful drain).
pub struct NetServer {
    addr: SocketAddr,
    shutting_down: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: ConnRegistry,
    counters: Arc<Counters>,
    shutdown_req_rx: Receiver<()>,
}

/// How long a connection may sit with zero open streams and zero
/// inbound bytes before the server reaps it (slow-loris defense).
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting connections against the given engine front door.
    /// Connections idle past [`DEFAULT_IDLE_TIMEOUT`] with no open
    /// streams are reaped; use [`NetServer::start_with_idle_timeout`]
    /// to tune that window.
    pub fn start<A: ToSocketAddrs>(addr: A, engine: EngineHandle) -> io::Result<NetServer> {
        Self::start_with_idle_timeout(addr, engine, DEFAULT_IDLE_TIMEOUT)
    }

    /// [`NetServer::start`] with an explicit idle-connection timeout. A
    /// connection that has sent no bytes for `idle_timeout` AND holds
    /// no open streams is closed and counted in
    /// [`NetMetrics::idle_conns_reaped`] — a half-open or deliberately
    /// slow client cannot pin a reader thread + fd forever. A
    /// connection with open streams is never reaped, however quiet
    /// (streaming clients legitimately sit idle between pushes).
    pub fn start_with_idle_timeout<A: ToSocketAddrs>(
        addr: A,
        engine: EngineHandle,
        idle_timeout: Duration,
    ) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let shutting_down = Arc::new(AtomicBool::new(false));
        let conns: ConnRegistry = Arc::default();
        let counters = Arc::new(Counters::new(engine.obs().level()));
        let (shutdown_req_tx, shutdown_req_rx) = mpsc::channel();
        let acceptor = {
            let shutting_down = Arc::clone(&shutting_down);
            let conns = Arc::clone(&conns);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new().name("deepcot-net-acceptor".into()).spawn(move || {
                loop {
                    let sock = match listener.accept() {
                        Ok((sock, _peer)) => sock,
                        Err(_) if shutting_down.load(Ordering::SeqCst) => return,
                        Err(_) => {
                            // persistent accept failures (e.g. EMFILE)
                            // must not busy-spin a core
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    if shutting_down.load(Ordering::SeqCst) {
                        // the wake-up connection (or a late client):
                        // drop it and stop accepting
                        return;
                    }
                    counters.connections_accepted.fetch_add(1, Ordering::Relaxed);
                    counters.connections_active.fetch_add(1, Ordering::Relaxed);
                    let _ = sock.set_nodelay(true);
                    let reader_sock = match sock.try_clone() {
                        Ok(s) => s,
                        Err(_) => {
                            counters.connections_active.fetch_sub(1, Ordering::Relaxed);
                            continue;
                        }
                    };
                    let engine = engine.clone();
                    let shutting_down2 = Arc::clone(&shutting_down);
                    let counters2 = Arc::clone(&counters);
                    let shutdown_req = shutdown_req_tx.clone();
                    let spawned =
                        std::thread::Builder::new().name("deepcot-net-conn".into()).spawn(
                            move || {
                                conn_main(
                                    reader_sock,
                                    engine,
                                    shutting_down2,
                                    Arc::clone(&counters2),
                                    shutdown_req,
                                    idle_timeout,
                                );
                                counters2.connections_active.fetch_sub(1, Ordering::Relaxed);
                            },
                        );
                    match spawned {
                        Ok(handle) => {
                            let mut reg = conns.lock().unwrap_or_else(|p| p.into_inner());
                            // prune finished connections so a long-lived
                            // server doesn't accumulate one fd + handle
                            // per connection it ever served (the dropped
                            // socket clone releases the kernel socket)
                            reg.retain(|(_, h)| !h.is_finished());
                            reg.push((sock, handle));
                        }
                        Err(_) => {
                            counters.connections_active.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                }
            })?
        };
        Ok(NetServer {
            addr,
            shutting_down,
            acceptor: Some(acceptor),
            conns,
            counters,
            shutdown_req_rx,
        })
    }

    /// The address the server actually listens on (resolves `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the net layer's counters.
    pub fn metrics(&self) -> NetMetrics {
        self.counters.snapshot()
    }

    /// A counters handle that outlives this server value (for the
    /// metrics endpoint's render closure).
    pub fn metrics_handle(&self) -> NetMetricsHandle {
        NetMetricsHandle { counters: Arc::clone(&self.counters) }
    }

    /// Block until some client sends a SHUTDOWN frame, or `timeout`
    /// passes (`true` = shutdown was requested). The server keeps
    /// serving either way — pair with [`NetServer::shutdown`]. A
    /// defunct acceptor (every request source gone) also reports
    /// `true`: there is nothing left to wait for but the drain.
    pub fn wait_shutdown_requested(&self, timeout: Duration) -> bool {
        match self.shutdown_req_rx.recv_timeout(timeout) {
            Ok(()) => true,
            Err(RecvTimeoutError::Disconnected) => true,
            Err(RecvTimeoutError::Timeout) => false,
        }
    }

    /// Graceful drain: stop accepting, sever every connection's read
    /// half (each reader announces terminal `ShuttingDown` errors for
    /// its live streams and closes its sessions), and join every
    /// thread. Engine shutdown is the caller's (the engine may outlive
    /// the front door).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the acceptor out of accept(); it sees the flag and exits
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let conns = {
            let mut reg = self.conns.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *reg)
        };
        for (sock, _) in &conns {
            // readers wake with EOF/error and run their drain path;
            // their writers still own a live write half for the
            // terminal error frames
            let _ = sock.shutdown(Shutdown::Read);
        }
        for (_, handle) in conns {
            let _ = handle.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One connection's reader loop: decode → dispatch → reply. Owns the
/// connection's sessions; spawns its writer and per-stream forwarders.
fn conn_main(
    sock: TcpStream,
    engine: EngineHandle,
    shutting_down: Arc<AtomicBool>,
    counters: Arc<Counters>,
    shutdown_req: Sender<()>,
    idle_timeout: Duration,
) {
    let Ok(write_sock) = sock.try_clone() else { return };
    let inj = engine.fault();
    let (wtx, wrx) = mpsc::channel::<Reply>();
    let writer = {
        let counters = Arc::clone(&counters);
        let inj = inj.clone();
        std::thread::Builder::new()
            .name("deepcot-net-writer".into())
            .spawn(move || writer_main(write_sock, wrx, counters, inj))
    };
    let Ok(writer) = writer else { return };

    let mut sock = sock;
    // a bounded read timeout turns the blocking reader into a periodic
    // idle sweep: read_frame returns the timeout untouched at a frame
    // boundary (retryable), so each tick we can check idleness and the
    // shutdown flag without ever tearing a frame
    let tick = idle_timeout.min(Duration::from_secs(5)).max(Duration::from_millis(10));
    let _ = sock.set_read_timeout(Some(tick));
    let mut last_activity = Instant::now();
    let mut streams: BTreeMap<u64, StreamEntry> = BTreeMap::new();
    let mut frame_buf: Vec<u8> = Vec::with_capacity(4096);
    let obs = engine.obs().clone();
    let spans_on = counters.spans_on();
    loop {
        match proto::read_frame(&mut sock, &mut frame_buf) {
            Ok(true) => last_activity = Instant::now(),
            // clean client EOF: the connection is over
            Ok(false) => break,
            // boundary timeout: no frame bytes consumed — an idle tick,
            // not an error. Reap only truly abandoned connections:
            // quiet past the deadline AND holding no streams (a
            // streaming client legitimately idles between pushes).
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if shutting_down.load(Ordering::SeqCst) {
                    break;
                }
                let idle = last_activity.elapsed();
                if streams.is_empty() && idle >= idle_timeout {
                    counters.idle_conns_reaped.fetch_add(1, Ordering::Relaxed);
                    obs.event(EventKind::ConnReaped, 0, -1, idle.as_millis() as u64);
                    break;
                }
                continue;
            }
            // torn frame, severed socket, or an undecodable length
            // prefix: the connection is over (a bad prefix cannot be
            // resynchronized; a mid-frame timeout arrives here as
            // UnexpectedEof — the stream is desynchronized)
            Err(_) => break,
        }
        if inj.fire(FaultSite::NetRead) {
            // injected transport fault: behave exactly like a socket
            // read error — tear the connection down through the normal
            // drain path (clients must recover via reconnect + resume)
            break;
        }
        counters.frames_in.fetch_add(1, Ordering::Relaxed);
        let t_decode = Instant::now();
        let raw = match RawFrame::parse(&frame_buf) {
            Ok(raw) => raw,
            Err(e) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                obs.event(EventKind::ProtoError, 0, -1, 0);
                let _ = wtx.send(invalid(0, &e));
                continue;
            }
        };
        // PUSH dominates steady state: decode it zero-copy off the
        // reused frame buffer before falling back to the owned decoder
        let mut tokens = Vec::new();
        if let Ok(stream) = raw.push_fields_into(&mut tokens) {
            if spans_on {
                counters.record_span(Stage::NetDecode, t_decode.elapsed());
            }
            let reply = match streams.get(&stream) {
                None => {
                    let id = crate::coordinator::slots::StreamId(stream);
                    // "hibernated" and "gone" must stay distinguishable:
                    // a hibernated stream is reattachable via OPEN with
                    // a resume id, a closed one is not
                    let e = if engine.is_hibernated(id) {
                        EngineError::Hibernated(id)
                    } else {
                        EngineError::StreamClosed(id)
                    };
                    Frame::Error(WireError::from_engine(stream, &e))
                }
                Some(entry) => match entry.sess.push(tokens) {
                    Ok(()) => Frame::PushOk { stream },
                    Err(e) => Frame::Error(WireError::from_engine(stream, &e)),
                },
            };
            let _ = wtx.send(Reply::Frame(reply));
            continue;
        }
        let decoded = raw.to_frame();
        if spans_on {
            counters.record_span(Stage::NetDecode, t_decode.elapsed());
        }
        match decoded {
            Ok(Frame::Open { resume }) => {
                // fresh open, or reattach to a stream recovered from
                // the state store (same id, ticks continue where the
                // previous run left off)
                let opened = match resume {
                    None => engine.open(),
                    Some(id) => engine.resume(crate::coordinator::slots::StreamId(id)),
                };
                let reply = match opened {
                    Ok(mut sess) => {
                        let stream = sess.id().0;
                        // the receiving half lives on its own forwarder
                        // thread; the session half stays here for
                        // push/close
                        let rx = sess.split_receiver().expect("fresh session has its receiver");
                        let closed = Arc::new(AtomicBool::new(false));
                        let forwarder = spawn_forwarder(
                            rx,
                            stream,
                            wtx.clone(),
                            Arc::clone(&closed),
                            Arc::clone(&shutting_down),
                        );
                        match forwarder {
                            Ok(forwarder) => {
                                counters.streams_opened.fetch_add(1, Ordering::Relaxed);
                                if let Some(old) = streams.remove(&stream) {
                                    // a resume only succeeds when the
                                    // stream lost its owner (shard crash
                                    // re-home), so this entry is a
                                    // zombie — defuse its RAII close or
                                    // it would tear down the stream we
                                    // just resumed
                                    old.closed.store(true, Ordering::SeqCst);
                                    old.sess.forget();
                                    let _ = old.forwarder.join();
                                }
                                streams.insert(stream, StreamEntry { sess, closed, forwarder });
                                Frame::Opened { stream }
                            }
                            Err(_) => Frame::Error(WireError::from_engine(
                                stream,
                                &EngineError::Internal("spawning stream forwarder".into()),
                            )),
                        }
                    }
                    Err(e) => Frame::Error(WireError::from_engine(resume.unwrap_or(0), &e)),
                };
                let _ = wtx.send(Reply::Frame(reply));
            }
            Ok(Frame::Close { stream }) => {
                let reply = match streams.remove(&stream) {
                    Some(entry) => {
                        entry.closed.store(true, Ordering::SeqCst);
                        entry.sess.close();
                        let _ = entry.forwarder.join();
                        Frame::Closed { stream }
                    }
                    None => Frame::Error(WireError::from_engine(
                        stream,
                        &EngineError::StreamClosed(crate::coordinator::slots::StreamId(stream)),
                    )),
                };
                let _ = wtx.send(Reply::Frame(reply));
            }
            Ok(Frame::Metrics) => {
                let reply = match engine.metrics() {
                    Ok(m) => Frame::MetricsReport {
                        report: format!("{}\n  {}", m.report(), counters.snapshot().report()),
                    },
                    Err(e) => Frame::Error(WireError::from_engine(0, &e)),
                };
                let _ = wtx.send(Reply::Frame(reply));
            }
            Ok(Frame::MetricsProm) => {
                // the same document the HTTP /metrics endpoint serves,
                // carried in a MetricsReport frame
                let reply = match engine.metrics() {
                    Ok(m) => Frame::MetricsReport {
                        report: expo::render_prometheus(&obs, &m, Some(&counters.snapshot())),
                    },
                    Err(e) => Frame::Error(WireError::from_engine(0, &e)),
                };
                let _ = wtx.send(Reply::Frame(reply));
            }
            Ok(Frame::Shutdown) => {
                counters.shutdown_requests.fetch_add(1, Ordering::Relaxed);
                let _ = wtx.send(Reply::Frame(Frame::ShutdownOk));
                // the owner of the NetServer decides what shutdown
                // means (typically: drain the front door, then the
                // engine); the reader keeps serving until severed
                let _ = shutdown_req.send(());
            }
            // reply frames arriving at the server are client bugs, not
            // transport corruption: answer typed, keep serving
            Ok(_) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                obs.event(EventKind::ProtoError, 0, -1, u64::from(raw.op));
                let _ = wtx.send(Reply::Frame(Frame::Error(WireError::from_engine(
                    0,
                    &EngineError::InvalidRequest("reply opcode sent to the server".into()),
                ))));
            }
            Err(e) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                obs.event(EventKind::ProtoError, 0, -1, u64::from(raw.op));
                let _ = wtx.send(invalid(0, &e));
            }
        }
    }

    // teardown: on server shutdown every still-open stream gets a
    // terminal typed error (flushed before the writer closes); on a
    // plain client disconnect the sessions just close (RAII) silently
    let announce = shutting_down.load(Ordering::SeqCst);
    for (stream, entry) in streams {
        entry.closed.store(true, Ordering::SeqCst);
        if announce {
            let _ = wtx.send(Reply::Frame(Frame::Error(WireError::from_engine(
                stream,
                &EngineError::ShuttingDown,
            ))));
        }
        entry.sess.close();
        let _ = entry.forwarder.join();
    }
    drop(wtx);
    let _ = writer.join();
}

fn invalid(stream: u64, e: &proto::ProtoError) -> Reply {
    Reply::Frame(Frame::Error(WireError::from_engine(
        stream,
        &EngineError::InvalidRequest(e.to_string()),
    )))
}

/// Relay a stream's tick results to the connection's writer until the
/// stream tears down; an unexpected teardown (eviction, engine or
/// server shutdown) is announced with a terminal typed error.
fn spawn_forwarder(
    rx: TickReceiver,
    stream: u64,
    wtx: Sender<Reply>,
    closed: Arc<AtomicBool>,
    shutting_down: Arc<AtomicBool>,
) -> io::Result<JoinHandle<()>> {
    std::thread::Builder::new().name("deepcot-net-stream".into()).spawn(move || loop {
        match rx.recv() {
            Ok(result) => {
                if wtx.send(Reply::Tick { stream, result }).is_err() {
                    return; // connection gone
                }
            }
            Err(e) => {
                if !closed.load(Ordering::SeqCst) {
                    let e = if shutting_down.load(Ordering::SeqCst) {
                        EngineError::ShuttingDown
                    } else {
                        e
                    };
                    let _ =
                        wtx.send(Reply::Frame(Frame::Error(WireError::from_engine(stream, &e))));
                }
                return;
            }
        }
    })
}

/// Drain the reply queue into the socket through one reusable encode
/// buffer. Exits when every sender is gone or the socket dies.
fn writer_main(
    mut sock: TcpStream,
    wrx: Receiver<Reply>,
    counters: Arc<Counters>,
    inj: FaultInjector,
) {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let spans_on = counters.spans_on();
    while let Ok(reply) = wrx.recv() {
        let t_encode = Instant::now();
        match reply {
            Reply::Frame(f) => f.encode_into(&mut buf),
            Reply::Tick { stream, result } => {
                proto::write_tick(&mut buf, stream, result.tick, &result.logits, &result.out)
            }
        }
        if spans_on {
            counters.record_span(Stage::NetEncode, t_encode.elapsed());
        }
        if inj.fire(FaultSite::NetWrite) {
            // injected partial write: flush half a frame then die, the
            // worst desync a crashing peer can leave on the wire — the
            // client's length prefix discipline must reject the tail
            let half = buf.len() / 2;
            let _ = sock.write_all(&buf[..half]);
            while wrx.recv().is_ok() {}
            break;
        }
        if sock.write_all(&buf).is_err() {
            // socket dead: drain (dropping replies) so senders never
            // observe the channel as live-but-stuck
            while wrx.recv().is_ok() {}
            break;
        }
        counters.frames_out.fetch_add(1, Ordering::Relaxed);
    }
    let _ = sock.flush();
    let _ = sock.shutdown(Shutdown::Write);
}
