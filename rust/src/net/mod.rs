//! The network serving layer: a dependency-free (std-only) TCP front
//! door that turns the in-process shard cluster into a servable
//! system — streams arrive from *outside* the process, which is the
//! deployment shape the paper's real-time-inference pitch implies.
//!
//! ```text
//!   remote clients ──► net::client::NetClient (pipelined: many
//!        │             in-flight requests, bounded demux inbox; also:
//!        │             any implementation of net::proto over TCP)
//!        │  OPEN(+token) / PUSH / CLOSE / METRICS / SHUTDOWN
//!        │  ◄─ OPENED / PUSH_OK / TICK / typed ERROR frames
//!        ▼
//!   net::server::NetServer
//!        │  ┌─ "deepcot-net-poll" readiness loop (net::poller —
//!        │  │   std-only epoll/poll shim): accepts, nonblocking
//!        │  │   reads/writes, per-connection write queues, tick
//!        │  │   multiplexing via Session::split_receiver, idle reaping
//!        │  └─ "deepcot-net-worker-0..N" fixed pool (size from
//!        │      EngineConfig): decodes frames, drives the engine,
//!        │      one job in flight per connection (strict FIFO)
//!        ▼
//!   EngineHandle (cluster front door)
//!        │  ShardRouter: placement, migration, rebalance
//!   ┌────┼──────────┐
//!   ▼    ▼          ▼
//! shard 0 … shard N-1   Router + Batcher + StreamBackend per worker
//! ```
//!
//! Layering: [`proto`] is the pure codec (length-prefixed binary
//! frames, typed error mapping, zero-alloc hot-path readers/writers —
//! byte-identical since PR 5, the executor rewrite changed nothing on
//! the wire); [`poller`] is the readiness shim; [`server`] owns the
//! poll thread, the worker pool, and the engine sessions; [`client`]
//! is the pipelined reference client. Thread count is O(workers), not
//! O(connections): admission control (connection limits, per-connection
//! stream quotas, optional shared-secret OPEN auth) is the server's,
//! not the OS scheduler's. The engine is untouched — the server is
//! just another `EngineHandle` user, so everything the cluster pins
//! (bitwise layout-independence, migration transparency,
//! drain-on-shutdown) holds identically for TCP streams, which
//! `tests/net.rs` pins end-to-end over loopback.
//!
//! Error semantics over the wire mirror the in-process `Session` API:
//! a push that would return [`EngineError::Backpressure`] in-process
//! returns the same variant through [`client::NetClient::push`];
//! saturation, shutdown, and malformed requests all arrive as typed
//! [`proto::WireError`] frames instead of dropped connections.
//!
//! [`EngineError::Backpressure`]: crate::coordinator::session::EngineError::Backpressure

pub mod client;
pub mod poller;
pub mod proto;
pub mod server;
