//! The network serving layer: a dependency-free (std-only) TCP front
//! door that turns the in-process shard cluster into a servable
//! system — streams arrive from *outside* the process, which is the
//! deployment shape the paper's real-time-inference pitch implies.
//!
//! ```text
//!   remote clients ──► net::client::NetClient (blocking; also: any
//!        │             implementation of net::proto over TCP)
//!        │  OPEN / PUSH / CLOSE / METRICS / SHUTDOWN
//!        │  ◄─ OPENED / PUSH_OK / TICK / typed ERROR frames
//!        ▼
//!   net::server::NetServer (acceptor + per-connection reader/writer
//!        │                  threads + per-stream tick forwarders;
//!        │                  owns one engine Session per client stream)
//!        ▼
//!   EngineHandle (cluster front door)
//!        │  ShardRouter: placement, migration, rebalance
//!   ┌────┼──────────┐
//!   ▼    ▼          ▼
//! shard 0 … shard N-1   Router + Batcher + StreamBackend per worker
//! ```
//!
//! Layering: [`proto`] is the pure codec (length-prefixed binary
//! frames, typed error mapping, zero-alloc hot-path readers/writers);
//! [`server`] owns the threads and the engine sessions; [`client`] is
//! the blocking reference client. The engine is untouched — the server
//! is just another `EngineHandle` user, so everything the cluster
//! pins (bitwise layout-independence, migration transparency,
//! drain-on-shutdown) holds identically for TCP streams, which
//! `tests/net.rs` pins end-to-end over loopback.
//!
//! Error semantics over the wire mirror the in-process `Session` API:
//! a push that would return [`EngineError::Backpressure`] in-process
//! returns the same variant through [`client::NetClient::push`];
//! saturation, shutdown, and malformed requests all arrive as typed
//! [`proto::WireError`] frames instead of dropped connections.
//!
//! [`EngineError::Backpressure`]: crate::coordinator::session::EngineError::Backpressure

pub mod client;
pub mod proto;
pub mod server;
