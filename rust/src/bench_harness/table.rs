//! Paper-style table rendering (stdout + markdown file under
//! `bench_out/`), with a paper-reference column so EXPERIMENTS.md can
//! record measured-vs-paper side by side.

use std::path::Path;

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, cell) in r.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        w
    }

    /// Fixed-width text rendering for stdout.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut s = format!("== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut l = String::new();
            for (i, c) in cells.iter().enumerate() {
                l.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            l.trim_end().to_string() + "\n"
        };
        s.push_str(&line(&self.columns, &w));
        s.push_str(&format!("{}\n", "-".repeat(w.iter().sum::<usize>() + 2 * w.len())));
        for r in &self.rows {
            s.push_str(&line(r, &w));
        }
        s
    }

    /// GitHub-markdown rendering for bench_out/ + EXPERIMENTS.md.
    pub fn render_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.columns.join(" | ")));
        s.push_str(&format!("|{}|\n", self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    /// Print to stdout and append to `bench_out/<file>.md`.
    pub fn emit(&self, out_dir: &Path, file: &str) -> Result<()> {
        print!("{}", self.render());
        std::fs::create_dir_all(out_dir)?;
        let path = out_dir.join(format!("{file}.md"));
        let mut existing = std::fs::read_to_string(&path).unwrap_or_default();
        existing.push_str(&self.render_markdown());
        existing.push('\n');
        std::fs::write(&path, existing)?;
        Ok(())
    }
}

/// Format a speedup column like the paper ("x23.65").
pub fn speedup(base_s: f64, this_s: f64) -> String {
    format!("x{:.2}", base_s / this_s)
}

/// Format seconds as an adaptive human unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "x"]);
        t.row(vec!["deepcot".into(), "1".into()]);
        t.row(vec!["enc".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("deepcot"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(speedup(10.0, 1.0), "x10.00");
        assert!(fmt_secs(5e-7).contains("µs"));
        assert!(fmt_secs(5e-2).contains("ms"));
        assert!(fmt_secs(2.0).contains(" s"));
    }
}
