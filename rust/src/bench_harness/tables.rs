//! Regeneration of every table and figure in the paper's evaluation
//! (experiment index: DESIGN.md §5). Each `run_*` returns the rendered
//! [`Table`]s and appends markdown to `bench_out/`.
//!
//! Shared shape: build the synthetic workload at the paper's geometry,
//! run every model family with identical weights, fill the paper's
//! columns — quality metric (probe), analytic FLOPs, measured runtime.
//! Paper reference values ride along in a trailing column so measured
//! vs published shape can be compared at a glance.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use crate::baselines::{
    BatchedScalarModel, ChainedStepModel, ChainedWindowModel, ContinualModel, NaiveScalarModel,
    ScalarModel, StreamModel, WindowModel,
};
use crate::bench_harness::pipeline::{clip_probe_eval, frame_probe_eval, sed_probe_eval};
use crate::bench_harness::table::{fmt_secs, speedup, Table};
use crate::bench_harness::{adaptive_ticks, measure_ticks};
use crate::flops::{format_flops, per_tick, FlopsMode};
use crate::manifest::ModelConfig;
use crate::nn::params::ModelParams;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::workload::{audio, sed, text, video};

/// Global effort knobs for a table run.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub out_dir: PathBuf,
    pub seed: u64,
    /// corpus size multiplier (1.0 = defaults below)
    pub scale: f64,
    /// wall budget per runtime measurement
    pub time_budget: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            out_dir: PathBuf::from("bench_out"),
            seed: 0,
            scale: 1.0,
            time_budget: Duration::from_secs(3),
        }
    }
}

impl BenchOpts {
    pub fn quick() -> Self {
        Self { scale: 0.35, time_budget: Duration::from_millis(600), ..Default::default() }
    }

    fn n(&self, base: usize) -> usize {
        ((base as f64 * self.scale) as usize).max(6)
    }
}

fn runtime_of(model: &mut dyn StreamModel, opts: &BenchOpts, seed: u64) -> Result<f64> {
    let (probe, _) = measure_ticks(model, 1, 3, seed)?;
    let ticks = adaptive_ticks(
        Duration::from_secs_f64(probe.mean_s),
        opts.time_budget,
        8,
    );
    let (s, _) = measure_ticks(model, 2, ticks, seed)?;
    Ok(s.mean_s)
}

// ---------------------------------------------------------------------
// Table I — Online Action Detection (THUMOS14 stand-in)

pub fn run_table1(rt: &Runtime, opts: &BenchOpts) -> Result<Table> {
    let mut table = Table::new(
        "Table I — Online Action Detection (synthetic THUMOS14; paper cols in [])",
        &[
            "Model",
            "mAP A (%)",
            "mAP B (%)",
            "FLOPs",
            "Rel. runtime",
            "[paper mAP K400 / FLOPs / runtime]",
        ],
    );
    let models: Vec<(&str, Box<dyn Fn() -> Result<Box<dyn StreamModel>>>, &str, &str)> = vec![
        (
            "OAD Transformer",
            Box::new(|| Ok(Box::new(WindowModel::load(rt, "t1_encoder")?) as _)),
            "encoder",
            "64.66 / 16.92M / x1",
        ),
        (
            "Co. Transformer",
            Box::new(|| Ok(Box::new(ContinualModel::load(rt, "t1_cotransformer")?) as _)),
            "cotransformer",
            "63.93 / 0.65M / x10.55",
        ),
        (
            "Nystromformer",
            Box::new(|| Ok(Box::new(WindowModel::load(rt, "t1_nystrom")?) as _)),
            "nystrom",
            "59.32 / 9.42M / x1.06",
        ),
        (
            "DeepCoT (ours)",
            Box::new(|| Ok(Box::new(ContinualModel::load(rt, "t1_deepcot")?) as _)),
            "deepcot",
            "63.68 / 0.40M / x23.65",
        ),
    ];
    // two corpora = the paper's two feature extractors (K400 / ANet)
    let mk_corpus = |seed: u64, d_in: usize, classes: usize, opts: &BenchOpts| {
        video::generate(&mut Rng::new(seed), opts.n(36), 160, d_in, classes)
    };
    let mut base_rt: Option<f64> = None;
    for (label, load, family, paper) in models {
        let mut m = load()?;
        let cfg = m.config().clone();
        let ca = mk_corpus(opts.seed + 11, cfg.d_in, cfg.n_classes - 1, opts);
        let cb = mk_corpus(opts.seed + 23, cfg.d_in, cfg.n_classes - 1, opts);
        let ea = frame_probe_eval(m.as_mut(), &ca, 0.7, 1e-1)?;
        let eb = frame_probe_eval(m.as_mut(), &cb, 0.7, 1e-1)?;
        let secs = runtime_of(m.as_mut(), opts, opts.seed)?;
        let base = *base_rt.get_or_insert(secs);
        table.row(vec![
            label.to_string(),
            format!("{:.2}", 100.0 * ea.frame_map),
            format!("{:.2}", 100.0 * eb.frame_map),
            format_flops(per_tick(family, &cfg, FlopsMode::AttentionOnly)),
            speedup(base, secs),
            paper.to_string(),
        ]);
    }
    table.emit(&opts.out_dir, "table1")?;
    Ok(table)
}

// ---------------------------------------------------------------------
// Table II — Audio classification (GTZAN stand-in)

pub fn run_table2(rt: &Runtime, opts: &BenchOpts) -> Result<Table> {
    let mut table = Table::new(
        "Table II — Audio classification (synthetic GTZAN; paper cols in [])",
        &["Model", "Accuracy (%)", "FLOPs", "Rel. runtime", "[paper acc / FLOPs / runtime]"],
    );
    let models: Vec<(&str, Box<dyn Fn() -> Result<Box<dyn StreamModel>>>, &str, &str)> = vec![
        (
            "Transformer",
            Box::new(|| Ok(Box::new(WindowModel::load(rt, "t2_encoder")?) as _)),
            "encoder",
            "94.19 / 11134.3K / x1",
        ),
        (
            "Co. Transformer",
            Box::new(|| Ok(Box::new(ContinualModel::load(rt, "t2_cotransformer")?) as _)),
            "cotransformer",
            "94.28 / 230.7K / x1.02",
        ),
        (
            "Nystromformer",
            Box::new(|| Ok(Box::new(WindowModel::load(rt, "t2_nystrom")?) as _)),
            "nystrom",
            "94.66 / 845.4K / x0.56",
        ),
        (
            "DeepCoT (ours)",
            Box::new(|| Ok(Box::new(ContinualModel::load(rt, "t2_deepcot")?) as _)),
            "deepcot",
            "94.19 / 138.7K / x37.24",
        ),
    ];
    let mut base_rt: Option<f64> = None;
    for (label, load, family, paper) in models {
        let mut m = load()?;
        let cfg = m.config().clone();
        let corpus = audio::generate(
            &mut Rng::new(opts.seed + 5),
            opts.n(60),
            cfg.window,
            cfg.d_in,
            cfg.n_classes,
        );
        let e = clip_probe_eval(m.as_mut(), &corpus, 0.7, 1e-1)?;
        let secs = runtime_of(m.as_mut(), opts, opts.seed)?;
        let base = *base_rt.get_or_insert(secs);
        table.row(vec![
            label.to_string(),
            format!("{:.2}", 100.0 * e.accuracy),
            format_flops(per_tick(family, &cfg, FlopsMode::AttentionOnly)),
            speedup(base, secs),
            paper.to_string(),
        ]);
    }
    table.emit(&opts.out_dir, "table2")?;
    Ok(table)
}

// ---------------------------------------------------------------------
// Table III — Sound Event Detection (MAT-SED pipeline, URBAN-SED stand-in)

pub fn run_table3(rt: &Runtime, opts: &BenchOpts) -> Result<Table> {
    let mut table = Table::new(
        "Table III — SED, MAT-SED architecture (synthetic URBAN-SED; paper cols in [])",
        &["Model", "SbF1", "AtF1", "FLOPs", "Throughput (tps)", "[paper SbF1/AtF1/FLOPs/tps]"],
    );
    let mut rows: Vec<(&str, Box<dyn StreamModel>, u64, &str)> = vec![];
    {
        let m = ChainedWindowModel::load(rt, "t3_encoder_enc", "t3_encoder_ctx")?;
        let enc_cfg = rt.manifest().variant("t3_encoder_enc")?.config.clone();
        let ctx_cfg = rt.manifest().variant("t3_encoder_ctx")?.config.clone();
        let flops = per_tick("encoder", &enc_cfg, FlopsMode::FullModel)
            + per_tick("xl_full", &ctx_cfg, FlopsMode::FullModel);
        rows.push((
            "MAT-SED",
            Box::new(m),
            flops,
            "0.583 / 0.706 / 41G / 0.532",
        ));
    }
    {
        let m = ChainedStepModel::load(rt, "t3_deepcot_enc", "t3_deepcot_ctx")?;
        let enc_cfg = rt.manifest().variant("t3_deepcot_enc")?.config.clone();
        let ctx_cfg = rt.manifest().variant("t3_deepcot_ctx")?.config.clone();
        let flops = per_tick("deepcot", &enc_cfg, FlopsMode::FullModel)
            + per_tick("xl", &ctx_cfg, FlopsMode::FullModel);
        rows.push((
            "DeepCoT MAT-SED (ours)",
            Box::new(m),
            flops,
            "0.406 / 0.670 / 0.284G / 8.004",
        ));
    }
    for (label, mut m, flops, paper) in rows {
        let cfg = m.config().clone();
        // SED probes need enough eval clips to calibrate thresholds —
        // floor the corpus at 16 clips even in quick mode
        let corpus = sed::generate(
            &mut Rng::new(opts.seed + 31),
            opts.n(32).max(16),
            cfg.m_tokens * 40,
            cfg.d_in,
            cfg.n_classes,
        );
        let e = sed_probe_eval(m.as_mut(), &corpus, 0.7, 100.0, 4)?;
        let (probe, _) = measure_ticks(m.as_mut(), 1, 3, opts.seed)?;
        let ticks =
            adaptive_ticks(Duration::from_secs_f64(probe.mean_s), opts.time_budget, 6);
        let (s, tps) = measure_ticks(m.as_mut(), 1, ticks, opts.seed)?;
        let _ = s;
        table.row(vec![
            label.to_string(),
            format!("{:.3}", e.segment_f1),
            format!("{:.3}", e.tagging_f1),
            format_flops(flops),
            format!("{:.2}", tps),
            paper.to_string(),
        ]);
    }
    table.emit(&opts.out_dir, "table3")?;
    Ok(table)
}

// ---------------------------------------------------------------------
// Table IV — GLUE-style text grid (7 tasks x 3 window scales)

pub const T4_TASKS: &[(&str, [usize; 3])] = &[
    ("CoLA", [6, 12, 24]),
    ("SST-2", [12, 24, 48]),
    ("MRPC", [26, 52, 104]),
    ("STS-B", [15, 30, 60]),
    ("QQP", [15, 30, 60]),
    ("MNLI", [19, 38, 76]),
    ("QNLI", [25, 50, 100]),
];

pub const T4_MODELS: &[(&str, &str, bool)] = &[
    // (display, variant prefix, is_window_model)
    ("DeepCoT Roformer", "t4_deepcot_n", false),
    ("Roformer", "t4_encoder_n", true),
    ("FNet", "t4_fnet_n", true),
    ("DeepCoT SOFT", "t4_deepcot_soft_n", false),
    ("SOFT Roformer", "t4_encoder_soft_n", true),
];

pub fn run_table4(
    rt: &Runtime,
    opts: &BenchOpts,
    scales: &[usize],
    tasks: &[&str],
) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    for (si, scale_name) in ["x0.5", "x1", "x2"].iter().enumerate() {
        if !scales.contains(&si) {
            continue;
        }
        let mut table = Table::new(
            &format!("Table IV ({scale_name}) — synthetic GLUE: F1 / throughput (tps)"),
            &{
                let mut cols = vec!["Model"];
                cols.extend(T4_TASKS.iter().filter(|(t, _)| tasks.contains(t)).map(|(t, _)| *t));
                cols.push("Average F1");
                cols
            }
            .as_slice(),
        );
        for (display, prefix, is_window) in T4_MODELS {
            let mut cells = vec![display.to_string()];
            let mut f1s = Vec::new();
            for (task, windows) in T4_TASKS {
                if !tasks.contains(task) {
                    continue;
                }
                let w = windows[si];
                let variant = format!("{prefix}{w}");
                let mut model: Box<dyn StreamModel> = if *is_window {
                    Box::new(WindowModel::load(rt, &variant)?)
                } else {
                    Box::new(ContinualModel::load(rt, &variant)?)
                };
                let cfg = model.config().clone();
                // sample length ~ twice the x1 window so x0.5 windows
                // miss part of the evidence (the paper's regime)
                let len = (2 * windows[1]).max(w + 8);
                let mut rng = Rng::new(opts.seed + 7 * si as u64 + hash(task));
                let task_def = text::make_task(&mut rng, 64, cfg.d_in, cfg.n_classes);
                let lag_hi = (2 * (w - 1)).min(len.saturating_sub(4)).max(2);
                let corpus =
                    text::generate(&mut rng, &task_def, opts.n(42), len, 0, lag_hi);
                let e = clip_probe_eval(model.as_mut(), &corpus, 0.7, 1e-1)?;
                let secs = runtime_of(model.as_mut(), opts, opts.seed)?;
                f1s.push(e.macro_f1);
                cells.push(format!("{:.1} / {:.0}", 100.0 * e.macro_f1, 1.0 / secs));
            }
            let avg = 100.0 * f1s.iter().sum::<f64>() / f1s.len().max(1) as f64;
            cells.push(format!("{avg:.1}"));
            table.row(cells);
        }
        table.emit(&opts.out_dir, "table4")?;
        out.push(table);
    }
    Ok(out)
}

fn hash(s: &str) -> u64 {
    s.bytes().fold(1469598103934665603u64, |h, b| (h ^ b as u64).wrapping_mul(1099511628211))
}

// ---------------------------------------------------------------------
// Fig. 1 + supp. Figs. 2-3 — latency / throughput vs window size

pub fn run_fig1(rt: &Runtime, opts: &BenchOpts, windows: &[usize]) -> Result<Table> {
    let mut table = Table::new(
        "Fig. 1 / supp. Figs. 2-3 — per-token latency (s) and throughput (tps) vs window size (batch 4)",
        &["Model", "n", "latency/token", "tps", "asymptotic"],
    );
    let fams: &[(&str, &str, bool, &str)] = &[
        ("DeepCoT", "fig1_deepcot_n", false, "O(n)"),
        ("Roformer", "fig1_encoder_n", true, "O(n^2)"),
        ("FNet", "fig1_fnet_n", true, "O(n log n)"),
        ("DeepCoT SOFT", "fig1_deepcot_soft_n", false, "O(n)"),
        ("SOFT Roformer", "fig1_encoder_soft_n", true, "O(n^2)"),
    ];
    for (label, prefix, is_window, asym) in fams {
        for &w in windows {
            let variant = format!("{prefix}{w}");
            if rt.manifest().variant(&variant).is_err() {
                continue;
            }
            let mut model: Box<dyn StreamModel> = if *is_window {
                Box::new(WindowModel::load(rt, &variant)?)
            } else {
                Box::new(ContinualModel::load(rt, &variant)?)
            };
            let secs = runtime_of(model.as_mut(), opts, opts.seed)?;
            let b = model.config().batch as f64;
            table.row(vec![
                label.to_string(),
                w.to_string(),
                fmt_secs(secs / b),
                format!("{:.1}", b / secs),
                asym.to_string(),
            ]);
        }
    }
    table.emit(&opts.out_dir, "fig1")?;
    Ok(table)
}

/// Geometry for the scalar-engine Fig. 1 companion sweep: Fig. 1's
/// "deep encoder" regime scaled to the CPU engines (d=64, 4 heads).
pub fn fig1_scalar_config(window: usize, depth: usize, batch: usize) -> ModelConfig {
    let mut cfg = ModelConfig::synthetic(64, 4, depth, window);
    cfg.batch = batch;
    cfg
}

/// Fig. 1 companion on the pure-Rust scalar engines — no PJRT, no
/// artifacts (synthetic weights): per-tick latency of the pre-refactor
/// naive stepper vs the ring-buffer stepper vs the 4-lane batched
/// stepper (per-lane normalized), across window sizes at `depth`
/// layers. This is the "standard implementation" baseline the paper's
/// runtime comparisons lean on; the speedup column isolates what the
/// zero-allocation ring refactor buys over allocator/memmove noise.
pub fn run_fig1_scalar(opts: &BenchOpts, windows: &[usize], depth: usize) -> Result<Table> {
    let mut table = Table::new(
        &format!(
            "Fig. 1 (scalar CPU engines, {depth} layers) — per-tick latency vs window size"
        ),
        &["Engine", "n", "latency/tick", "tps", "speedup vs naive"],
    );
    for &w in windows {
        let cfg = fig1_scalar_config(w, depth, 1);
        let params = ModelParams::synthetic(&cfg, &mut Rng::new(opts.seed ^ ((w as u64) << 8)));
        let mut naive = NaiveScalarModel::from_parts(
            format!("scalar-naive-n{w}"),
            cfg.clone(),
            params.clone(),
        );
        let naive_s = runtime_of(&mut naive, opts, opts.seed)?;
        table.row(vec![
            "scalar naive (pre-refactor)".into(),
            w.to_string(),
            fmt_secs(naive_s),
            format!("{:.1}", 1.0 / naive_s),
            "x1.00".into(),
        ]);
        let mut ring =
            ScalarModel::from_parts(format!("scalar-ring-n{w}"), cfg.clone(), params.clone());
        let ring_s = runtime_of(&mut ring, opts, opts.seed)?;
        table.row(vec![
            "scalar ring (KvRing)".into(),
            w.to_string(),
            fmt_secs(ring_s),
            format!("{:.1}", 1.0 / ring_s),
            speedup(naive_s, ring_s),
        ]);
        let bcfg = fig1_scalar_config(w, depth, 4);
        let mut batched = BatchedScalarModel::from_parts(
            format!("scalar-batched-b4-n{w}"),
            bcfg,
            params.clone(),
        );
        let batched_s = runtime_of(&mut batched, opts, opts.seed)?;
        let per_lane = batched_s / 4.0;
        table.row(vec![
            "scalar batched B=4 (per lane)".into(),
            w.to_string(),
            fmt_secs(per_lane),
            format!("{:.1}", 1.0 / per_lane),
            speedup(naive_s, per_lane),
        ]);
    }
    table.emit(&opts.out_dir, "fig1_scalar")?;
    Ok(table)
}
