//! Table/figure regeneration harness (criterion replacement, offline):
//! runtime measurement over StreamModels, feature extraction + probe
//! pipelines, and paper-style table printing.

pub mod pipeline;
pub mod tables;
pub mod table;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::baselines::StreamModel;
use crate::runtime::HostTensor;
use crate::util::rng::Rng;
use crate::util::timing::Summary;

/// Measure per-tick latency of a model over a random stream.
/// Returns (summary, tokens-per-second) where a "token" is one time
/// step per batch lane x m_tokens (the paper's tps convention).
pub fn measure_ticks(
    model: &mut dyn StreamModel,
    warmup: usize,
    ticks: usize,
    seed: u64,
) -> Result<(Summary, f64)> {
    let cfg = model.config().clone();
    let mut rng = Rng::new(seed);
    let lane = cfg.batch * cfg.m_tokens * cfg.d_in;
    model.reset()?;
    for _ in 0..warmup {
        let t = HostTensor::new(
            vec![cfg.batch, cfg.m_tokens, cfg.d_in],
            rng.normal_vec(lane, 1.0),
        )?;
        model.tick(&t)?;
    }
    let mut durs = Vec::with_capacity(ticks);
    for _ in 0..ticks {
        let t = HostTensor::new(
            vec![cfg.batch, cfg.m_tokens, cfg.d_in],
            rng.normal_vec(lane, 1.0),
        )?;
        let t0 = Instant::now();
        model.tick(&t)?;
        durs.push(t0.elapsed());
    }
    let s = Summary::of(&durs);
    let tokens_per_tick = (cfg.batch * cfg.m_tokens) as f64;
    Ok((s, tokens_per_tick / s.mean_s))
}

/// Adaptive tick count: spend ~`budget` wall time per measurement, with
/// at least `min_ticks`, so fast models get tight statistics and slow
/// ones stay affordable.
pub fn adaptive_ticks(probe_tick: Duration, budget: Duration, min_ticks: usize) -> usize {
    if probe_tick.is_zero() {
        return min_ticks.max(32);
    }
    ((budget.as_secs_f64() / probe_tick.as_secs_f64()) as usize).clamp(min_ticks, 2000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_ticks_clamped() {
        assert_eq!(
            adaptive_ticks(Duration::from_millis(100), Duration::from_secs(1), 5),
            10
        );
        assert_eq!(
            adaptive_ticks(Duration::from_secs(10), Duration::from_secs(1), 5),
            5
        );
        assert_eq!(
            adaptive_ticks(Duration::from_nanos(1), Duration::from_secs(1), 5),
            2000
        );
    }
}
