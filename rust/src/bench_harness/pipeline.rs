//! Feature-extraction + probe pipelines shared by the accuracy columns
//! of Tables I-IV: stream a corpus through a model, collect attended
//! output features, train a ridge readout on the train split, evaluate
//! on the eval split with the table's metric.

use anyhow::Result;

use crate::baselines::StreamModel;
use crate::nn::tensor::Mat;
use crate::probe::{metrics, RidgeProbe};
use crate::runtime::HostTensor;
use crate::workload::{Corpus, StreamSample};

/// Stream one sample through the model; return the last-token feature
/// at every tick (t_len x d_model rows).
pub fn stream_features(
    model: &mut dyn StreamModel,
    sample: &StreamSample,
) -> Result<Vec<Vec<f32>>> {
    stream_features_pooled(model, sample, false)
}

/// Like [`stream_features`], optionally mean-pooling the m output
/// tokens of each tick (multi-token SED ticks carry events anywhere in
/// the tick, not only at its newest frame).
pub fn stream_features_pooled(
    model: &mut dyn StreamModel,
    sample: &StreamSample,
    pool_tick: bool,
) -> Result<Vec<Vec<f32>>> {
    let cfg = model.config().clone();
    anyhow::ensure!(cfg.batch == 1, "feature pipelines run single-lane");
    anyhow::ensure!(cfg.d_in == sample.d_in, "d_in mismatch");
    let m = cfg.m_tokens;
    model.reset()?;
    let mut feats = Vec::with_capacity(sample.t_len / m);
    let d = cfg.d_model;
    let mut t = 0;
    while t + m <= sample.t_len {
        let mut chunk = Vec::with_capacity(m * cfg.d_in);
        for j in 0..m {
            chunk.extend_from_slice(sample.token(t + j));
        }
        let tokens = HostTensor::new(vec![1, m, cfg.d_in], chunk)?;
        let out = model.tick(&tokens)?;
        let od = out.out.data.len();
        if pool_tick {
            // mean over the tick's m attended tokens
            let mut pooled = vec![0.0f32; d];
            let mm = od / d;
            for j in 0..mm {
                for (pv, &v) in pooled.iter_mut().zip(&out.out.data[j * d..(j + 1) * d]) {
                    *pv += v;
                }
            }
            pooled.iter_mut().for_each(|v| *v /= mm as f32);
            feats.push(pooled);
        } else {
            // newest attended token of the tick
            feats.push(out.out.data[od - d..].to_vec());
        }
        t += m;
    }
    Ok(feats)
}

/// Result of a probe evaluation.
#[derive(Debug, Clone)]
pub struct ProbeEval {
    pub accuracy: f64,
    pub macro_f1: f64,
    pub frame_map: f64,
}

/// Clip-level pipeline (Tables II, IV): feature = last tick's output.
pub fn clip_probe_eval(
    model: &mut dyn StreamModel,
    corpus: &Corpus,
    train_frac: f64,
    lambda: f32,
) -> Result<ProbeEval> {
    let (train, eval) = corpus.split(train_frac);
    let d = model.config().d_model;
    let m = model.config().m_tokens;
    let collect = |model: &mut dyn StreamModel, set: &[&StreamSample]| -> Result<(Mat, Vec<usize>)> {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for s in set {
            // mean-pool the last half of ticks — a steadier clip feature
            // than the single final token, identical across families.
            // Early ticks only need `warm` (window models skip their
            // O(n²·d) recompute there).
            let n_ticks = s.t_len / m;
            let tail_start = n_ticks / 2;
            let cfg = model.config().clone();
            model.reset()?;
            let mut pooled = vec![0.0f32; d];
            let mut pooled_n = 0usize;
            for i in 0..n_ticks {
                let mut chunk = Vec::with_capacity(m * cfg.d_in);
                for j in 0..m {
                    chunk.extend_from_slice(s.token(i * m + j));
                }
                let tokens = HostTensor::new(vec![1, m, cfg.d_in], chunk)?;
                if i < tail_start {
                    model.warm(&tokens)?;
                } else {
                    let out = model.tick(&tokens)?;
                    let od = out.out.data.len();
                    for (p, &v) in pooled.iter_mut().zip(&out.out.data[od - d..]) {
                        *p += v;
                    }
                    pooled_n += 1;
                }
            }
            pooled.iter_mut().for_each(|p| *p /= pooled_n.max(1) as f32);
            rows.extend_from_slice(&pooled);
            labels.push(s.clip_label);
        }
        Ok((Mat::from_vec(labels.len(), d, rows), labels))
    };
    let (xtr, ytr) = collect(model, &train)?;
    let probe = RidgeProbe::train(&xtr, &ytr, corpus.n_classes, lambda)?;
    let (xev, yev) = collect(model, &eval)?;
    let pred: Vec<usize> = (0..xev.rows).map(|r| probe.predict(xev.row(r))).collect();
    Ok(ProbeEval {
        accuracy: metrics::accuracy(&pred, &yev),
        macro_f1: metrics::macro_f1(&pred, &yev, corpus.n_classes),
        frame_map: 0.0,
    })
}

/// Frame-level pipeline (Table I OAD): per-tick features + frame labels,
/// evaluated with frame-level mAP over action classes.
pub fn frame_probe_eval(
    model: &mut dyn StreamModel,
    corpus: &Corpus,
    train_frac: f64,
    lambda: f32,
) -> Result<ProbeEval> {
    let (train, eval) = corpus.split(train_frac);
    let d = model.config().d_model;
    let m = model.config().m_tokens;
    let collect = |model: &mut dyn StreamModel, set: &[&StreamSample]| -> Result<(Mat, Vec<usize>)> {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for s in set {
            for (i, f) in stream_features(model, s)?.into_iter().enumerate() {
                rows.extend_from_slice(&f);
                labels.push(s.frame_labels[(i + 1) * m - 1]);
            }
        }
        Ok((Mat::from_vec(labels.len(), d, rows), labels))
    };
    let (xtr, ytr) = collect(model, &train)?;
    let probe = RidgeProbe::train(&xtr, &ytr, corpus.n_classes, lambda)?;
    let (xev, yev) = collect(model, &eval)?;
    let mut pred = Vec::with_capacity(xev.rows);
    let mut scores = Vec::with_capacity(xev.rows);
    for r in 0..xev.rows {
        let s = probe.scores(xev.row(r));
        pred.push(crate::probe::argmax(&s));
        scores.push(s);
    }
    Ok(ProbeEval {
        accuracy: metrics::accuracy(&pred, &yev),
        macro_f1: metrics::macro_f1(&pred, &yev, corpus.n_classes),
        frame_map: metrics::frame_map(&scores, &yev, corpus.n_classes),
    })
}

/// SED pipeline (Table III): multi-hot frame events, segment + tagging F1.
pub struct SedEval {
    pub segment_f1: f64,
    pub tagging_f1: f64,
}

pub fn sed_probe_eval(
    model: &mut dyn StreamModel,
    corpus: &Corpus,
    train_frac: f64,
    lambda: f32,
    seg_len: usize,
) -> Result<SedEval> {
    let (train, eval) = corpus.split(train_frac);
    let d = model.config().d_model;
    let m = model.config().m_tokens;
    let n_ev = corpus.n_classes;
    // train multi-hot probe on tick features
    let tick_events = |s: &StreamSample, i: usize| -> u32 {
        // all events active anywhere within the tick's m frames
        (i * m..(i + 1) * m).fold(0u32, |a, t| a | s.frame_events[t])
    };
    let mut rows = Vec::new();
    let mut targets = Vec::new();
    for s in &train {
        for (i, f) in stream_features_pooled(model, s, true)?.into_iter().enumerate() {
            rows.extend_from_slice(&f);
            let ev = tick_events(s, i);
            for c in 0..n_ev {
                targets.push(if ev & (1 << c) != 0 { 1.0 } else { 0.0 });
            }
        }
    }
    let n_rows = rows.len() / d;
    let xtr = Mat::from_vec(n_rows, d, rows);
    let ytr = Mat::from_vec(n_rows, n_ev, targets);
    let probe = RidgeProbe::train_multihot(&xtr, &ytr, lambda)?;
    // calibrate a per-class decision threshold on the train scores:
    // midpoint of positive / negative class-score means (ridge scores
    // compress toward the class prior, so a fixed 0.5 is useless for
    // sparse events)
    let mut thr = vec![0.0f32; n_ev];
    {
        let mut pos = vec![(0.0f64, 0u32); n_ev];
        let mut neg = vec![(0.0f64, 0u32); n_ev];
        for r in 0..n_rows {
            let sc = probe.scores(xtr.row(r));
            for c in 0..n_ev {
                if ytr.at(r, c) > 0.5 {
                    pos[c].0 += sc[c] as f64;
                    pos[c].1 += 1;
                } else {
                    neg[c].0 += sc[c] as f64;
                    neg[c].1 += 1;
                }
            }
        }
        for c in 0..n_ev {
            let p = if pos[c].1 > 0 { pos[c].0 / pos[c].1 as f64 } else { 1.0 };
            let n_ = if neg[c].1 > 0 { neg[c].0 / neg[c].1 as f64 } else { 0.0 };
            thr[c] = (0.5 * (p + n_)) as f32;
        }
    }
    let (mut sseg, mut stag, mut cnt) = (0.0, 0.0, 0);
    for s in &eval {
        let mut pred_ev = Vec::with_capacity(s.t_len / m);
        let mut true_ev = Vec::with_capacity(s.t_len / m);
        for (i, f) in stream_features_pooled(model, s, true)?.into_iter().enumerate() {
            let sc = probe.scores(&f);
            let mut mask = 0u32;
            for (c, &v) in sc.iter().enumerate() {
                if v > thr[c] {
                    mask |= 1 << c;
                }
            }
            pred_ev.push(mask);
            true_ev.push(tick_events(s, i));
        }
        sseg += metrics::segment_f1(&pred_ev, &true_ev, n_ev, seg_len);
        stag += metrics::tagging_f1(&pred_ev, &true_ev, n_ev);
        cnt += 1;
    }
    Ok(SedEval { segment_f1: sseg / cnt.max(1) as f64, tagging_f1: stag / cnt.max(1) as f64 })
}
