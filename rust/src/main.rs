//! `deepcot-serve` — the leader entrypoint: starts the serving engine on
//! a batched DeepCoT variant and drives a demonstration load (or, with
//! `--list`, shows the available AOT variants).
//!
//! Python never runs here: the binary consumes `artifacts/` produced by
//! `make artifacts` and serves entirely from Rust + PJRT.

use std::time::{Duration, Instant};

use anyhow::Result;

use deepcot::config::EngineConfig;
use deepcot::coordinator::engine::{EngineThread, Session};
use deepcot::manifest::Manifest;
use deepcot::util::cli::Cli;
use deepcot::util::rng::Rng;

fn main() -> Result<()> {
    let cli = EngineConfig::cli(Cli::new(
        "deepcot-serve: stream-inference coordinator for DeepCoT AOT artifacts",
    ))
    .opt("streams", "4", "number of synthetic client streams")
    .opt("ticks", "64", "tokens each client sends")
    .opt("seed", "0", "workload seed")
    .flag("list", "list manifest variants and exit");
    let args = cli.parse()?;
    let cfg = EngineConfig::from_args(&args)?;

    if args.has("list") {
        let (m, _) = Manifest::load(&cfg.artifacts_dir)?;
        println!(
            "{:<28} {:>14} {:>6} {:>4} {:>6} {:>3} {:>6}",
            "variant", "family", "layers", "B", "window", "m", "d"
        );
        for (name, e) in &m.variants {
            let c = &e.config;
            println!(
                "{:<28} {:>14} {:>6} {:>4} {:>6} {:>3} {:>6}",
                name, e.family, c.n_layers, c.batch, c.window, c.m_tokens, c.d_model
            );
        }
        return Ok(());
    }

    let n_streams = args.get_usize("streams")?;
    let ticks = args.get_usize("ticks")?;
    let seed = args.get_u64("seed")?;

    let (manifest, _) = Manifest::load(&cfg.artifacts_dir)?;
    let mc = manifest.variant(&cfg.variant)?.config.clone();
    let lane = mc.m_tokens * mc.d_in;

    eprintln!("starting engine on {} ...", cfg.variant);
    let engine = EngineThread::spawn(cfg.clone())?;
    let handle = engine.handle();
    eprintln!("engine ready; driving {n_streams} streams x {ticks} ticks");

    let t0 = Instant::now();
    let mut clients = Vec::new();
    for s in 0..n_streams {
        let h = engine.handle();
        clients.push(std::thread::spawn(move || -> Result<(u64, Duration)> {
            let mut rng = Rng::new(seed ^ ((s as u64) << 17));
            let sess: Session = h.open()?;
            let mut got = 0u64;
            let mut lat = Duration::ZERO;
            for _ in 0..ticks {
                let sent = Instant::now();
                sess.push(rng.normal_vec(lane, 1.0))?;
                let _out = sess.recv_timeout(Duration::from_secs(30))?;
                lat += sent.elapsed();
                got += 1;
            }
            sess.close();
            Ok((got, lat))
        }));
    }
    let mut total = 0u64;
    let mut lat_sum = Duration::ZERO;
    for c in clients {
        let (got, lat) = c.join().expect("client thread")?;
        total += got;
        lat_sum += lat;
    }
    let wall = t0.elapsed();
    let metrics = handle.metrics()?;
    println!("== deepcot-serve summary ==");
    println!("streams={n_streams} ticks/stream={ticks} outputs={total}");
    println!(
        "wall={:.2?}  throughput={:.1} tokens/s  mean client latency={:.2?}",
        wall,
        total as f64 / wall.as_secs_f64(),
        lat_sum / total.max(1) as u32
    );
    println!("engine: {}", metrics.report());
    engine.shutdown()?;
    Ok(())
}
