//! Tiny CLI argument parser — substrate replacing `clap`.
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` from registered options.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative CLI: register options, then parse.
#[derive(Debug, Default)]
pub struct Cli {
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(about: &'static str) -> Self {
        Self { about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nUSAGE: {prog} [OPTIONS]\n\nOPTIONS:\n", self.about);
        for o in &self.opts {
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s.push_str("  --help               print this message\n");
        s
    }

    /// Parse argv (without the program name). Exits on `--help`.
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                print!("{}", self.usage("<prog>"));
                std::process::exit(0);
            }
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (raw.to_string(), None),
                };
                let Some(spec) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option --{name}");
                };
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("--{name} is a flag and takes no value");
                    }
                    flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => match it.next() {
                            Some(v) => v,
                            None => bail!("--{name} needs a value"),
                        },
                    };
                    values.insert(name, v);
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { values, flags, positional })
    }

    pub fn parse(&self) -> Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name);
        match v.parse() {
            Ok(x) => Ok(x),
            Err(_) => bail!("--{name} expects an integer, got {v:?}"),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name);
        match v.parse() {
            Ok(x) => Ok(x),
            Err(_) => bail!("--{name} expects a number, got {v:?}"),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name);
        match v.parse() {
            Ok(x) => Ok(x),
            Err(_) => bail!("--{name} expects an integer, got {v:?}"),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test").opt("n", "8", "count").flag("fast", "go fast")
    }

    fn parse(args: &[&str]) -> Result<Args> {
        cli().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 8);
        let a = parse(&["--n", "32"]).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 32);
        let a = parse(&["--n=64"]).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 64);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["--fast", "pos1"]).unwrap();
        assert!(a.has("fast"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&["--bogus"]).is_err());
        assert!(parse(&["--n"]).is_err());
        assert!(parse(&["--fast=1"]).is_err());
        assert!(parse(&["--n", "abc"]).unwrap().get_usize("n").is_err());
    }
}
