//! Measurement helpers shared by the bench harness and the metrics
//! module: steady-clock stopwatch, robust summary statistics.

use std::time::{Duration, Instant};

/// Run `f` `iters` times after `warmup` runs; return per-iteration
/// durations.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<Duration> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed());
    }
    out
}

/// Summary statistics over a sample of durations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Summary {
    pub fn of(durs: &[Duration]) -> Summary {
        assert!(!durs.is_empty());
        let mut secs: Vec<f64> = durs.iter().map(|d| d.as_secs_f64()).collect();
        secs.sort_by(|a, b| a.total_cmp(b));
        let n = secs.len();
        Summary {
            n,
            mean_s: secs.iter().sum::<f64>() / n as f64,
            p50_s: percentile(&secs, 0.50),
            p95_s: percentile(&secs, 0.95),
            min_s: secs[0],
            max_s: secs[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_counts() {
        let durs: Vec<Duration> = (1..=10).map(Duration::from_millis).collect();
        let s = Summary::of(&durs);
        assert_eq!(s.n, 10);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s && s.p95_s <= s.max_s);
        assert!((s.mean_s - 0.0055).abs() < 1e-9);
    }

    #[test]
    fn time_iters_runs() {
        let mut count = 0;
        let d = time_iters(2, 5, || count += 1);
        assert_eq!(d.len(), 5);
        assert_eq!(count, 7);
    }
}
