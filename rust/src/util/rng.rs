//! Deterministic PRNG — substrate replacing the `rand` crate.
//!
//! SplitMix64 core (Steele et al.): excellent statistical quality for
//! workload synthesis, trivially seedable, and byte-stable across
//! platforms so every experiment in EXPERIMENTS.md is reproducible from
//! its seed alone.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller sample.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * self.uniform();
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid N(0, sigma^2) samples.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * sigma).collect()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Derive an independent child generator (for parallel workloads).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let x = r.range(3, 9);
            assert!((3..9).contains(&x));
        }
    }
}
