//! Minimal JSON parser/serializer — substrate replacing `serde_json`
//! (unavailable in this offline image; DESIGN.md §2).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`:
//! objects (insertion-ordered), arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are stored as f64 — integral values up to
//! 2^53 round-trip exactly, far beyond any shape or count we encode.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----- accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing key {key:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Decode an array of numbers into f32s (golden payloads).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Ok(out)
    }

    /// Decode an array of non-negative integers (shapes).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_usize()?);
        }
        Ok(out)
    }

    // ----- parsing ---------------------------------------------------

    pub fn parse(s: &str) -> Result<Json> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ----- serialization ----------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{n}"));
                }
            }
            Json::Str(v) => write_escaped(s, v),
            Json::Arr(items) => {
                s.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    it.write(s);
                }
                s.push(']');
            }
            Json::Obj(kv) => {
                s.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_escaped(s, k);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

fn write_escaped(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        match self.b.get(self.i) {
            Some(&c) => Ok(c),
            None => bail!("unexpected end of input"),
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek()? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            // BMP only (no surrogate pairs in our payloads)
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => bail!("bad escape {:?}", c as char),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => bail!("bad number {text:?} at byte {start}"),
        }
    }
}

/// Build helpers for serialization call-sites.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr_f32(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basics() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\"y\n", "c": true, "d": null, "e": {}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x\"y\n");
        assert!(v.req("c").unwrap().as_bool().unwrap());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_order_preserved() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn usize_vec_and_errors() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(Json::parse("[-1]").unwrap().as_usize_vec().is_err());
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn integral_floats_serialize_as_ints() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
