//! Micro property-testing harness — substrate replacing `proptest`
//! (unavailable offline). Seeded, reproducible, with per-case seed
//! reporting on failure so any counterexample can be replayed.

use crate::util::rng::Rng;

/// Run `cases` random cases of `f`; each gets an independent `Rng`.
/// On failure, panics with the case seed for replay.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u32, mut f: F) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}): {msg}\n\
                 replay with DEEPCOT_PROP_SEED={seed:#x}"
            );
        }
    }
}

fn base_seed() -> u64 {
    if let Ok(s) = std::env::var("DEEPCOT_PROP_SEED") {
        let s = s.trim_start_matches("0x");
        if let Ok(v) = u64::from_str_radix(s, 16) {
            return v;
        }
    }
    // fixed default: CI determinism beats novelty
    0xDEE9_C075_EED0_0001_u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("tautology", 50, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failure() {
        check("always-false", 3, |_| Err("nope".into()));
    }
}
