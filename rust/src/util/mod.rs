//! In-repo substrates replacing crates unavailable in this offline image
//! (DESIGN.md §2): JSON (`serde_json`), PRNG (`rand`), CLI (`clap`),
//! property testing (`proptest`), plus shared timing helpers.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timing;
