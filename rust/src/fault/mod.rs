//! Deterministic, seeded fault injection — the chaos half of the
//! fault-isolation story.
//!
//! A [`FaultPlan`] names *where* faults may fire (an injection site per
//! failure-prone subsystem boundary: shard step, state-store I/O, net
//! reads/writes, snapshot torn tails) and *when* (a seeded 1-in-N
//! probability per call, or exactly once at call N). Plans are
//! plain-text specs so they travel through config, CLI, and the
//! `DEEPCOT_FAULT` environment variable:
//!
//! ```text
//!   seed=7,shard=0,shard_step=@40      # shard 0 panics on its 40th tick
//!   seed=9,store_put=25                # ~1 in 25 store puts fail
//!   seed=3,net_read=200,torn_tail=@1   # flaky reads + one torn tail
//! ```
//!
//! Determinism contract: whether call number `k` at a site fires
//! depends only on `(seed, site, k)` — never on wall time, thread
//! scheduling, or an OS RNG — so a failing chaos run replays exactly
//! from its seed. Shard-step faults additionally apply to one target
//! shard (`shard=K`, default 0) and are counted on that shard's calls
//! alone, so "panic on the 40th tick" means the 40th tick *of that
//! shard* regardless of how the other shards interleave.
//!
//! Cost contract: a disabled [`FaultInjector`] is one `Option` branch
//! per site visit — no atomics, no hashing, no allocation — so the
//! zero-alloc and bitwise pins on the serving hot path hold unchanged
//! when injection is off (the default everywhere).

use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::store::{StateStore, StoreError};

/// Where a fault can be injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Panic the shard worker just before a backend step (targets the
    /// plan's `shard=K`; counted on that shard's steps alone).
    ShardStep = 0,
    /// Fail a [`StateStore::put`] with a typed I/O error.
    StorePut = 1,
    /// Fail a [`StateStore::get`] with a typed I/O error.
    StoreGet = 2,
    /// Fail a [`StateStore::sync`] with a typed I/O error.
    StoreSync = 3,
    /// Tear down a server-side connection read (half-open client).
    NetRead = 4,
    /// Abandon a server-side frame write halfway (partial write).
    NetWrite = 5,
    /// Append a torn (truncated, CRC-less) entry to the state log, as
    /// a crash mid-append would leave behind.
    TornTail = 6,
}

impl FaultSite {
    /// Number of injection sites.
    pub const COUNT: usize = 7;

    /// Every site, in discriminant order.
    pub const ALL: [FaultSite; FaultSite::COUNT] = [
        FaultSite::ShardStep,
        FaultSite::StorePut,
        FaultSite::StoreGet,
        FaultSite::StoreSync,
        FaultSite::NetRead,
        FaultSite::NetWrite,
        FaultSite::TornTail,
    ];

    /// The spec key naming this site in a [`FaultPlan`] string.
    pub fn key(&self) -> &'static str {
        match self {
            FaultSite::ShardStep => "shard_step",
            FaultSite::StorePut => "store_put",
            FaultSite::StoreGet => "store_get",
            FaultSite::StoreSync => "store_sync",
            FaultSite::NetRead => "net_read",
            FaultSite::NetWrite => "net_write",
            FaultSite::TornTail => "torn_tail",
        }
    }

    fn from_key(key: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|s| s.key() == key)
    }
}

/// When a site fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// Fire with seeded probability 1-in-N per call.
    Rate(u64),
    /// Fire exactly once, on the N-th call (1-based).
    At(u64),
}

/// A parsed fault schedule: which sites fire, and when. The default
/// plan is fully disabled; see the module docs for the spec grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for the per-call fire decision at `Rate` sites.
    pub seed: u64,
    /// Shard index that shard-step faults target (other shards never
    /// count or fire them).
    pub target_shard: u64,
    triggers: [Option<Trigger>; FaultSite::COUNT],
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { seed: 0, target_shard: 0, triggers: [None; FaultSite::COUNT] }
    }
}

impl FaultPlan {
    /// Environment variable consulted by [`FaultPlan::default_from_env`].
    pub const ENV: &'static str = "DEEPCOT_FAULT";

    /// A plan with no armed sites (injection fully off).
    pub fn disabled() -> FaultPlan {
        FaultPlan::default()
    }

    /// Whether any site is armed.
    pub fn is_enabled(&self) -> bool {
        self.triggers.iter().any(|t| t.is_some())
    }

    /// Whether the shard-step site is armed (a supervisor smoke can
    /// expect a panic only when one is scheduled).
    pub fn arms_shard_step(&self) -> bool {
        self.triggers[FaultSite::ShardStep as usize].is_some()
    }

    /// The plan `DEEPCOT_FAULT` requests, or the disabled default when
    /// the variable is unset. An unparsable value warns on stderr and
    /// keeps the default rather than silently arming anything.
    pub fn default_from_env() -> FaultPlan {
        match std::env::var(Self::ENV) {
            Err(_) => FaultPlan::default(),
            Ok(raw) => match raw.parse() {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("warning: ignoring {}={raw:?}: {e}", Self::ENV);
                    FaultPlan::default()
                }
            },
        }
    }
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<FaultPlan, String> {
        let s = s.trim();
        let mut plan = FaultPlan::default();
        if s.is_empty() || s == "off" {
            return Ok(plan);
        }
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec entry {part:?} is not key=value"))?;
            let parse_u64 = |v: &str, what: &str| {
                v.parse::<u64>().map_err(|_| format!("{what} {v:?} is not an integer"))
            };
            match key.trim() {
                "seed" => plan.seed = parse_u64(value, "seed")?,
                "shard" => plan.target_shard = parse_u64(value, "shard")?,
                key => {
                    let site = FaultSite::from_key(key)
                        .ok_or_else(|| format!("unknown fault site {key:?}"))?;
                    let trig = if let Some(at) = value.strip_prefix('@') {
                        Trigger::At(parse_u64(at, "call index")?)
                    } else {
                        Trigger::Rate(parse_u64(value, "rate")?)
                    };
                    let n = match trig {
                        Trigger::Rate(n) | Trigger::At(n) => n,
                    };
                    if n == 0 {
                        return Err(format!("fault site {key} wants a value >= 1"));
                    }
                    plan.triggers[site as usize] = Some(trig);
                }
            }
        }
        Ok(plan)
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if !self.is_enabled() {
            return write!(f, "off");
        }
        write!(f, "seed={},shard={}", self.seed, self.target_shard)?;
        for site in FaultSite::ALL {
            match self.triggers[site as usize] {
                None => {}
                Some(Trigger::Rate(n)) => write!(f, ",{}={n}", site.key())?,
                Some(Trigger::At(n)) => write!(f, ",{}=@{n}", site.key())?,
            }
        }
        Ok(())
    }
}

/// SplitMix64 finalizer: the stateless hash behind `Rate` decisions.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[derive(Debug)]
struct InjectorState {
    seed: u64,
    target_shard: u64,
    triggers: [Option<Trigger>; FaultSite::COUNT],
    calls: [AtomicU64; FaultSite::COUNT],
    fired: [AtomicU64; FaultSite::COUNT],
}

/// The runtime form of a [`FaultPlan`]: cheap to clone, shared across
/// every subsystem of one engine, with per-site call and fire counters.
/// Disabled (the default) it is a single `Option` branch per visit.
#[derive(Debug, Clone, Default)]
pub struct FaultInjector {
    state: Option<Arc<InjectorState>>,
}

impl FaultInjector {
    /// An injector that never fires (zero per-visit cost beyond one
    /// branch).
    pub fn disabled() -> FaultInjector {
        FaultInjector { state: None }
    }

    /// Build the injector a plan describes (disabled when the plan
    /// arms nothing).
    pub fn from_plan(plan: &FaultPlan) -> FaultInjector {
        if !plan.is_enabled() {
            return FaultInjector::disabled();
        }
        FaultInjector {
            state: Some(Arc::new(InjectorState {
                seed: plan.seed,
                target_shard: plan.target_shard,
                triggers: plan.triggers,
                calls: std::array::from_fn(|_| AtomicU64::new(0)),
                fired: std::array::from_fn(|_| AtomicU64::new(0)),
            })),
        }
    }

    /// Whether any site is armed.
    pub fn enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Visit a site: count the call and decide — deterministically from
    /// `(seed, site, call index)` — whether the fault fires here.
    pub fn fire(&self, site: FaultSite) -> bool {
        let Some(st) = &self.state else { return false };
        let Some(trig) = st.triggers[site as usize] else { return false };
        let call = st.calls[site as usize].fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match trig {
            Trigger::At(n) => call == n,
            Trigger::Rate(n) => mix(st.seed ^ ((site as u64) << 32) ^ call) % n == 0,
        };
        if hit {
            st.fired[site as usize].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// [`FaultInjector::fire`] gated to the plan's target shard: other
    /// shards neither count nor fire (this is what keeps "the 40th
    /// step" deterministic on a multi-shard cluster).
    pub fn fire_on_shard(&self, site: FaultSite, shard: u64) -> bool {
        let Some(st) = &self.state else { return false };
        if shard != st.target_shard {
            return false;
        }
        self.fire(site)
    }

    /// Times `site` has been visited.
    pub fn calls(&self, site: FaultSite) -> u64 {
        self.state.as_ref().map_or(0, |st| st.calls[site as usize].load(Ordering::Relaxed))
    }

    /// Times `site` has fired.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.state.as_ref().map_or(0, |st| st.fired[site as usize].load(Ordering::Relaxed))
    }
}

/// A [`StateStore`] decorator that injects typed I/O failures and torn
/// log tails per the engine's fault plan. With injection disabled it is
/// never constructed — the engine wraps its store only when a store
/// site is armed, so healthy configurations pay nothing.
pub struct FaultStore {
    inner: Box<dyn StateStore>,
    inj: FaultInjector,
    /// The on-disk log to tear when [`FaultSite::TornTail`] fires
    /// (`None` for volatile stores, where a torn tail is meaningless).
    torn_path: Option<PathBuf>,
}

impl FaultStore {
    /// Wrap `inner`, injecting per `inj`; `torn_path` is the log file
    /// torn-tail faults append garbage to.
    pub fn new(
        inner: Box<dyn StateStore>,
        inj: FaultInjector,
        torn_path: Option<PathBuf>,
    ) -> FaultStore {
        FaultStore { inner, inj, torn_path }
    }

    fn tear_tail(&self) {
        let Some(path) = &self.torn_path else { return };
        // a truncated entry: a plausible length prefix with only a few
        // of its promised bytes behind it — exactly what a crash
        // mid-append leaves; the next open must truncate it away
        if let Ok(mut f) = std::fs::OpenOptions::new().append(true).open(path) {
            use std::io::Write;
            let _ = f.write_all(&[0x40, 0, 0, 0, 1, 0xDE, 0xAD, 0xBE, 0xEF, 0x00, 0x01]);
        }
    }
}

impl StateStore for FaultStore {
    fn put(&mut self, stream: u64, blob: &[u8]) -> Result<(), StoreError> {
        if self.inj.fire(FaultSite::StorePut) {
            return Err(StoreError::Io(format!("injected fault: store put (stream {stream})")));
        }
        self.inner.put(stream, blob)
    }

    fn get(&mut self, stream: u64) -> Result<Option<Vec<u8>>, StoreError> {
        if self.inj.fire(FaultSite::StoreGet) {
            return Err(StoreError::Io(format!("injected fault: store get (stream {stream})")));
        }
        self.inner.get(stream)
    }

    fn delete(&mut self, stream: u64) -> Result<bool, StoreError> {
        self.inner.delete(stream)
    }

    fn list(&mut self) -> Result<Vec<u64>, StoreError> {
        self.inner.list()
    }

    fn sync(&mut self) -> Result<(), StoreError> {
        if self.inj.fire(FaultSite::StoreSync) {
            return Err(StoreError::Io("injected fault: store sync".into()));
        }
        if self.inj.fire(FaultSite::TornTail) {
            self.tear_tail();
        }
        self.inner.sync()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    #[test]
    fn default_plan_is_disabled_and_free() {
        let plan = FaultPlan::default();
        assert!(!plan.is_enabled());
        assert_eq!(plan.to_string(), "off");
        let inj = FaultInjector::from_plan(&plan);
        assert!(!inj.enabled());
        for site in FaultSite::ALL {
            assert!(!inj.fire(site));
            assert_eq!(inj.calls(site), 0, "disabled injector must not even count");
        }
    }

    #[test]
    fn spec_round_trips() {
        let plan: FaultPlan = "seed=7,shard=1,shard_step=@40,store_put=25".parse().unwrap();
        assert!(plan.is_enabled());
        assert!(plan.arms_shard_step());
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.target_shard, 1);
        let rendered = plan.to_string();
        let back: FaultPlan = rendered.parse().unwrap();
        assert_eq!(back, plan);
        assert_eq!("off".parse::<FaultPlan>().unwrap(), FaultPlan::disabled());
        assert_eq!("".parse::<FaultPlan>().unwrap(), FaultPlan::disabled());
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in ["nonsense", "seed", "bogus_site=3", "store_put=0", "store_put=@0", "seed=x"] {
            assert!(bad.parse::<FaultPlan>().is_err(), "spec {bad:?} should not parse");
        }
    }

    #[test]
    fn at_trigger_fires_exactly_once() {
        let plan: FaultPlan = "seed=1,store_put=@3".parse().unwrap();
        let inj = FaultInjector::from_plan(&plan);
        let fires: Vec<bool> = (0..10).map(|_| inj.fire(FaultSite::StorePut)).collect();
        assert_eq!(fires.iter().filter(|f| **f).count(), 1);
        assert!(fires[2], "must fire on the 3rd call");
        assert_eq!(inj.fired(FaultSite::StorePut), 1);
        assert_eq!(inj.calls(FaultSite::StorePut), 10);
    }

    #[test]
    fn rate_trigger_is_seed_deterministic() {
        let plan: FaultPlan = "seed=42,store_get=10".parse().unwrap();
        let a = FaultInjector::from_plan(&plan);
        let b = FaultInjector::from_plan(&plan);
        let fa: Vec<bool> = (0..10_000).map(|_| a.fire(FaultSite::StoreGet)).collect();
        let fb: Vec<bool> = (0..10_000).map(|_| b.fire(FaultSite::StoreGet)).collect();
        assert_eq!(fa, fb, "same seed, same schedule");
        let hits = fa.iter().filter(|f| **f).count();
        // 1-in-10 over 10k calls: loose 2x band, deterministic anyway
        assert!((500..2000).contains(&hits), "rate wildly off: {hits}");
        // a different seed gives a different schedule
        let other = FaultInjector::from_plan(&"seed=43,store_get=10".parse().unwrap());
        let fo: Vec<bool> = (0..10_000).map(|_| other.fire(FaultSite::StoreGet)).collect();
        assert_ne!(fa, fo);
    }

    #[test]
    fn shard_gate_neither_counts_nor_fires_elsewhere() {
        let plan: FaultPlan = "seed=5,shard=2,shard_step=@1".parse().unwrap();
        let inj = FaultInjector::from_plan(&plan);
        assert!(!inj.fire_on_shard(FaultSite::ShardStep, 0));
        assert!(!inj.fire_on_shard(FaultSite::ShardStep, 1));
        assert_eq!(inj.calls(FaultSite::ShardStep), 0);
        assert!(inj.fire_on_shard(FaultSite::ShardStep, 2));
        assert_eq!(inj.fired(FaultSite::ShardStep), 1);
    }

    #[test]
    fn fault_store_injects_typed_io_errors() {
        let plan: FaultPlan = "seed=1,store_put=@2,store_get=@1,store_sync=@1".parse().unwrap();
        let mut s =
            FaultStore::new(Box::new(MemStore::new()), FaultInjector::from_plan(&plan), None);
        s.put(1, b"one").unwrap();
        match s.put(2, b"two") {
            Err(StoreError::Io(m)) => assert!(m.contains("injected"), "{m}"),
            other => panic!("expected injected Io error, got {other:?}"),
        }
        match s.get(1) {
            Err(StoreError::Io(_)) => {}
            other => panic!("expected injected Io error, got {other:?}"),
        }
        assert!(s.sync().is_err());
        // after the scheduled faults, the store serves normally
        assert_eq!(s.get(1).unwrap().as_deref(), Some(&b"one"[..]));
        s.sync().unwrap();
        assert_eq!(s.list().unwrap(), vec![1]);
        assert!(s.delete(1).unwrap());
    }
}
