//! Linear probes + evaluation metrics.
//!
//! The paper fine-tunes full models; with identical weights across
//! continual and non-continual variants (its own equivalence protocol),
//! the *relative* quality of the features each attention mechanism
//! exposes is what varies. We measure that with a closed-form ridge
//! readout on the encoder outputs — cheap, deterministic, and identical
//! across model families. Metrics mirror each table: accuracy (II),
//! mAP (I), macro F1 (IV), segment-based F1 + audio-tagging F1 (III).

pub mod metrics;

use anyhow::Result;

use crate::nn::linalg::ridge;
use crate::nn::tensor::Mat;

/// One-vs-all ridge classifier trained on feature rows.
#[derive(Debug, Clone)]
pub struct RidgeProbe {
    pub w: Mat, // (d x c)
    pub n_classes: usize,
}

impl RidgeProbe {
    /// features: rows of d-dim features; labels: class per row.
    pub fn train(features: &Mat, labels: &[usize], n_classes: usize, lambda: f32) -> Result<Self> {
        assert_eq!(features.rows, labels.len());
        let mut y = Mat::zeros(features.rows, n_classes);
        for (r, &l) in labels.iter().enumerate() {
            *y.at_mut(r, l) = 1.0;
        }
        Ok(Self { w: ridge(features, &y, lambda)?, n_classes })
    }

    /// Train on multi-hot targets (SED): `targets` is (rows x c) in {0,1}.
    pub fn train_multihot(features: &Mat, targets: &Mat, lambda: f32) -> Result<Self> {
        Ok(Self { w: ridge(features, targets, lambda)?, n_classes: targets.cols })
    }

    /// Per-class scores for one feature row.
    pub fn scores(&self, feat: &[f32]) -> Vec<f32> {
        let x = Mat::from_vec(1, feat.len(), feat.to_vec());
        x.matmul(&self.w).data
    }

    pub fn predict(&self, feat: &[f32]) -> usize {
        let s = self.scores(feat);
        argmax(&s)
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn probe_learns_linear_classes() {
        let mut rng = Rng::new(21);
        let (n, d, c) = (300, 10, 3);
        let mut feats = Mat::zeros(n, d);
        let mut labels = vec![0usize; n];
        for r in 0..n {
            let cls = r % c;
            labels[r] = cls;
            for i in 0..d {
                *feats.at_mut(r, i) =
                    rng.normal_f32() * 0.3 + if i == cls { 2.0 } else { 0.0 };
            }
        }
        let probe = RidgeProbe::train(&feats, &labels, c, 1e-2).unwrap();
        let mut correct = 0;
        for r in 0..n {
            if probe.predict(feats.row(r)) == labels[r] {
                correct += 1;
            }
        }
        assert!(correct as f64 / n as f64 > 0.95);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.1, 3.0, 3.0, -1.0]), 1);
    }
}
