//! Evaluation metrics matching each paper table.

/// Top-1 accuracy.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let ok = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    ok as f64 / pred.len() as f64
}

/// Macro-averaged F1 over classes present in the truth.
pub fn macro_f1(pred: &[usize], truth: &[usize], n_classes: usize) -> f64 {
    let mut f1s = Vec::new();
    for c in 0..n_classes {
        let tp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t == c).count() as f64;
        let fp = pred.iter().zip(truth).filter(|(&p, &t)| p == c && t != c).count() as f64;
        let fnn = pred.iter().zip(truth).filter(|(&p, &t)| p != c && t == c).count() as f64;
        if tp + fnn == 0.0 {
            continue; // class absent from truth
        }
        let prec = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
        let rec = tp / (tp + fnn);
        f1s.push(if prec + rec > 0.0 { 2.0 * prec * rec / (prec + rec) } else { 0.0 });
    }
    if f1s.is_empty() {
        0.0
    } else {
        f1s.iter().sum::<f64>() / f1s.len() as f64
    }
}

/// Average precision for one class from (score, is_positive) pairs.
pub fn average_precision(scored: &mut Vec<(f32, bool)>) -> f64 {
    let n_pos = scored.iter().filter(|(_, p)| *p).count();
    if n_pos == 0 {
        return 0.0;
    }
    scored.sort_by(|a, b| b.0.total_cmp(&a.0));
    let (mut tp, mut ap) = (0usize, 0.0f64);
    for (rank, (_, pos)) in scored.iter().enumerate() {
        if *pos {
            tp += 1;
            ap += tp as f64 / (rank + 1) as f64;
        }
    }
    ap / n_pos as f64
}

/// Frame-level mean Average Precision over action classes (Table I,
/// THUMOS protocol: background class 0 excluded).
pub fn frame_map(scores: &[Vec<f32>], truth: &[usize], n_classes: usize) -> f64 {
    assert_eq!(scores.len(), truth.len());
    let mut aps = Vec::new();
    for c in 1..n_classes {
        let mut scored: Vec<(f32, bool)> = scores
            .iter()
            .zip(truth)
            .map(|(s, &t)| (s[c], t == c))
            .collect();
        if scored.iter().any(|(_, p)| *p) {
            aps.push(average_precision(&mut scored));
        }
    }
    if aps.is_empty() {
        0.0
    } else {
        aps.iter().sum::<f64>() / aps.len() as f64
    }
}

/// Segment-based F1 for SED (Table III SbF1): frame-level multi-hot
/// decisions pooled into fixed-length segments; a segment counts an
/// event active if any frame inside does.
pub fn segment_f1(
    pred_events: &[u32],
    true_events: &[u32],
    n_events: usize,
    seg_len: usize,
) -> f64 {
    assert_eq!(pred_events.len(), true_events.len());
    let pool = |ev: &[u32]| -> Vec<u32> {
        ev.chunks(seg_len.max(1)).map(|c| c.iter().fold(0u32, |a, &b| a | b)).collect()
    };
    let ps = pool(pred_events);
    let ts = pool(true_events);
    let (mut tp, mut fp, mut fnn) = (0.0f64, 0.0f64, 0.0f64);
    for c in 0..n_events {
        let bit = 1u32 << c;
        for (p, t) in ps.iter().zip(&ts) {
            match (p & bit != 0, t & bit != 0) {
                (true, true) => tp += 1.0,
                (true, false) => fp += 1.0,
                (false, true) => fnn += 1.0,
                _ => {}
            }
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fnn);
    2.0 * prec * rec / (prec + rec)
}

/// Audio-tagging F1 (Table III AtF1): clip-level event presence.
pub fn tagging_f1(pred_events: &[u32], true_events: &[u32], n_events: usize) -> f64 {
    let clip_or = |ev: &[u32]| ev.iter().fold(0u32, |a, &b| a | b);
    segment_f1(&[clip_or(pred_events)], &[clip_or(true_events)], n_events, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn perfect_f1() {
        let p = vec![0, 1, 2, 0, 1, 2];
        assert!((macro_f1(&p, &p, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ap_ranks_matter() {
        // positive ranked first -> AP 1.0
        let mut s = vec![(0.9, true), (0.5, false), (0.1, false)];
        assert!((average_precision(&mut s) - 1.0).abs() < 1e-12);
        // positive ranked last -> AP 1/3
        let mut s = vec![(0.9, false), (0.5, false), (0.1, true)];
        assert!((average_precision(&mut s) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn frame_map_excludes_background() {
        let scores = vec![vec![0.0, 1.0], vec![0.0, 0.2], vec![0.0, 0.9]];
        let truth = vec![1, 0, 1];
        let m = frame_map(&scores, &truth, 2);
        assert!(m > 0.99);
    }

    #[test]
    fn segment_f1_pools_frames() {
        // event active frames 0..2, prediction shifted by one frame:
        // seg_len 2 -> seg truth [1,0], seg pred [1,1]: tp=1, fp=1
        // -> F1 = 2/3; seg_len 1 -> tp=1, fp=1, fn=1 -> F1 = 1/2.
        let truth = vec![1, 1, 0, 0];
        let pred = vec![0, 1, 1, 0];
        let f2 = segment_f1(&pred, &truth, 1, 2);
        let f1 = segment_f1(&pred, &truth, 1, 1);
        assert!((f2 - 2.0 / 3.0).abs() < 1e-9, "{f2}");
        assert!((f1 - 0.5).abs() < 1e-9, "{f1}");
        assert!(f2 > f1, "coarser segments are more tolerant to shifts");
    }

    #[test]
    fn tagging_f1_clip_level() {
        let truth = vec![0b01, 0b01, 0, 0];
        let pred = vec![0, 0, 0b01, 0];
        assert!((tagging_f1(&pred, &truth, 2) - 1.0).abs() < 1e-12);
    }
}
